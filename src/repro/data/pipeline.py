"""Stateless, counter-keyed synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` via the same
kinetic_hash32 counter RNG the market engine uses (DESIGN.md §4.3): no
iterator state to checkpoint, any host can regenerate any shard of any step
— which is what makes elastic restart and bitwise-reproducible resume work
at 1000-node scale.

The synthetic LM stream is Zipf-ish over the vocabulary with a deterministic
shift structure so the loss is learnable (next token correlates with the
current one), which the convergence tests rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import rng as krng
from repro.models.model_config import ModelConfig

_CH_TOK = 11


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, shard): int32[tokens/labels]."""
        B, T = self.shard_batch, self.seq_len
        row0 = self.shard * self.shard_batch
        rows = np.arange(row0, row0 + B, dtype=np.uint32)[:, None]
        cols = np.arange(T + 1, dtype=np.uint32)[None, :]
        gid = rows * np.uint32(1_000_003) + cols
        u = krng.uniform32(np.uint32(self.seed), gid, np.uint32(step),
                           np.uint32(_CH_TOK), np)
        # Zipf-ish marginal: heavy mass on small ids.
        base = (u ** np.float32(4.0) * np.float32(self.vocab_size)).astype(np.int64)
        # Learnable structure: every odd position repeats an affine function
        # of the previous token.
        seq = base.copy()
        shifted = (seq[:, :-1] * 31 + 7) % self.vocab_size
        odd = (np.arange(1, T + 1) % 2).astype(bool)
        seq[:, 1:][:, odd[: T]] = shifted[:, odd[: T]]
        seq = np.clip(seq, 0, self.vocab_size - 1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def make_batch(cfg: ModelConfig, shape, step: int, seed: int = 0,
               num_shards: int = 1, shard: int = 0) -> Dict[str, np.ndarray]:
    """Full batch (incl. modality stubs) for an (arch, shape) cell."""
    data = SyntheticLMData(cfg.vocab_size, shape.seq_len, shape.global_batch,
                           seed=seed, num_shards=num_shards, shard=shard)
    batch = dict(data.batch(step))
    B, T = batch["tokens"].shape
    if cfg.family == "encdec":
        u = krng.uniform32(np.uint32(seed + 1),
                           np.arange(B * cfg.source_len * cfg.d_model,
                                     dtype=np.uint32).reshape(
                               B, cfg.source_len, cfg.d_model) % np.uint32(2**24),
                           np.uint32(step), np.uint32(13), np)
        batch["frames"] = (u * 2 - 1).astype(np.float32)
    if cfg.family == "vlm":
        nv = cfg.num_vision_tokens
        u = krng.uniform32(np.uint32(seed + 2),
                           np.arange(B * nv * cfg.d_model,
                                     dtype=np.uint32).reshape(B, nv, cfg.d_model)
                           % np.uint32(2**24),
                           np.uint32(step), np.uint32(17), np)
        batch["vision_embeds"] = (u * 2 - 1).astype(np.float32)
        batch["mrope_positions"] = np.broadcast_to(
            np.arange(T, dtype=np.int32)[None, None, :], (B, 3, T)).copy()
    return batch
