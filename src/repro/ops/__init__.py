"""Operations subsystem: failure injection, warm-start, observability.

Three parts, wired through :class:`repro.core.session.Engine`:

  * :mod:`repro.ops.chaos`   — deterministic fault injection at chunk
    boundaries (device loss, checkpoint corruption, OOM-shaped autotune
    failures) plus the harness the ``chaos`` test tier drives;
  * :mod:`repro.ops.warmup`  — ``Engine.warm(specs)`` precompiles the
    ``(M, A, L, seed) × chunk`` trace set at open so first-request latency
    is deterministic, and ``Engine.readiness()`` reports which static keys
    are warm;
  * :mod:`repro.ops.metrics` — a per-session :class:`MetricsRegistry`
    sampled entirely outside the jitted graph (zero additional traces,
    bitwise-invisible to results).
"""
from repro.ops.chaos import (  # noqa: F401 (re-exported API)
    AutotuneOOM,
    ChaosReport,
    CheckpointCorruption,
    DeviceLoss,
    FaultEvent,
    FaultPlan,
    ServeChaosReport,
    SimulatedCrash,
    TornCheckpointWrite,
    corrupt_checkpoint,
    count_write_ops,
    crash_during_write,
    force_autotune_oom,
    run_plan,
    run_serve_plan,
)
from repro.ops.metrics import MetricsRegistry  # noqa: F401
from repro.ops.warmup import Readiness, readiness, warm  # noqa: F401
