"""Deterministic failure injection at chunk boundaries (the chaos harness).

A :class:`FaultPlan` schedules faults at exact step coordinates and
:func:`run_plan` drives a session through them, recovering after each one
from the last *loadable* checkpoint and replaying the lost steps. Because
the engine's RNG keys on the absolute step coordinate and snapshots are
layout-portable (PR 3), recovery is **bitwise**: every replayed chunk must
equal the chunk originally streamed before the fault, whatever device
topology the session restarts on. The ``chaos`` test tier
(``tests/test_chaos.py``) asserts exactly that for every fault class, on
both single-device and forced-2-device sharded paths.

Fault classes:

  * :class:`DeviceLoss`     — tear the session down and rebuild the engine
    on a different device set (``devices_after=N`` or
    ``lost_device=i`` → a mesh over the survivors via
    ``make_markets_mesh(skip=(i,))``), then restore the last checkpoint
    onto the new topology.
  * :class:`CheckpointCorruption` — damage the newest checkpoint on disk
    (truncate or bit-flip a shard / the manifest) before restarting. The
    restore path must raise a typed
    :class:`~repro.checkpoint.manager.CheckpointCorruptError` — never load
    silently — and the harness falls back down the checkpoint ladder to
    the newest intact step.
  * :class:`AutotuneOOM`    — restart with ``autotune=True`` under
    :func:`force_autotune_oom`, which makes every timed tile candidate
    fail with an OOM-shaped error; the sweep must degrade to the
    conservative heuristic tile (never crash), and results stay bitwise.
  * :class:`TornCheckpointWrite` — crash the process at an exact durable
    write offset *inside* a checkpoint commit (via
    :func:`crash_during_write`, which patches the manager's ``_barrier``
    choke point), then restart. The commit protocol (tmp + fsync + atomic
    rename + terminal ``COMMIT`` marker) must leave either the previous
    committed checkpoint or a skipped uncommitted directory — a torn
    write must **never** restore loadable-but-wrong state. The chaos
    tests sweep every injection offset.

Every fault is injected *between* chunk dispatches — the simulator's only
coherent preemption points (mid-chunk state never exists on the host) —
so plans validate fault coordinates against the chunk length.
"""
from __future__ import annotations

import contextlib
import dataclasses
import zipfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointError, CheckpointManager)
from repro.core.params import EnsembleSpec
from repro.core.session import Engine, StepBatch


@dataclasses.dataclass(frozen=True)
class Fault:
    """Base fault: fires when the session cursor reaches ``at_step``."""

    at_step: int


@dataclasses.dataclass(frozen=True)
class DeviceLoss(Fault):
    """Simulated loss of a device: rebuild on the survivors and restore.

    ``devices_after`` pins the rebuilt mesh width (``devices=N``);
    ``lost_device`` instead names the lost local device index and spans
    every survivor (``make_markets_mesh(skip=(lost_device,))``). With
    neither, the session rebuilds on the engine's original options — a
    plain restart.
    """

    devices_after: Optional[int] = None
    lost_device: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class CheckpointCorruption(Fault):
    """Damage the newest checkpoint before restarting.

    ``kind``:   ``"truncate"`` (keep the first half of the bytes) or
                ``"bitflip"`` (XOR one mid-file byte).
    ``target``: ``"shard"`` (the first shard_*.npz) or ``"manifest"``.
    """

    kind: str = "truncate"
    target: str = "shard"

    def __post_init__(self):
        if self.kind not in ("truncate", "bitflip"):
            raise ValueError(f"unknown corruption kind {self.kind!r}")
        if self.target not in ("shard", "manifest"):
            raise ValueError(f"unknown corruption target {self.target!r}")


@dataclasses.dataclass(frozen=True)
class AutotuneOOM(Fault):
    """Restart with the autotune sweep enabled while every timed candidate
    fails with an OOM-shaped error; the runner must fall back to the
    conservative heuristic tile."""


@dataclasses.dataclass(frozen=True)
class TornCheckpointWrite(Fault):
    """Crash mid-checkpoint-commit at durable-write op ``crash_at_op``,
    then restart and restore.

    The save attempt runs under :func:`crash_during_write`, which raises
    :class:`SimulatedCrash` after the ``crash_at_op``-th barrier inside
    the manager's commit sequence — simulating process death at that
    exact write offset. The restart must restore a committed checkpoint
    (the torn one is skipped by the ``COMMIT``-marker protocol; an
    explicit restore of it raises a typed
    :class:`~repro.checkpoint.manager.CheckpointCorruptError`) and replay
    bitwise. Use ``count_write_ops`` to discover the sweep range.
    """

    crash_at_op: int = 0


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """What actually happened when one fault fired."""

    fault: Fault
    at_step: int
    recovered_from: int          # checkpoint step the session resumed at
    errors: Tuple[str, ...]      # typed errors hit on the way (corruption)
    detail: str = ""             # fault-specific notes (tile choice, mesh)


@dataclasses.dataclass(frozen=True)
class ChaosReport:
    """Result of :func:`run_plan`."""

    batch: StepBatch             # the full recovered [M, n_steps] stream
    state: Tuple[np.ndarray, ...]  # final MarketState, host-side
    events: Tuple[FaultEvent, ...]
    replay_matched: bool         # every replayed chunk == original, bitwise
    checkpoints: Tuple[int, ...]  # intact checkpoint steps at exit


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of faults over one simulated run.

    ``checkpoint_every`` steps (0 disables periodic checkpoints beyond the
    mandatory one at step 0). Fault coordinates and the checkpoint cadence
    must be chunk-boundary-aligned — faults are injected between chunk
    dispatches, the engine's only coherent preemption points.
    """

    faults: Tuple[Fault, ...]
    checkpoint_every: int = 0

    def __init__(self, faults: Sequence[Fault], checkpoint_every: int = 0):
        object.__setattr__(self, "faults",
                           tuple(sorted(faults, key=lambda f: f.at_step)))
        object.__setattr__(self, "checkpoint_every", int(checkpoint_every))

    def validate(self, chunk: int, n_steps: int) -> None:
        if self.checkpoint_every and self.checkpoint_every % chunk:
            raise ValueError(
                f"checkpoint_every={self.checkpoint_every} is not a "
                f"multiple of the chunk length {chunk}: checkpoints are "
                "taken at chunk boundaries")
        for f in self.faults:
            if not (0 < f.at_step <= n_steps):
                raise ValueError(
                    f"fault {f} fires at step {f.at_step}, outside the "
                    f"run's (0, {n_steps}] window")
            if f.at_step % chunk:
                raise ValueError(
                    f"fault {f} fires at step {f.at_step}, which is not a "
                    f"chunk boundary (chunk={chunk}): faults inject at the "
                    "engine's coherent preemption points only")


# ---------------------------------------------------------------------------
# corruption + OOM injectors (used directly by tests as well)
# ---------------------------------------------------------------------------

def corrupt_checkpoint(directory, step: int, kind: str = "truncate",
                       target: str = "shard") -> Path:
    """Damage one file of checkpoint ``step`` in ``directory`` on disk.

    Returns the path that was damaged. ``kind="truncate"`` keeps the first
    half of the file's bytes; ``kind="bitflip"`` XORs one mid-file byte.
    """
    sdir = Path(directory) / f"step_{step:08d}"
    if target == "manifest":
        victim = sdir / "manifest.json"
    else:
        shards = sorted(sdir.glob("shard_*.npz"))
        if not shards:
            raise FileNotFoundError(f"no shards under {sdir}")
        victim = shards[0]
    data = victim.read_bytes()
    if kind == "truncate":
        data = data[:max(1, len(data) // 2)]
    elif kind == "bitflip":
        i = _payload_offset(victim, data)
        data = data[:i] + bytes([data[i] ^ 0xFF]) + data[i + 1:]
    else:
        raise ValueError(f"unknown corruption kind {kind!r}")
    victim.write_bytes(data)
    return victim


def _payload_offset(victim: Path, data: bytes) -> int:
    """A byte offset inside actual *payload* (not container metadata).

    A flip in a zip archive's central directory (timestamps, attributes)
    can be semantically invisible — the member data still reads back
    intact, which is not a corruption at all. Aim at the first member's
    data region instead, so the archive's CRC deterministically trips.
    Non-zip files (the JSON manifest) just take a mid-file byte.
    """
    if victim.suffix == ".npz":
        with zipfile.ZipFile(victim) as z:
            info = z.infolist()[0]
        # local file header: 30 fixed bytes + filename + extra field
        name_len = int.from_bytes(
            data[info.header_offset + 26:info.header_offset + 28], "little")
        extra_len = int.from_bytes(
            data[info.header_offset + 28:info.header_offset + 30], "little")
        start = info.header_offset + 30 + name_len + extra_len
        return min(start + info.compress_size // 2, len(data) - 1)
    return len(data) // 2


class SimulatedCrash(RuntimeError):
    """Stands in for process death at an exact durable-write offset: the
    op that raised it — and everything after — never reached disk order.
    Only :func:`crash_during_write` raises it."""


@contextlib.contextmanager
def crash_during_write(after_ops: Optional[int]):
    """Simulate a process crash inside the checkpoint commit sequence.

    Patches :func:`repro.checkpoint.manager._barrier` — the no-op hook the
    manager calls between every durable sub-operation (open, mid-write,
    pre-fsync, pre-rename, post-rename, per file) — to raise
    :class:`SimulatedCrash` on the ``after_ops``-th call. Everything the
    commit sequence did *before* that barrier is on disk exactly as a real
    crash would leave it (including torn ``.tmp`` files: the mid-write
    barrier fires with half the payload written).

    ``after_ops=None`` is count-only mode: nothing raises, and the yielded
    list's single element ends up holding the total number of barrier ops
    a full commit executes — the sweep range for torn-write enumeration::

        with crash_during_write(None) as ops:
            mgr.save(step, tree)            # sync manager: completes
        for k in range(ops[0]):
            with crash_during_write(k), pytest.raises(SimulatedCrash):
                mgr.save(step2, tree2)
            ...assert restore never loads torn state...
    """
    from repro.checkpoint import manager as ckpt

    counter = [0]
    real = ckpt._barrier

    def crashing_barrier(label: str) -> None:
        if after_ops is not None and counter[0] == after_ops:
            raise SimulatedCrash(
                f"injected crash at durable-write op {after_ops} ({label})")
        counter[0] += 1

    ckpt._barrier = crashing_barrier
    try:
        yield counter
    finally:
        ckpt._barrier = real


def count_write_ops(mgr: CheckpointManager, step: int, tree) -> int:
    """Number of durable-write barrier ops one full commit of ``tree``
    executes (run against a scratch save of ``step``) — the enumeration
    bound for a torn-write sweep."""
    with crash_during_write(None) as ops:
        mgr.save(step, tree)
        mgr.wait()
    return ops[0]


class _FakeOom(RuntimeError):
    """An OOM-shaped failure, as XLA spells device memory exhaustion."""


@contextlib.contextmanager
def force_autotune_oom():
    """Make every autotune tile-candidate timing call fail OOM-shaped.

    Patches ``repro.kernels.autotune.time_call`` for the duration, so any
    sweep started inside the context disqualifies every candidate and must
    fall back to the heuristic tile. The fake error carries XLA's
    RESOURCE_EXHAUSTED/VMEM markers so ``autotune.is_oom_error`` recognises
    it.
    """
    from repro.kernels import autotune as tune

    real = tune.time_call

    def exploding_time_call(fn, block, trials: int = 2) -> float:
        raise _FakeOom(
            "RESOURCE_EXHAUSTED: injected chaos fault: tile candidate "
            "exceeded VMEM while allocating scratch (out of memory)")

    tune.time_call = exploding_time_call
    try:
        yield
    finally:
        tune.time_call = real


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------

def _restore_resilient(session, mgr: CheckpointManager,
                       errors: List[str]) -> int:
    """Restore the newest *loadable* checkpoint, walking the ladder down.

    Typed corruption errors are recorded in ``errors`` (the chaos tests
    assert they were raised — silent loads of damaged data are the bug this
    module exists to catch) and the next-older step is tried.
    """
    for step in sorted(mgr.steps(), reverse=True):
        try:
            return session.restore_checkpoint(mgr, step)
        except CheckpointError as exc:
            errors.append(f"step {step}: {type(exc).__name__}: {exc}")
    raise CheckpointCorruptError(
        "no loadable checkpoint survives in "
        f"{mgr.dir}; errors: {errors}")


def run_plan(plan: FaultPlan, spec, *, backend: str, ckpt_dir,
             chunk_size: int, engine_opts: Optional[Dict[str, Any]] = None,
             n_steps: Optional[int] = None, keep: int = 32) -> ChaosReport:
    """Drive ``spec`` for ``n_steps`` under ``plan``, recovering each fault.

    The harness checkpoints at step 0 and every ``plan.checkpoint_every``
    steps; when a fault fires it injects the failure, rebuilds the
    engine/session (on a different device set for :class:`DeviceLoss`),
    restores the newest loadable checkpoint, and replays the lost chunks.
    Replayed chunks are compared bitwise against the originally streamed
    ones (``ChaosReport.replay_matched``); the returned batch is the
    deduplicated full-horizon stream.
    """
    spec = EnsembleSpec.coerce(spec)
    opts = dict(engine_opts or {})
    steps = int(n_steps if n_steps is not None else spec.num_steps)
    plan.validate(chunk_size, steps)
    mgr = CheckpointManager(ckpt_dir, async_write=False, keep=keep)

    def open_session(engine_opts):
        eng = Engine(backend, chunk_size=chunk_size, **engine_opts)
        return eng, eng.open(spec)

    eng, sess = open_session(opts)
    sess.save_checkpoint(mgr)                 # step 0: the mandatory anchor
    faults = list(plan.faults)
    events: List[FaultEvent] = []
    collected: Dict[int, StepBatch] = {}      # chunk start step -> batch
    replay_matched = True
    t = 0
    while t < steps:
        if faults and faults[0].at_step == t:
            fault = faults.pop(0)
            errors: List[str] = []
            detail = ""
            if isinstance(fault, CheckpointCorruption):
                latest = mgr.latest_step()
                victim = corrupt_checkpoint(mgr.dir, latest, fault.kind,
                                            fault.target)
                detail = f"corrupted {victim.name} of step {latest}"
                sess.close()
                eng, sess = open_session(opts)
            elif isinstance(fault, DeviceLoss):
                sess.close()
                new_opts = dict(opts)
                new_opts.pop("devices", None)
                new_opts.pop("mesh", None)
                if fault.devices_after is not None:
                    new_opts["devices"] = fault.devices_after
                    detail = f"rebuilt on devices={fault.devices_after}"
                elif fault.lost_device is not None:
                    from repro.launch.mesh import make_markets_mesh

                    new_opts["mesh"] = make_markets_mesh(
                        skip=(fault.lost_device,))
                    detail = (f"lost device {fault.lost_device}; mesh over "
                              f"{new_opts['mesh'].devices.size} survivors")
                eng, sess = open_session(new_opts)
            elif isinstance(fault, AutotuneOOM):
                from repro.kernels import autotune as tune

                sess.close()
                tune.clear_tune_cache()
                with force_autotune_oom():
                    eng, sess = open_session({**opts, "autotune": True})
                report = tune.last_sweep_report()
                if report is not None:
                    detail = (f"sweep fell_back={report.fell_back} "
                              f"winner={report.winner} "
                              f"failures={len(report.failures)}")
                    errors.extend(report.failures)
            elif isinstance(fault, TornCheckpointWrite):
                # A checkpoint save at this boundary dies mid-commit at the
                # requested durable-write offset; the "process" restarts
                # and must restore a committed checkpoint — never the torn
                # one (the ladder skips it; loading it explicitly raises).
                try:
                    with crash_during_write(fault.crash_at_op):
                        sess.save_checkpoint(mgr)
                except SimulatedCrash as exc:
                    errors.append(f"SimulatedCrash: {exc}")
                detail = (f"crashed at durable-write op "
                          f"{fault.crash_at_op} during save at step {t}")
                sess.close()
                eng, sess = open_session(opts)
            else:
                raise TypeError(f"unknown fault class {type(fault).__name__}")
            recovered = _restore_resilient(sess, mgr, errors)
            events.append(FaultEvent(fault=fault, at_step=t,
                                     recovered_from=recovered,
                                     errors=tuple(errors), detail=detail))
            t = recovered
            continue
        n = min(chunk_size, steps - t)
        batch = sess.run(n).to_numpy()
        prev = collected.get(t)
        if prev is not None:       # replaying steps lost to a fault
            for field, a, b in zip(batch._fields, prev, batch):
                if not (np.asarray(a) == np.asarray(b)).all():
                    replay_matched = False
        collected[t] = batch
        t += n
        if (plan.checkpoint_every and t < steps
                and t % plan.checkpoint_every == 0):
            sess.save_checkpoint(mgr)
    full = StepBatch.concatenate(
        [collected[k] for k in sorted(collected)], xp=np)
    state = tuple(np.asarray(x) for x in sess.state)
    sess.close()
    return ChaosReport(batch=full, state=state, events=tuple(events),
                       replay_matched=replay_matched,
                       checkpoints=tuple(mgr.steps()))


# ---- serving-gateway chaos (faults under concurrent client load) ----

@dataclasses.dataclass(frozen=True)
class ServeChaosReport:
    """Outcome of :func:`run_serve_plan`: per-client streams + recovery.

    ``frames``/``events`` are keyed by client id in attach order. Compare
    two reports' frames bitwise (fault-free vs faulted run of the same
    scenario mixture) to prove recovery resumed every client's trajectory
    exactly; ``reconnects`` counts the ``reconnect`` control events each
    surviving client observed (all clients see every recovery).
    ``traces_delta`` is the gateway's post-(re)warm trace delta — 0 means
    no client request ever paid a compile, before or after the fault.
    """

    frames: Dict[str, Tuple[Any, ...]]
    events: Dict[str, Tuple[Any, ...]]
    reconnects: int
    traces_delta: int
    steps: int
    recoveries: int = 0          # supervised recovery passes that succeeded
    health: Optional[Dict[str, Any]] = None   # gateway health pre-shutdown

    def client_paths(self, client: str) -> Tuple[np.ndarray, np.ndarray]:
        """(mid, price) concatenated over the client's frames."""
        fs = self.frames[client]
        return (np.concatenate([f.mid for f in fs]),
                np.concatenate([f.price for f in fs]))


def run_serve_plan(scenarios: Sequence[str], *, backend: str, ckpt_dir,
                   chunk_size: int = 8, chunks: int = 12,
                   checkpoint_every: int = 2, slots: Optional[int] = None,
                   fault: Union[Fault, Sequence[Fault], None] = None,
                   fault_after: int = 2,
                   late_attach: Optional[str] = None, late_after: int = 4,
                   num_agents: int = 16, num_levels: int = 32,
                   ckpt_keep: int = 64,
                   engine_opts: Optional[Dict[str, Any]] = None,
                   ) -> ServeChaosReport:
    """Drive a serving gateway under concurrent client load, with a fault.

    One client session opens per entry of ``scenarios`` (preset names)
    before the first chunk; ``late_attach`` optionally adds one more after
    ``late_after`` chunks — *after* a checkpoint, so recovery must replay
    the attach from the gateway's durable splice journal. ``fault``
    (typically :class:`DeviceLoss`) is injected at the chunk boundary
    after the first client has received ``fault_after`` frames; recovery
    restores the newest checkpoint and replays quietly, and every client
    sees a ``reconnect`` event while its stream continues bitwise. A
    *sequence* of faults is injected back-to-back — a fault storm — and
    must coalesce into ONE supervised recovery pass (one ``reconnect``
    broadcast; ``ServeChaosReport.recoveries == 1``).

    ``ckpt_keep`` bounds the gateway's checkpoint ladder, so a small value
    under a long run forces GC + splice-journal compaction mid-flight (the
    compaction-never-breaks-replay test rides on this).

    Per-client queues are sized to hold the whole run (``chunks`` deep) so
    this harness measures recovery fidelity, not backpressure — the
    backpressure tier lives in ``tests/test_serve.py``.
    """
    import asyncio

    from repro.serve import Gateway, parked_template

    n_clients = len(scenarios) + (1 if late_attach else 0)
    tpl = parked_template(
        slots=n_clients if slots is None else slots, num_agents=num_agents,
        num_levels=num_levels, num_steps=max(4096, chunks * chunk_size))
    faults = ([] if fault is None
              else list(fault) if isinstance(fault, (list, tuple))
              else [fault])

    async def drive():
        gw = Gateway(tpl, backend=backend, chunk_size=chunk_size,
                     queue_maxsize=chunks + 4,
                     ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every,
                     ckpt_keep=ckpt_keep, engine_opts=engine_opts)
        await gw.start(chunks=chunks)
        clients = [gw.open_session(s, client=f"c{i}")
                   for i, s in enumerate(scenarios)]
        collected = [list(await clients[0].frames(fault_after))]
        collected += [[] for _ in clients[1:]]
        if late_attach is not None:
            while len(collected[0]) < late_after:
                collected[0].append(await clients[0].next_frame())
            clients.append(gw.open_session(late_attach, client="late"))
            collected.append([])
        for f in faults:     # back-to-back: the loop must coalesce these
            gw.inject_fault(f)
        rest = await asyncio.gather(
            *(cs.frames(chunks) for cs in clients))
        for got, more in zip(collected, rest):
            got.extend(more)
        health = gw.health()
        recoveries = 0
        if gw.metrics is not None:
            recoveries = int(gw.metrics.counter("recoveries_total"))
        await gw.stop()
        return gw, clients, collected, health, recoveries

    gw, clients, collected, health, recoveries = asyncio.run(drive())
    events = {cs.client: tuple(cs.events) for cs in clients}
    return ServeChaosReport(
        frames={cs.client: tuple(fs)
                for cs, fs in zip(clients, collected)},
        events=events,
        reconnects=sum(1 for e in events[clients[0].client]
                       if e.kind == "reconnect"),
        traces_delta=gw.traces_delta,
        steps=gw.step_count,
        recoveries=recoveries,
        health=health)
