"""Zero-hot-path metrics surface: per-session counters, gauges and timings.

A :class:`MetricsRegistry` is attached to every session ``Engine.open``
creates (disable with ``Engine(backend, metrics=False)`` or per-session
``open(spec, metrics=False)``). Everything it records is sampled on the
*host*, strictly outside the jitted graph:

  * no value ever becomes an operand of a compiled executable, so metrics
    collection causes **zero additional traces** and results stay
    bitwise-identical to a metrics-off session (asserted by the tier-1
    test ``tests/test_ops.py::test_metrics_zero_traces_and_bitwise``);
  * chunk/step timings are dispatch wall-times around the existing host
    call sites (no ``block_until_ready`` is inserted — blocking would
    perturb the very latency being observed);
  * the retrace counter samples the runner's Python-side trace counter
    before/after each dispatch — two integer reads per chunk.

Recorded by the session wiring (see :class:`repro.core.session.Session`):

  counters  ``steps_total``, ``chunks_total``, ``traces`` (retrace counter:
            0 on a warm engine), ``snapshots_total``, ``restores_total``
  timings   ``chunk_seconds``, ``step_seconds``, ``snapshot_seconds``,
            ``restore_seconds``  (count/total/min/max aggregates)
  gauges    ``chunk``, ``num_markets``, and on the Pallas engines the
            autotune tile pressure: ``autotune_vmem_bytes``, ``tile_mb``,
            ``tile_agent_chunk``

The registry is generic — any consumer may ``inc``/``observe``/``gauge``
additional series (the serving gateway will add queue depths here).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional


class Aggregate:
    """count/total/min/max running aggregate of host-side observations."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def add(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def summary(self) -> Dict[str, float]:
        mean = self.total / self.count if self.count else 0.0
        return {"count": self.count, "total": self.total, "mean": mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0}


class MetricsRegistry:
    """Per-session metrics: counters, gauges, timing aggregates.

    Thread-safe (one lock around the tiny dict updates) so a streaming
    consumer thread may read :meth:`snapshot` while the session advances.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, Any] = {}
        self._timings: Dict[str, Aggregate] = {}

    # ---- write side (host-only; never called from inside a trace) ----
    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: Any) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            agg = self._timings.get(name)
            if agg is None:
                agg = self._timings[name] = Aggregate()
            agg.add(value)

    # ---- read side ----
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0)

    def steps_per_s(self) -> float:
        """Derived throughput: steps dispatched per second of chunk wall
        time (dispatch-side; see module docstring for the async caveat)."""
        with self._lock:
            steps = self._counters.get("steps_total", 0)
            agg = self._timings.get("chunk_seconds")
            secs = agg.total if agg is not None else 0.0
        return steps / secs if secs > 0 else 0.0

    def snapshot(self) -> Dict[str, Any]:
        """Plain-python view: {'counters', 'gauges', 'timings', 'derived'}."""
        with self._lock:
            out = {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "timings": {k: v.summary() for k, v in self._timings.items()},
            }
        out["derived"] = {"steps_per_s": self.steps_per_s()}
        return out
