"""Zero-hot-path metrics surface: per-session counters, gauges and timings.

A :class:`MetricsRegistry` is attached to every session ``Engine.open``
creates (disable with ``Engine(backend, metrics=False)`` or per-session
``open(spec, metrics=False)``). Everything it records is sampled on the
*host*, strictly outside the jitted graph:

  * no value ever becomes an operand of a compiled executable, so metrics
    collection causes **zero additional traces** and results stay
    bitwise-identical to a metrics-off session (asserted by the tier-1
    test ``tests/test_ops.py::test_metrics_zero_traces_and_bitwise``);
  * chunk/step timings are dispatch wall-times around the existing host
    call sites (no ``block_until_ready`` is inserted — blocking would
    perturb the very latency being observed);
  * the retrace counter samples the runner's Python-side trace counter
    before/after each dispatch — two integer reads per chunk.

Recorded by the session wiring (see :class:`repro.core.session.Session`):

  counters  ``steps_total``, ``chunks_total``, ``traces`` (retrace counter:
            0 on a warm engine), ``snapshots_total``, ``restores_total``
  timings   ``chunk_seconds``, ``step_seconds``, ``snapshot_seconds``,
            ``restore_seconds``  (count/total/min/max aggregates)
  gauges    ``chunk``, ``num_markets``, and on the Pallas engines the
            autotune tile pressure: ``autotune_vmem_bytes``, ``tile_mb``,
            ``tile_agent_chunk``

The registry is generic — any consumer may ``inc``/``observe``/``gauge``
additional series. The serving gateway (:mod:`repro.serve`) records:

  counters  ``frames_published_total``, ``frames_dropped_total``,
            ``sessions_opened_total``, ``sessions_closed_total``,
            ``reconnects_total``, ``swaps_total`` (slot attach/detach rows)
  gauges    ``queue_depth.<client>`` per-client fan-out queue depths,
            ``clients_connected``, ``slots_attached``
  windows   ``chunk_latency_seconds`` — a bounded-window
            :class:`QuantileWindow` whose p50/p99 feed ``BENCH_serve.json``

Durability + fault-storm series (PR 8; all host-side, zero hot-path):

  counters  ``checkpoints_saved_total`` (committed by the async writer),
            ``journal_entries_total`` (splices journaled),
            ``journal_compactions_total`` /
            ``journal_entries_compacted_total`` (GC-driven compaction),
            ``recoveries_total`` (successful supervised recovery passes),
            ``recovery_attempts_total`` (including retried failures),
            ``faults_coalesced_total`` (extra faults folded into one pass)
  gauges    ``checkpoint_writer_pending`` (snapshots not yet committed,
            0–2 by the lag bound), ``checkpoints_skipped`` (saves dropped
            by the latest-wins mailbox), ``degraded`` (0/1)
  windows   ``checkpoint_snapshot_seconds`` — the engine-thread cost of a
            checkpoint (device→host mirror ONLY; `BENCH_serve.json` fails
            hard when its max stalls past threshold), and
            ``checkpoint_write_seconds`` — the background writer's
            serialize+fsync+commit latency (never on the engine thread)
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, List, Optional


class Aggregate:
    """count/total/min/max running aggregate of host-side observations."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def add(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def summary(self) -> Dict[str, float]:
        mean = self.total / self.count if self.count else 0.0
        return {"count": self.count, "total": self.total, "mean": mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0}


class QuantileWindow:
    """Bounded sliding window of the last ``size`` observations with exact
    percentile reads — the latency-summary shape a serving layer needs
    (p50/p99 over *recent* traffic, not a run-lifetime mean).

    A ring buffer holds arrival order while a parallel sorted list supports
    O(log n) insert/remove, so :meth:`percentile` is an O(1) index into the
    sorted view. Memory is O(size) however long the gateway runs; ``size``
    defaults to 1024 observations.
    """

    __slots__ = ("size", "count", "_ring", "_next", "_sorted")

    def __init__(self, size: int = 1024) -> None:
        if size < 1:
            raise ValueError(f"window size must be >= 1, got {size}")
        self.size = int(size)
        self.count = 0            # lifetime observations (window may be full)
        self._ring: List[float] = []
        self._next = 0            # ring slot the next add overwrites
        self._sorted: List[float] = []

    def add(self, value: float) -> None:
        value = float(value)
        if len(self._ring) < self.size:
            self._ring.append(value)
        else:
            evicted = self._ring[self._next]
            self._sorted.pop(bisect.bisect_left(self._sorted, evicted))
            self._ring[self._next] = value
        self._next = (self._next + 1) % self.size
        bisect.insort(self._sorted, value)
        self.count += 1

    def __len__(self) -> int:
        return len(self._sorted)

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile of the current window (q in
        [0, 100]); 0.0 on an empty window."""
        n = len(self._sorted)
        if not n:
            return 0.0
        rank = min(n - 1, max(0, int(round(q / 100.0 * (n - 1)))))
        return self._sorted[rank]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "window": len(self._sorted),
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99),
                "min": self._sorted[0] if self._sorted else 0.0,
                "max": self._sorted[-1] if self._sorted else 0.0}


class MetricsRegistry:
    """Per-session metrics: counters, gauges, timing aggregates, and
    bounded-window quantile summaries.

    Thread-safe (one lock around the tiny dict updates) so a streaming
    consumer thread may read :meth:`snapshot` while the session advances.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, Any] = {}
        self._timings: Dict[str, Aggregate] = {}
        self._windows: Dict[str, QuantileWindow] = {}

    # ---- write side (host-only; never called from inside a trace) ----
    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: Any) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            agg = self._timings.get(name)
            if agg is None:
                agg = self._timings[name] = Aggregate()
            agg.add(value)

    def observe_window(self, name: str, value: float,
                       size: int = 1024) -> None:
        """Record into a bounded :class:`QuantileWindow` series (created on
        first use with ``size``; later calls ignore ``size``)."""
        with self._lock:
            win = self._windows.get(name)
            if win is None:
                win = self._windows[name] = QuantileWindow(size)
            win.add(value)

    # ---- read side ----
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0)

    def gauge_value(self, name: str, default: Any = None) -> Any:
        with self._lock:
            return self._gauges.get(name, default)

    def window(self, name: str) -> Optional[QuantileWindow]:
        with self._lock:
            return self._windows.get(name)

    def steps_per_s(self) -> float:
        """Derived throughput: steps dispatched per second of chunk wall
        time (dispatch-side; see module docstring for the async caveat)."""
        with self._lock:
            steps = self._counters.get("steps_total", 0)
            agg = self._timings.get("chunk_seconds")
            secs = agg.total if agg is not None else 0.0
        return steps / secs if secs > 0 else 0.0

    def snapshot(self) -> Dict[str, Any]:
        """Plain-python view: {'counters', 'gauges', 'timings', 'windows',
        'derived'}."""
        with self._lock:
            out = {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "timings": {k: v.summary() for k, v in self._timings.items()},
                "windows": {k: v.summary() for k, v in self._windows.items()},
            }
        out["derived"] = {"steps_per_s": self.steps_per_s()}
        return out
