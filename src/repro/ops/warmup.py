"""Warm-start controller: precompile the trace set at engine open.

The engine's compile-once guarantee makes *steady-state* latency
deterministic, but the first request after process start still pays the
full trace+compile cost. ``Engine.warm(specs)`` (delegating here) runs one
throwaway chunk call per ``(static_key, chunk)`` entry so every executable
a session will need — the streaming chunk and, optionally, the
single-step RL/gym executable — is compiled before the first request:

    eng = Engine("pallas-kinetic")
    eng.warm([spec])              # compiles (M, A, L, seed) x chunk now
    eng.readiness().ready         # -> True
    with eng.open(spec) as s:     # first request: ZERO new traces
        s.run(...)

``readiness()`` is the probe: it reports which static keys are warm
(host-loop backends compile nothing and are always ready), the shape a
serving layer needs for its readiness endpoint.
"""
from __future__ import annotations

from typing import Any, Iterable, NamedTuple, Optional, Sequence, Tuple, Union


class KeyReadiness(NamedTuple):
    """Warm/cold status of one cached executable."""

    static_key: Tuple[Any, ...]   # EnsembleSpec.static_key(): (M, A, L, seed)
    chunk: int
    warm: bool                    # compiled (or nothing to compile)
    traces: int                   # times this executable has been traced


class Readiness(NamedTuple):
    """Aggregate probe result: ``ready`` iff every known key is warm.

    An engine with no cached runners reports ``ready=True`` vacuously —
    probe *after* :func:`warm` (or after opening the serving specs) for a
    meaningful answer.
    """

    ready: bool
    entries: Tuple[KeyReadiness, ...]

    def warm_keys(self) -> Tuple[Tuple[Any, ...], ...]:
        return tuple(e.static_key + (e.chunk,) for e in self.entries if e.warm)

    def cold_keys(self) -> Tuple[Tuple[Any, ...], ...]:
        return tuple(e.static_key + (e.chunk,)
                     for e in self.entries if not e.warm)


def _warm_runner(runner, spec) -> None:
    """Force the runner's executable to compile with one throwaway call.

    The call uses fresh state/params buffers (discarded afterwards — the
    chunk executable donates them), so warming never touches any live
    session. Host-loop runners compile nothing and return immediately; an
    already-traced runner is left alone.
    """
    if not runner.compiled or runner.trace_count > 0:
        return
    state = runner.init_state(spec)
    params = runner.params_to_device(spec.params)
    aux = runner.init_aux(spec)
    stats = runner.init_stats(spec)
    runner.run(state, params, aux, 0, runner.chunk, None, stats)


def warm(engine, specs: Union[Any, Sequence[Any]], *,
         chunk_sizes: Optional[Iterable[int]] = None,
         include_step: bool = True) -> Readiness:
    """Precompile every ``(static_key, chunk)`` executable for ``specs``.

    ``specs`` is one spec/config or a sequence of them. For each, the
    engine's default chunk resolution is warmed, plus the ``chunk=1``
    single-step executable :meth:`Session.step` uses (``include_step``),
    plus any explicit ``chunk_sizes``. Returns the post-warm
    :func:`readiness` probe, so ``engine.warm(specs).ready`` is the
    one-liner a serving layer gates traffic on.
    """
    from repro.core.params import EnsembleSpec
    from repro.core.session import DEFAULT_CHUNK

    if not isinstance(specs, (list, tuple)):
        specs = [specs]
    for spec in specs:
        spec = EnsembleSpec.coerce(spec)
        chunks = {engine.chunk_size or min(DEFAULT_CHUNK, spec.num_steps)}
        if include_step:
            chunks.add(1)
        for c in chunk_sizes or ():
            chunks.add(int(c))
        for c in sorted(chunks):
            _warm_runner(engine._runner(spec, max(1, c)), spec)
    return readiness(engine)


def readiness(engine) -> Readiness:
    """Probe which of the engine's cached executables are warm.

    A key is warm when its runner has nothing to compile (host-loop
    backends) or has been traced at least once (the compile is cached).
    """
    entries = []
    for key, runner in engine._runners.items():
        entries.append(KeyReadiness(
            static_key=key[:-1], chunk=key[-1],
            warm=(not runner.compiled) or runner.trace_count > 0,
            traces=runner.trace_count))
    return Readiness(ready=all(e.warm for e in entries),
                     entries=tuple(entries))
