"""Pure-functional vectorized RL environment over the persistent engine.

The Session API (``repro.core.session``) is stateful: ``Session.step``
crosses the host boundary every step, so a policy-in-the-loop rollout pays
a device round-trip per step — exactly the launch-per-step regime the
paper's persistent engine eliminates for the simulator itself. This module
is the RL front door to the persistent regime: a gymnax-style
pure-functional environment whose entire rollout — policy included —
compiles to **one** device computation:

    env = Engine("pallas-kinetic").env(spec)
    state, obs = env.reset()
    state, obs, reward, done, info = env.step(state, actions)
    final, traj = rollout(env, policy_fn, n_steps)   # one lax.scan, one trace

Design:

  * :class:`EnvState` is a pytree wrapping the engine's ``MarketState`` /
    ``MarketParams`` (+ portfolio accounting, step cursor, optional
    ``MarketStats`` accumulators and runtime-seed/aux leaves), so
    ``MarketEnv.step`` is a pure ``(state, actions) -> (state, obs, reward,
    done, info)`` function compatible with ``jax.jit`` / ``jax.vmap`` /
    ``jax.lax.scan``.
  * The step core is each backend's :meth:`ChunkRunner.env_step_fn` — the
    *same* ``simulate_step`` entry the Session's chunked run/stream path
    compiles — so the two APIs cannot drift: a zero-action env trajectory
    is bitwise-identical to ``Session.run`` on every backend, and on the
    Pallas engines the env composes with ``devices=``/``mesh=`` sharding.
  * Actions are per-market external limit orders lowered onto the reserved
    ``ext_buy``/``ext_ask`` incoming-flow slot (:mod:`repro.env.actions`);
    ``actions=None`` injects exact zeros — a bitwise no-op.
  * Observations and rewards are pluggable frozen specs
    (:mod:`repro.env.obs` / :mod:`repro.env.rewards`).
  * ``done`` fires when the episode cursor reaches the horizon
    (``spec.num_steps`` by default); with ``auto_reset=True`` the state is
    re-seeded **in-graph** (branch-free ``where`` selects) from the
    ensemble's per-market opening books, which ride in ``EnvState`` as the
    ``reset_market`` operand — so one compiled rollout serves any scenario
    mixture, auto-resets included.
  * Jitted step/rollout executables are cached on the :class:`Engine`
    under the shape-semantic ``EnsembleSpec.static_key()`` — training
    against a different scenario mixture of the same shape reuses every
    warm trace (``Engine.trace_count`` stays flat).

Episodes are deterministic replays of the configured scenario: the counter
RNG keys on the in-episode step, so an auto-reset episode re-fires its
scenario events (a flash-crash shocks every episode) and two episodes
differ only through the policy's actions. Vary randomness across parallel
rollouts by vmapping over the runtime ``seed`` operand of :meth:`reset`
(counter-RNG jax backends).
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.core import auction
from repro.core import stats as stats_mod
from repro.core.config import MarketConfig
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.session import Engine
from repro.core.stats import MarketStats
from repro.core.step import MarketState, StepOutput
from repro.env import actions as actions_mod
from repro.env.obs import MarketFeatures, ObservationSpec
from repro.env.rewards import PnLReward, RewardContext, RewardFn


class Portfolio(NamedTuple):
    """Per-market accounting for the external-order agent; f32[M, 1] each."""

    cash: Any       # cumulative signed fill cash flows
    inventory: Any  # net lots held (buys - sells)
    equity: Any     # cash + inventory * mid (mark-to-market)


class EnvState(NamedTuple):
    """The full environment state as a pytree (jit/vmap/scan carrier).

    ``last_out`` is the :class:`StepOutput` that produced ``market`` (a
    synthetic zero-volume output at reset), kept so observations are a pure
    function of the state. ``reset_market`` carries the ensemble's
    per-market opening books as runtime operands — the in-graph auto-reset
    target — so one compiled step serves any scenario mixture. ``seed`` is
    ``None`` (trace-static RNG seed) or a uint32 scalar override; ``aux``
    is the stateful host RNG of the ``numpy-pcg64`` reference (``None``
    for every counter-RNG backend).
    """

    market: MarketState
    last_out: StepOutput
    reset_market: MarketState
    params: MarketParams
    t: Any                      # int32 scalar — step cursor in the episode
    portfolio: Portfolio
    stats: Optional[MarketStats]
    seed: Any
    aux: Any


class StepInfo(NamedTuple):
    """Diagnostics for one transition (pre-auto-reset values)."""

    price: Any     # f32[M, 1] clearing price (last price when no cross)
    volume: Any    # f32[M, 1] total transacted volume
    mid: Any       # f32[M, 1] pre-clearing mid
    fill_buy: Any  # f32[M, 1] external buy lots filled
    fill_ask: Any  # f32[M, 1] external sell lots filled


class RolloutBatch(NamedTuple):
    """Stacked per-step outputs of a :func:`rollout` — a transitions pytree.

    ``obs``/``reward``/``done`` plus the per-step ``extras`` returned by a
    carried policy (see :func:`rollout`'s ``policy_carry``) are everything
    an advantage estimator needs: ``repro.train`` computes GAE directly on
    this batch. ``extras`` is ``None`` for stateless policies.
    """

    obs: Any       # f32[S, M, D]
    reward: Any    # f32[S, M]
    done: Any      # bool[S]
    price: Any     # f32[M, S] — StepBatch-layout paths (bitwise-comparable
    volume: Any    # f32[M, S]   to Session.run on every backend)
    mid: Any       # f32[M, S]
    fill_buy: Any  # f32[M, S]
    fill_ask: Any  # f32[M, S]
    extras: Any = None  # pytree of [S, ...] leaves stacked from the policy

    @property
    def num_steps(self) -> int:
        return int(self.reward.shape[0])

    def to_numpy(self) -> "RolloutBatch":
        fixed = (np.asarray(x) for x in self[:8])
        extras = self.extras
        if extras is not None:
            import jax

            extras = jax.tree_util.tree_map(np.asarray, extras)
        return RolloutBatch(*fixed, extras=extras)


class MarketEnv:
    """Gymnax-style pure-functional environment (see module docstring).

    Obtain one from :meth:`Engine.env` (preferred — shares the engine's
    executable caches) or construct directly with a backend name. The env
    object itself is immutable configuration; all mutable simulation state
    lives in the :class:`EnvState` values returned by :meth:`reset` /
    :meth:`step`.
    """

    def __init__(self, spec: Union[EnsembleSpec, MarketConfig],
                 backend: str = "jax-scan", *,
                 obs: Optional[ObservationSpec] = None,
                 reward: Optional[RewardFn] = None,
                 horizon: Optional[int] = None,
                 auto_reset: bool = True,
                 engine: Optional[Engine] = None,
                 **backend_opts: Any):
        if engine is not None and backend_opts:
            raise ValueError(
                "pass backend options to the Engine when engine= is given")
        self.spec = EnsembleSpec.coerce(spec)
        self._engine = engine if engine is not None \
            else Engine(backend, **backend_opts)
        self._runner = self._engine._runner(self.spec, 1)
        if self._runner.stats_only:
            raise ValueError(
                "stats_only engines have no per-step outputs to observe; "
                "open the env on a default engine (StatsFeatures carries "
                "its own in-graph accumulators)")
        self._step_core = self._runner.env_step_fn()
        if self._step_core is None:
            raise ValueError(
                f"backend {self._engine.backend!r} exposes no functional "
                "env step core")
        self.obs_spec = obs if obs is not None else MarketFeatures()
        self.reward_fn = reward if reward is not None else PnLReward()
        self.horizon = int(horizon) if horizon is not None \
            else self.spec.num_steps
        if self.horizon <= 0:
            raise ValueError(f"horizon must be >= 1, got {self.horizon}")
        self.auto_reset = bool(auto_reset)
        self._traceable = self._runner.env_traceable
        # Engine-level executable cache, keyed shape-semantically: envs on
        # different scenario mixtures of one shape share every warm trace.
        key = (self.spec.static_key(), self.obs_spec, self.reward_fn,
               self.horizon, self.auto_reset)
        self._cache = self._engine._env_traces.setdefault(key, {})
        xp = self._runner.xp
        M, L = self.spec.num_markets, self.spec.num_levels
        self._zero_ext = (xp.zeros((M, L), xp.float32),
                          xp.zeros((M, L), xp.float32))

    # ---- introspection ----
    @property
    def backend(self) -> str:
        return self._engine.backend

    @property
    def engine(self) -> Engine:
        return self._engine

    @property
    def num_markets(self) -> int:
        return self.spec.num_markets

    def obs_size(self) -> int:
        """Feature dimension D of the observation block."""
        return self.obs_spec.size(self.spec)

    # ---- functional API ----
    def reset(self, seed: Any = None) -> Tuple[EnvState, Any]:
        """Fresh :class:`EnvState` + opening observation.

        ``seed`` optionally overrides the RNG seed at *runtime* (scalar,
        traced ok — ``jax.vmap(env.reset)(seeds)`` batches whole rollouts
        over seeds in one trace). Runtime seeds require a counter-RNG
        backend whose step core takes the seed as an operand
        (``env_runtime_seed``); the Pallas kernels bake the seed into the
        trace and the PCG64 reference derives its stream at init, so both
        reject an override with a clear error — open the env on a spec
        carrying the desired seed instead. ``seed=None`` (or a concrete
        value equal to ``spec.seed``) is bitwise-identical to the baked
        stream.
        """
        runner, xp = self._runner, self._runner.xp
        if seed is not None and not runner.env_runtime_seed:
            raise ValueError(
                f"backend {self._engine.backend!r} compiles the RNG seed "
                "into its executable; open the env on a spec with "
                f"seed={seed} instead of passing a runtime override")
        market = runner.init_state(self.spec)
        reset_market = runner.init_state(self.spec)
        params = runner.params_to_device(self.spec.params)
        M = self.spec.num_markets
        zeros = xp.zeros((M, 1), xp.float32)
        portfolio = Portfolio(cash=zeros, inventory=zeros, equity=zeros)
        stats = (stats_mod.init_stats(M, xp)
                 if self.obs_spec.needs_stats else None)
        seed_leaf = None if seed is None \
            else xp.asarray(seed).astype(xp.uint32)
        state = EnvState(
            market=market, last_out=self._reset_out(market, xp),
            reset_market=reset_market, params=params, t=xp.int32(0),
            portfolio=portfolio, stats=stats, seed=seed_leaf,
            aux=runner.init_aux(self.spec))
        return state, self.observe(state)

    def observe(self, state: EnvState) -> Any:
        """float32[M, D] observation of ``state`` (pure; traced ok)."""
        return self.obs_spec.observe(self.spec, state.market, state.last_out,
                                     state.portfolio, state.stats,
                                     self._runner.xp)

    def step(self, state: EnvState, actions: Any = None,
             ) -> Tuple[EnvState, Any, Any, Any, StepInfo]:
        """Advance one step: ``(state, obs, reward, done, info)``.

        ``actions`` is an :class:`repro.core.session.ExternalOrders` (or
        triple / mapping — one external limit order per market), validated
        eagerly; ``None`` advances the markets untouched, bitwise-identical
        to :meth:`Session.run`. On traceable backends the transition runs
        as one cached jitted executable (shared engine-wide per static
        shape), and the method itself embeds in user jit/vmap transforms.
        """
        eb, ea = self._lower(actions)
        if self._traceable:
            return self._jitted_step()(state, eb, ea)
        return self._step_impl(state, eb, ea)

    # ---- internals ----
    def _lower(self, actions: Any) -> Tuple[Any, Any]:
        if actions is None:
            return self._zero_ext
        M, L = self.spec.num_markets, self.spec.num_levels
        orders = actions_mod.validate_actions(actions, M, L)
        return actions_mod.lower_actions(orders, M, L, self._runner.xp)

    def _reset_out(self, market: MarketState, xp) -> StepOutput:
        """Synthetic zero-volume output describing a freshly reset state."""
        _, _, mid = auction.best_quotes(market.bid, market.ask,
                                        market.last_price, xp)
        return StepOutput(price=xp.asarray(market.last_price, xp.float32),
                          volume=xp.zeros_like(mid), mid=mid)

    def _jitted_step(self) -> Callable:
        fn = self._cache.get("step")
        if fn is None:
            import jax

            runner = self._runner

            def counted(state, eb, ea):
                runner._trace_count += 1  # python side effect: trace-time
                return self._step_impl(state, eb, ea)

            fn = self._cache["step"] = jax.jit(counted)
        return fn

    def _step_impl(self, state: EnvState, eb: Any, ea: Any,
                   ) -> Tuple[EnvState, Any, Any, Any, StepInfo]:
        """The pure transition (shared by eager, jit, and scan paths)."""
        xp = self._runner.xp
        f32 = xp.float32
        market, out, aux = self._step_core(
            state.market, state.params, state.t, eb, ea, state.seed,
            state.aux)

        # Fill attribution (price-priority, no rationing — rewards.py).
        executed = xp.asarray(out.volume, f32) > f32(0.0)          # [M, 1]
        pstar = xp.asarray(out.price, f32)                         # [M, 1]
        levels = xp.arange(self.spec.num_levels, dtype=f32)[None, :]
        zero = f32(0.0)
        fill_buy = xp.where(
            executed,
            xp.sum(xp.where(levels >= pstar, eb, zero), axis=-1,
                   keepdims=True),
            xp.zeros_like(pstar))
        fill_ask = xp.where(
            executed,
            xp.sum(xp.where(levels <= pstar, ea, zero), axis=-1,
                   keepdims=True),
            xp.zeros_like(pstar))

        prev = state.portfolio
        cash = prev.cash - fill_buy * pstar + fill_ask * pstar
        inventory = prev.inventory + fill_buy - fill_ask
        equity = cash + inventory * xp.asarray(out.mid, f32)
        portfolio = Portfolio(cash=cash, inventory=inventory, equity=equity)
        reward = self.reward_fn(RewardContext(
            fill_buy=fill_buy, fill_ask=fill_ask, fill_price=pstar, out=out,
            prev=prev, portfolio=portfolio, xp=xp))

        stats = state.stats
        if stats is not None:
            stats = stats_mod.accumulate(stats, out.mid, out.volume, True, xp)

        t_next = xp.asarray(state.t).astype(xp.int32) + xp.int32(1)
        done = t_next >= xp.int32(self.horizon)
        info = StepInfo(price=out.price, volume=out.volume, mid=out.mid,
                        fill_buy=fill_buy, fill_ask=fill_ask)

        out_for_obs = out
        if self.auto_reset:
            # Branch-free in-graph episode reset from the carried opening
            # books: one trace serves done and not-done steps alike.
            market = MarketState(*(xp.where(done, r, c) for r, c
                                   in zip(state.reset_market, market)))
            portfolio = Portfolio(*(xp.where(done, xp.zeros_like(c), c)
                                    for c in portfolio))
            if stats is not None:
                fresh = stats_mod.init_stats(self.spec.num_markets, xp)
                stats = MarketStats(*(xp.where(done, r, c) for r, c
                                      in zip(fresh, stats)))
            reset_out = self._reset_out(state.reset_market, xp)
            out_for_obs = StepOutput(*(xp.where(done, r, c) for r, c
                                       in zip(reset_out, out)))
            t_next = xp.where(done, xp.int32(0), t_next)

        new_state = EnvState(
            market=market, last_out=out_for_obs,
            reset_market=state.reset_market, params=state.params, t=t_next,
            portfolio=portfolio, stats=stats, seed=state.seed, aux=aux)
        obs = self.observe(new_state)
        return new_state, obs, reward, done, info

    # ---- snapshot / checkpoint ----
    def snapshot(self, state: EnvState) -> Dict[str, Any]:
        """Exact host-side capture of an :class:`EnvState` (see
        :meth:`restore`); wire format shared with ``CheckpointManager``
        through :func:`state_tree` / :func:`state_from_tree`."""
        runner = self._runner
        snap: Dict[str, Any] = {
            "market": _tuple_to_dict(state.market),
            "last_out": _tuple_to_dict(state.last_out),
            "reset_market": _tuple_to_dict(state.reset_market),
            "params": _tuple_to_dict(state.params),
            "portfolio": _tuple_to_dict(state.portfolio),
            "t": int(np.asarray(state.t)),
            "rng": runner.aux_state(state.aux),
            "static_seed": self.spec.seed,
            "num_agents": self.spec.num_agents,
            "horizon": self.horizon,
        }
        if state.stats is not None:
            snap["stats"] = _tuple_to_dict(state.stats)
        if state.seed is not None:
            snap["seed"] = int(np.asarray(state.seed))
        return snap

    def restore(self, snap: Dict[str, Any]) -> EnvState:
        """Rebuild a live :class:`EnvState` from :meth:`snapshot` output.

        The snapshot is device-layout agnostic (arrays are re-placed via
        the runner, sharded runners re-shard them); a static mismatch —
        the snapshot was taken under a different compiled seed or agent
        count — is rejected loudly, mirroring ``Session.restore``.
        """
        runner, xp = self._runner, self._runner.xp
        for field, have in (("static_seed", self.spec.seed),
                            ("num_agents", self.spec.num_agents)):
            got = snap.get(field)
            if got is not None and int(got) != have:
                raise ValueError(
                    f"snapshot was taken under {field}={int(got)} but this "
                    f"env's executable is compiled for {field}={have}")
        market = runner.to_device(_dict_to_tuple(MarketState, snap["market"]))
        reset_market = runner.to_device(
            _dict_to_tuple(MarketState, snap["reset_market"]))
        params = runner.params_to_device(
            _dict_to_tuple(MarketParams, snap["params"]))
        last = _dict_to_tuple(StepOutput, snap["last_out"])
        last = StepOutput(*(xp.asarray(np.asarray(x), xp.float32)
                            for x in last))
        port = _dict_to_tuple(Portfolio, snap["portfolio"])
        port = Portfolio(*(xp.asarray(np.asarray(x), xp.float32)
                           for x in port))
        stats = None
        if snap.get("stats") is not None:
            stats = runner.stats_to_device(
                _dict_to_tuple(MarketStats, snap["stats"]))
        elif self.obs_spec.needs_stats:
            raise ValueError(
                "snapshot carries no MarketStats accumulators but this "
                "env's observation spec needs them")
        rng = snap.get("rng")
        aux = (runner.restore_aux(rng) if rng is not None
               else runner.init_aux(self.spec))
        seed = snap.get("seed")
        seed_leaf = None if seed is None \
            else xp.asarray(np.uint32(int(seed) & 0xFFFFFFFF))
        return EnvState(market=market, last_out=last,
                        reset_market=reset_market, params=params,
                        t=xp.int32(int(snap["t"])), portfolio=port,
                        stats=stats, seed=seed_leaf, aux=aux)

    def save_checkpoint(self, manager, state: EnvState,
                        step: Optional[int] = None) -> int:
        """Persist an :class:`EnvState` through a ``CheckpointManager``."""
        step = int(np.asarray(state.t)) if step is None else int(step)
        manager.save(step, state_tree(self.snapshot(state)))
        manager.wait()
        return step

    def restore_checkpoint(self, manager,
                           step: Optional[int] = None) -> EnvState:
        """Load an :class:`EnvState` from a ``CheckpointManager``."""
        tree = manager.restore(step)
        if tree is None:
            raise FileNotFoundError(f"no checkpoint found in {manager.dir}")
        return self.restore(state_from_tree(tree))


# ---------------------------------------------------------------------------
# Rollouts: the whole policy-in-the-loop trajectory as one lax.scan.
# ---------------------------------------------------------------------------

#: sentinel: distinguishes "no carry" from a legitimate ``None`` carry.
_NO_CARRY = object()


def rollout(env: MarketEnv, policy_fn: Optional[Callable] = None,
            n_steps: Optional[int] = None, *, state: Optional[EnvState] = None,
            seed: Any = None, policy_carry: Any = _NO_CARRY):
    """Roll ``policy_fn`` through ``env`` for ``n_steps`` steps.

    ``policy_fn(obs, t) -> actions`` maps the float32[M, D] observation and
    the int32 step cursor to per-market actions (or ``None`` to hold); it
    must be traceable on traceable backends, where the **entire rollout —
    environment and policy — runs as a single ``lax.scan`` inside one
    jitted executable**: one trace (cached engine-wide per static shape and
    per ``(policy_fn, n_steps)``), zero per-step host transfers. Host-loop
    backends (NumPy references) run the same semantics as a python loop.
    Pass a *stable* function object — a fresh lambda per call defeats the
    executable cache and retraces.

    Stateful policies pass ``policy_carry=<initial carry>`` and use the
    carried signature ``policy_fn(carry, obs, t) -> (carry, actions,
    extras)``: the carry (any pytree — PRNG keys, network params,
    inventory trackers) threads through the scan, and the per-step
    ``extras`` pytree (or ``None``) is stacked into ``batch.extras`` —
    this is how ``repro.train`` collects (obs, action, log_prob, value)
    transitions for GAE without leaving the graph. The return value then
    gains the final carry: ``(state, batch, carry)``. Both paths — jitted
    scan and NumPy host loop — honour the same carried signature.

    ``n_steps`` defaults to the env horizon; ``state`` resumes an existing
    rollout (otherwise :meth:`MarketEnv.reset` with ``seed``). Returns the
    final :class:`EnvState` and a :class:`RolloutBatch` of stacked
    per-step outputs whose ``price``/``volume``/``mid`` paths are laid out
    ``[M, S]`` — directly bitwise-comparable to ``Session.run`` batches.
    """
    carried = policy_carry is not _NO_CARRY
    if carried and policy_fn is None:
        raise ValueError(
            "policy_carry requires a policy_fn with the carried signature "
            "policy_fn(carry, obs, t) -> (carry, actions, extras)")
    n = env.horizon if n_steps is None else int(n_steps)
    if n < 0:
        raise ValueError(f"n_steps must be >= 0, got {n}")
    if state is None:
        state, obs = env.reset(seed=seed)
    else:
        obs = env.observe(state)
    if not env._traceable:
        return _rollout_host(env, policy_fn, n, state, obs,
                             policy_carry if carried else None, carried)
    key = ("rollout", policy_fn, n, carried)
    fn = env._cache.get(key)
    if fn is None:
        fn = env._cache[key] = _build_rollout(env, policy_fn, n, carried)
    if carried:
        return fn(state, obs, policy_carry)
    return fn(state, obs)


def _path(x) -> Any:
    """[S, M, 1] stacked columns -> [M, S] StepBatch layout."""
    return x[..., 0].T


def _build_rollout(env: MarketEnv, policy_fn: Optional[Callable], n: int,
                   carried: bool = False):
    import jax

    runner = env._runner

    def body(carry, _):
        state, obs, pc = carry
        if carried:
            pc, actions, extras = policy_fn(pc, obs, state.t)
        else:
            actions = policy_fn(obs, state.t) if policy_fn is not None \
                else None
            extras = None
        eb, ea = env._lower(actions)
        state, obs, reward, done, info = env._step_impl(state, eb, ea)
        return (state, obs, pc), (obs, reward, done, info, extras)

    def run(state, obs, pc=None):
        runner._trace_count += 1  # python side effect: trace-time only
        (state, obs, pc), (obs_path, rew, done, infos, extras) = jax.lax.scan(
            body, (state, obs, pc), None, length=n)
        batch = RolloutBatch(
            obs=obs_path, reward=rew, done=done,
            price=_path(infos.price), volume=_path(infos.volume),
            mid=_path(infos.mid), fill_buy=_path(infos.fill_buy),
            fill_ask=_path(infos.fill_ask), extras=extras)
        if carried:
            return state, batch, pc
        return state, batch

    return jax.jit(run)


def _rollout_host(env: MarketEnv, policy_fn: Optional[Callable], n: int,
                  state: EnvState, obs: Any, policy_carry: Any = None,
                  carried: bool = False):
    obs_path, rewards, dones, infos, extras_steps = [], [], [], [], []
    pc = policy_carry
    for _ in range(n):
        if carried:
            pc, actions, ex = policy_fn(pc, obs, state.t)
        else:
            actions = policy_fn(obs, state.t) if policy_fn is not None \
                else None
            ex = None
        eb, ea = env._lower(actions)
        state, obs, reward, done, info = env._step_impl(state, eb, ea)
        obs_path.append(np.asarray(obs))
        rewards.append(np.asarray(reward))
        dones.append(bool(done))
        infos.append(info)
        extras_steps.append(ex)
    M = env.spec.num_markets
    def stack(parts, width):
        if parts:
            return np.stack([np.asarray(p) for p in parts])
        return np.zeros((0,) + width, np.float32)
    cols = {f: [getattr(i, f) for i in infos] for f in StepInfo._fields}
    def path(field):
        if not infos:
            return np.zeros((M, 0), np.float32)
        return np.concatenate([np.asarray(c) for c in cols[field]], axis=-1)
    extras = None
    if extras_steps and extras_steps[0] is not None:
        import jax

        extras = jax.tree_util.tree_map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *extras_steps)
    batch = RolloutBatch(
        obs=stack(obs_path, (M, env.obs_size())),
        reward=stack(rewards, (M,)),
        done=np.asarray(dones, bool),
        price=path("price"), volume=path("volume"), mid=path("mid"),
        fill_buy=path("fill_buy"), fill_ask=path("fill_ask"), extras=extras)
    if carried:
        return state, batch, pc
    return state, batch


# ---------------------------------------------------------------------------
# Checkpoint wire format (CheckpointManager pytrees).
# ---------------------------------------------------------------------------

#: snapshot keys holding dicts of arrays (saved as array subtrees).
_ARRAY_SUBTREES = ("market", "last_out", "reset_market", "params",
                   "portfolio", "stats")


def _tuple_to_dict(t) -> Dict[str, np.ndarray]:
    return {f: np.asarray(v) for f, v in zip(type(t)._fields, t)}


def _dict_to_tuple(cls, d: Dict[str, Any]):
    return cls(*(np.asarray(d[f]) for f in cls._fields))


def state_tree(snap: Dict[str, Any]) -> Dict[str, Any]:
    """Pack a :meth:`MarketEnv.snapshot` dict into a checkpointable pytree
    (array subtrees + one JSON meta leaf), mirroring the Session wire
    format in :mod:`repro.checkpoint.manager`."""
    meta = {k: v for k, v in snap.items() if k not in _ARRAY_SUBTREES}
    tree: Dict[str, Any] = {"env_meta": np.asarray(json.dumps(meta))}
    for sub in _ARRAY_SUBTREES:
        if snap.get(sub) is not None:
            tree[sub] = {k: np.asarray(v) for k, v in snap[sub].items()}
    return tree


def state_from_tree(tree: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`state_tree` (for :meth:`MarketEnv.restore`)."""
    snap: Dict[str, Any] = dict(json.loads(str(tree["env_meta"])))
    for sub in _ARRAY_SUBTREES:
        if sub in tree:
            snap[sub] = dict(tree[sub])
    return snap
