"""Pluggable reward functions for :class:`repro.env.MarketEnv`.

A :class:`RewardFn` is a frozen (hashable — part of the engine's env-trace
cache key) dataclass mapping one transition to a float32 ``[M]`` reward,
one scalar per market (the env treats each market's external-order slot as
an independent acting agent). All inputs arrive in a :class:`RewardContext`
built by the env core from the step's clearing outputs and the carried
:class:`repro.env.core.Portfolio` accounting:

  * :class:`PnLReward`         — mark-to-market equity delta (fill cash
    flows plus inventory revaluation at the step's mid);
  * :class:`SpreadCapture`     — edge captured versus the prevailing mid:
    buys below mid and sells above mid earn ``fill · |mid − fill price|``;
  * :class:`InventoryPenalty`  — ``−weight · inventory²`` risk shaping;
  * :class:`Sum`               — weighted sum of child rewards.

Fill attribution uses the engine's uniform-price clearing outputs under a
price-priority, no-rationing model: when a step executes at clearing price
``p*``, an external buy at tick ``>= p*`` (ask at tick ``<= p*``) is
treated as fully filled at ``p*``, otherwise unfilled. This is exact for
the strictly-in-the-money levels of a uniform-price call auction and
optimistic only at the marginal tick (where the book is rationed pro-rata);
it is computable from ``(p*, volume)`` alone, so every backend — including
the fused Pallas kernels, whose per-level execution never leaves VMEM —
produces bitwise-identical fills.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple


class RewardContext(NamedTuple):
    """Everything a reward function may read about one transition."""

    fill_buy: Any   # f32[M, 1] externally-bought lots filled this step
    fill_ask: Any   # f32[M, 1] externally-sold lots filled this step
    fill_price: Any # f32[M, 1] clearing price p* (last price if no cross)
    out: Any        # StepOutput (price / volume / mid columns)
    prev: Any       # Portfolio before the transition
    portfolio: Any  # Portfolio after the transition
    xp: Any


@dataclasses.dataclass(frozen=True)
class RewardFn:
    """Base reward: subclasses implement ``__call__(ctx) -> f32[M]``."""

    def __call__(self, ctx: RewardContext) -> Any:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PnLReward(RewardFn):
    """Mark-to-market profit this step: ``equity_t − equity_{t−1}``.

    Equity is ``cash + inventory · mid`` with both sides marked at the
    step's pre-clearing mid, so the reward decomposes into realized fill
    cash flows plus inventory revaluation — the standard per-step PnL
    shaping for execution agents.
    """

    def __call__(self, ctx: RewardContext) -> Any:
        xp = ctx.xp
        delta = (xp.asarray(ctx.portfolio.equity, xp.float32)
                 - xp.asarray(ctx.prev.equity, xp.float32))
        return delta[:, 0]


@dataclasses.dataclass(frozen=True)
class SpreadCapture(RewardFn):
    """Edge versus the prevailing mid: buys earn ``fill · (mid − p*)``,
    sells earn ``fill · (p* − mid)`` — the market-making objective."""

    def __call__(self, ctx: RewardContext) -> Any:
        xp = ctx.xp
        f32 = xp.float32
        mid = xp.asarray(ctx.out.mid, dtype=f32)
        p = xp.asarray(ctx.fill_price, dtype=f32)
        edge = ctx.fill_buy * (mid - p) + ctx.fill_ask * (p - mid)
        return edge[:, 0]


@dataclasses.dataclass(frozen=True)
class InventoryPenalty(RewardFn):
    """Quadratic inventory-risk shaping: ``−weight · inventory²``."""

    weight: float = 0.01

    def __call__(self, ctx: RewardContext) -> Any:
        xp = ctx.xp
        inv = xp.asarray(ctx.portfolio.inventory, xp.float32)
        return (-xp.float32(self.weight)) * (inv * inv)[:, 0]


@dataclasses.dataclass(frozen=True)
class Sum(RewardFn):
    """Weighted sum of child rewards (default weight 1.0 each)."""

    children: Tuple[RewardFn, ...] = ()
    weights: Tuple[float, ...] = ()

    def __post_init__(self):
        if not self.children:
            raise ValueError("Sum needs at least one child reward")
        object.__setattr__(self, "children", tuple(self.children))
        weights = tuple(self.weights) or (1.0,) * len(self.children)
        if len(weights) != len(self.children):
            raise ValueError(
                f"got {len(weights)} weights for {len(self.children)} "
                "child rewards")
        object.__setattr__(self, "weights", weights)

    def __call__(self, ctx: RewardContext) -> Any:
        xp = ctx.xp
        total = None
        for w, child in zip(self.weights, self.children):
            term = xp.float32(w) * child(ctx)
            total = term if total is None else total + term
        return total
