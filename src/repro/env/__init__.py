"""repro.env — pure-functional vectorized RL environments over the engine.

Public surface:

    from repro.env import MarketEnv, rollout
    from repro.env.obs import MarketFeatures, BookWindow, StatsFeatures
    from repro.env.rewards import PnLReward, SpreadCapture, InventoryPenalty

See :mod:`repro.env.core` for the design notes.
"""
from repro.env.actions import lower_actions, validate_actions  # noqa: F401
from repro.env.core import (  # noqa: F401
    EnvState,
    MarketEnv,
    Portfolio,
    RolloutBatch,
    StepInfo,
    rollout,
    state_from_tree,
    state_tree,
)
from repro.env.obs import (  # noqa: F401
    BookWindow,
    Composite,
    MarketFeatures,
    ObservationSpec,
    PortfolioFeatures,
    StatsFeatures,
)
from repro.env.rewards import (  # noqa: F401
    InventoryPenalty,
    PnLReward,
    RewardContext,
    RewardFn,
    SpreadCapture,
    Sum,
)
