"""Action validation + lowering for the RL surfaces.

One external limit order per market, expressed as an
:class:`repro.core.session.ExternalOrders` triple (``side_buy``, ``price``,
``qty``), is *lowered* onto the reserved ``ext_buy``/``ext_ask`` slot of
``simulate_step`` as a pair of float32[M, L] one-hot quantity grids. Both
RL front doors — the stateful :meth:`Session.step` and the pure-functional
:meth:`repro.env.MarketEnv.step` — share this module, so action semantics
cannot drift between them.

Validation is *eager*: malformed actions (market-count mismatch, off-grid
price levels, negative quantities, non-integer price dtypes) raise a clear
``ValueError`` at the API boundary instead of surfacing as a shape error
deep inside a backend trace. Value checks (grid bounds, sign) run whenever
the operands are concrete host arrays; under jit/vmap tracing the values
are unknowable, so traced prices are additionally clipped to the grid
during lowering — a concrete in-grid action lowers bitwise-identically
with or without the clip.
"""
from __future__ import annotations

from typing import Any, Tuple

import numpy as np

from repro.core.session import ExternalOrders


def _is_concrete(x: Any) -> bool:
    """True when ``x`` is a concrete value whose entries can be inspected
    (host scalars/arrays, or jax device arrays that are not tracers)."""
    if isinstance(x, (int, float, bool, np.ndarray, np.generic, list,
                      tuple)):
        return True
    try:
        import jax

        # Tracers subclass jax.Array — rule them out before accepting it.
        if isinstance(x, jax.core.Tracer):
            return False
        return isinstance(x, jax.Array)
    except ImportError:  # pragma: no cover - jax is a hard dep here
        return False


def _field(value: Any, name: str, num_markets: int) -> Any:
    """Shape-check one action field: scalar or [M] (or [M, 1])."""
    shape = np.shape(value)
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if size not in (1, num_markets):
        raise ValueError(
            f"actions.{name} must broadcast to [{num_markets}] (one order "
            f"per market); got shape {shape} ({size} entries) — market "
            f"mismatch")
    if len(shape) > 2 or (len(shape) == 2 and shape[1] != 1):
        raise ValueError(
            f"actions.{name} must be a scalar, [{num_markets}] or "
            f"[{num_markets}, 1] array; got shape {shape}")
    return value


def validate_actions(actions: Any, num_markets: int,
                     num_levels: int) -> ExternalOrders:
    """Normalize + eagerly validate an action triple.

    Accepts an :class:`ExternalOrders`, any ``(side_buy, price, qty)``
    3-sequence, or a mapping with those keys. Raises ``ValueError`` on a
    market-count mismatch, a price off the ``[0, num_levels)`` grid, a
    negative quantity, or a floating-point price with a fractional part
    (all value checks apply only to concrete host operands — traced values
    pass through and are clipped during lowering).
    """
    if isinstance(actions, dict):
        try:
            actions = ExternalOrders(actions["side_buy"], actions["price"],
                                     actions["qty"])
        except KeyError as exc:
            raise ValueError(
                f"action mapping is missing key {exc.args[0]!r}; need "
                f"side_buy/price/qty") from None
    if not isinstance(actions, ExternalOrders):
        try:
            side_buy, price, qty = actions
        except (TypeError, ValueError):
            raise ValueError(
                "actions must be an ExternalOrders, a (side_buy, price, "
                f"qty) triple, or a mapping with those keys; got "
                f"{type(actions).__name__}") from None
        actions = ExternalOrders(side_buy, price, qty)

    side_buy = _field(actions.side_buy, "side_buy", num_markets)
    price = _field(actions.price, "price", num_markets)
    qty = _field(actions.qty, "qty", num_markets)

    if _is_concrete(price):
        p = np.asarray(price)
        if np.issubdtype(p.dtype, np.floating) and (p != np.floor(p)).any():
            raise ValueError(
                "actions.price must be integer tick indices; got fractional "
                f"values (e.g. {float(p.reshape(-1)[0])})")
        p = p.astype(np.int64)
        if ((p < 0) | (p >= num_levels)).any():
            bad = np.unique(p[(p < 0) | (p >= num_levels)])[:8]
            raise ValueError(
                f"actions.price must lie on the grid [0, {num_levels}); "
                f"got off-grid level(s) {bad.tolist()} — level mismatch")
    if _is_concrete(qty):
        q = np.asarray(qty, dtype=np.float32)
        if (q < 0).any():
            bad = np.unique(q[q < 0])[:8]
            raise ValueError(
                f"actions.qty must be >= 0 lots (0 is a no-op order); got "
                f"negative quantit{'y' if bad.size == 1 else 'ies'} "
                f"{bad.tolist()}")
    return actions


def lower_actions(orders: ExternalOrders, num_markets: int, num_levels: int,
                  xp) -> Tuple[Any, Any]:
    """Lower a validated order triple onto the reserved flow slot.

    Returns ``(ext_buy, ext_ask)`` float32[M, L] quantity grids — exactly
    one nonzero entry per market row (on the order's side, at its tick) —
    built branch-free with ``where`` selects so the same code lowers
    concrete host actions and traced in-graph policy outputs. Exact f32
    placement keeps the injection bitwise-deterministic on every backend.

    Traced values cannot be value-checked, so they are sanitized here the
    way :func:`validate_actions` would have rejected them: prices round to
    the nearest tick and clip to the grid, quantities clamp at 0 — all
    bitwise no-ops for actions that pass the concrete validation.
    """
    M, L = num_markets, num_levels
    f32 = xp.float32
    side = xp.reshape(xp.asarray(orders.side_buy).astype(bool), (-1,))
    side = xp.broadcast_to(side, (M,))[:, None]                  # bool[M, 1]
    price = xp.asarray(orders.price)
    if np.issubdtype(np.dtype(price.dtype), np.floating):
        price = xp.round(price)  # nearest tick, not truncation toward 0
    tick = xp.reshape(price.astype(xp.int32), (-1,))
    tick = xp.clip(xp.broadcast_to(tick, (M,)), 0, L - 1)[:, None]
    lots = xp.reshape(xp.asarray(orders.qty).astype(f32), (-1,))
    lots = xp.maximum(xp.broadcast_to(lots, (M,)), f32(0.0))[:, None]
    onehot = xp.arange(L, dtype=xp.int32)[None, :] == tick       # bool[M, L]
    zero = f32(0.0)
    ext_buy = xp.where(onehot & side, lots, zero).astype(f32)
    ext_ask = xp.where(onehot & ~side, lots, zero).astype(f32)
    return ext_buy, ext_ask
