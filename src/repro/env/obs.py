"""Pluggable observation builders for :class:`repro.env.MarketEnv`.

An :class:`ObservationSpec` is a frozen (hashable — it participates in the
engine's env-trace cache key) dataclass mapping the current environment
state to a float32 ``[M, D]`` feature block, built exclusively from
xp-polymorphic array ops so one spec serves every backend and embeds in
jit/vmap/``lax.scan`` rollouts:

  * :class:`MarketFeatures`   — mid / spread / book imbalance / last trade /
    cleared volume (D = 5), the default microstructure summary;
  * :class:`BookWindow`       — raw book-depth window of ``2·depth`` bid and
    ask quantity levels centred on the rounded mid (D = 4·depth);
  * :class:`PortfolioFeatures`— the acting agent's cash / inventory /
    mark-to-market equity (D = 3);
  * :class:`StatsFeatures`    — running :class:`repro.core.stats.MarketStats`
    moments (count, mean/var of the mid, extremes, total volume; D = 6).
    Specs with ``needs_stats`` make the env carry the accumulators in
    :class:`repro.env.core.EnvState` and update them in-graph each step;
  * :class:`Composite`        — concatenation of child specs along D.

Every feature is a deterministic elementwise map of already
bitwise-reproducible engine outputs, so observations inherit the engine's
cross-backend reproducibility.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

from repro.core import auction
from repro.core.params import EnsembleSpec
from repro.core.stats import MarketStats
from repro.core.step import MarketState, StepOutput


@dataclasses.dataclass(frozen=True)
class ObservationSpec:
    """Base observation builder: subclasses implement :meth:`observe`."""

    #: When True the env carries (and updates in-graph) per-market
    #: ``MarketStats`` accumulators for this spec to read.
    needs_stats = False

    def size(self, spec: EnsembleSpec) -> int:
        """Feature dimension D for a given ensemble spec."""
        raise NotImplementedError

    def observe(self, spec: EnsembleSpec, market: MarketState,
                out: StepOutput, portfolio: "Portfolio",
                stats: Optional[MarketStats], xp) -> Any:
        """float32[M, D] features of the current state.

        ``out`` is the step that *produced* ``market`` (at reset: a
        synthetic zero-volume output whose mid is the opening mid).
        """
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class MarketFeatures(ObservationSpec):
    """[mid, spread, book imbalance, last trade price, cleared volume]."""

    def size(self, spec: EnsembleSpec) -> int:
        return 5

    def observe(self, spec, market, out, portfolio, stats, xp):
        f32 = xp.float32
        bb, ba, _ = auction.best_quotes(market.bid, market.ask,
                                        market.last_price, xp)
        # Empty-side sentinels (bb=-1 / ba=L) make the raw spread ba-bb;
        # it degrades gracefully (wide) instead of branching.
        spread = (ba - bb).astype(f32)
        depth_b = xp.sum(market.bid, axis=-1, keepdims=True)
        depth_a = xp.sum(market.ask, axis=-1, keepdims=True)
        denom = xp.maximum(depth_b + depth_a, f32(1.0))
        imbalance = (depth_b - depth_a) / denom
        return xp.concatenate(
            [xp.asarray(out.mid, dtype=f32), spread, imbalance,
             xp.asarray(market.last_price, dtype=f32),
             xp.asarray(out.volume, dtype=f32)], axis=-1)


@dataclasses.dataclass(frozen=True)
class BookWindow(ObservationSpec):
    """Book-depth window: bid+ask quantities on ``2·depth`` ticks around
    the rounded mid (edge ticks repeat at the grid boundary)."""

    depth: int = 4

    def size(self, spec: EnsembleSpec) -> int:
        return 4 * self.depth

    def observe(self, spec, market, out, portfolio, stats, xp):
        L = spec.num_levels
        d = self.depth
        centre = xp.clip(xp.round(xp.asarray(out.mid, dtype=xp.float32)),
                         xp.float32(0.0),
                         xp.float32(L - 1)).astype(xp.int32)  # [M, 1]
        offsets = xp.arange(2 * d, dtype=xp.int32)[None, :] - xp.int32(d)
        idx = xp.clip(centre + offsets, 0, L - 1)             # [M, 2d]
        bid_win = xp.take_along_axis(market.bid, idx, axis=-1)
        ask_win = xp.take_along_axis(market.ask, idx, axis=-1)
        return xp.concatenate([bid_win, ask_win], axis=-1)


@dataclasses.dataclass(frozen=True)
class PortfolioFeatures(ObservationSpec):
    """The acting agent's [cash, inventory, mark-to-market equity]."""

    def size(self, spec: EnsembleSpec) -> int:
        return 3

    def observe(self, spec, market, out, portfolio, stats, xp):
        f32 = xp.float32
        return xp.concatenate(
            [xp.asarray(portfolio.cash, dtype=f32),
             xp.asarray(portfolio.inventory, dtype=f32),
             xp.asarray(portfolio.equity, dtype=f32)], axis=-1)


@dataclasses.dataclass(frozen=True)
class StatsFeatures(ObservationSpec):
    """Running-moment features from the carried ``MarketStats``:
    [count, mean mid, variance of mid, min mid, max mid, total volume].

    The mean/variance divisions are guarded f32 in-graph reductions (count
    0 reads as mean 0 / var 0); min/max start at ±inf and are clamped to 0
    until the first accumulated step.
    """

    needs_stats = True

    def size(self, spec: EnsembleSpec) -> int:
        return 6

    def observe(self, spec, market, out, portfolio, stats, xp):
        f32 = xp.float32
        if stats is None:
            raise ValueError(
                "StatsFeatures needs the env to carry MarketStats "
                "accumulators (MarketEnv enables them automatically)")
        count = xp.asarray(stats.count, dtype=f32)
        seen = count > f32(0.0)
        denom = xp.maximum(count, f32(1.0))
        mean = xp.asarray(stats.sum_mid, f32) / denom
        var = xp.maximum(
            xp.asarray(stats.sumsq_mid, f32) / denom - mean * mean,
            f32(0.0))
        zero = xp.zeros_like(count)
        mn = xp.where(seen, xp.asarray(stats.min_mid, f32), zero)
        mx = xp.where(seen, xp.asarray(stats.max_mid, f32), zero)
        return xp.concatenate(
            [count, mean, var, mn, mx,
             xp.asarray(stats.sum_volume, dtype=f32)], axis=-1)


@dataclasses.dataclass(frozen=True)
class Composite(ObservationSpec):
    """Concatenation of child observation specs along the feature axis."""

    children: Tuple[ObservationSpec, ...] = ()

    def __post_init__(self):
        if not self.children:
            raise ValueError("Composite needs at least one child spec")
        object.__setattr__(self, "children", tuple(self.children))

    @property
    def needs_stats(self) -> bool:
        return any(c.needs_stats for c in self.children)

    def size(self, spec: EnsembleSpec) -> int:
        return sum(c.size(spec) for c in self.children)

    def observe(self, spec, market, out, portfolio, stats, xp):
        return xp.concatenate(
            [c.observe(spec, market, out, portfolio, stats, xp)
             for c in self.children], axis=-1)
