"""Anakin-style PPO over the market env: the whole update loop is ONE jit.

The trainer compiles rollout collection, GAE, and every minibatched
gradient step into a single executable::

    train(ts, U)  =  jit( lax.scan(update, ts, length=U) )
    update        =  rollout(env, actor, T)        # inner lax.scan, inlined
                     -> gae(...)                   # reverse lax.scan
                     -> scan(epochs) { scan(minibatches) { grad + adam } }

so a full training run performs **zero per-step and zero per-update host
transfers** — the only host crossings are the ``train()`` call boundaries
the driver chooses (checkpointing, logging). This is the engine's
device-residency thesis carried to the gradient step: HBM traffic is
Θ(params + transitions), independent of how many updates run warm.

Experience batching follows the engine's axes: the market axis M is
always batch; ``num_envs > 1`` additionally vmaps whole rollouts over
runtime seeds (counter-RNG backends only — Pallas bakes the seed, so
there M *is* the batch and sharding over devices via the engine's
``shard_map`` path is the scale-out axis instead).

The optimizer is a self-contained pure-JAX Adam (global-norm clipped) so
the optimizer state is an explicit pytree in the scan carry — no
dependency beyond jax, and it checkpoints/restores bitwise through
``CheckpointManager`` like every other engine tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import numpy as np

from repro.env.core import MarketEnv, rollout
from repro.train import buffers
from repro.train.policies import (QuoteGrid, apply_actor_critic,
                                  init_actor_critic, logits_entropy,
                                  logits_log_prob)


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    """Hashable trainer config (keys the engine-wide train-trace cache)."""

    rollout_len: int = 64          # T: env steps collected per update
    num_updates: int = 16          # U: default scan length per train() call
    num_envs: int = 1              # B: vmapped runtime seeds (jax backends)
    num_epochs: int = 2            # passes over each update's transitions
    num_minibatches: int = 4       # gradient steps per epoch
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    max_grad_norm: float = 0.5
    hidden: Tuple[int, ...] = (32, 32)
    k_max: int = 3                 # quote grid half-width (A = 2*k_max + 1)
    qty: float = 1.0
    seed: int = 0


class AdamState(NamedTuple):
    mu: Any     # first-moment pytree, mirrors params
    nu: Any     # second-moment pytree, mirrors params
    count: Any  # i32 step counter


class TrainState(NamedTuple):
    """Everything the jitted train step threads through its scan carry."""

    params: Any      # actor-critic pytree
    opt_state: Any   # AdamState
    key: Any         # jax PRNG key (uint32[2])
    env_state: Any   # EnvState ([B]-batched leaves when num_envs > 1)
    update_idx: Any  # i32 global update counter


def adam_init(params) -> AdamState:
    import jax
    import jax.numpy as jnp

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    zeros2 = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(mu=zeros, nu=zeros2, count=jnp.int32(0))


def adam_apply(params, grads, state: AdamState, *, lr: float,
               b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
               max_grad_norm: Optional[float] = None):
    """One bias-corrected Adam step; optional global-norm gradient clip."""
    import jax
    import jax.numpy as jnp

    tree_map = jax.tree_util.tree_map
    if max_grad_norm is not None:
        sq = sum(jnp.sum(jnp.square(g))
                 for g in jax.tree_util.tree_leaves(grads))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
        grads = tree_map(lambda g: g * scale, grads)
    count = state.count + 1
    mu = tree_map(lambda m, g: b1 * m + (1.0 - b1) * g, state.mu, grads)
    nu = tree_map(lambda v, g: b2 * v + (1.0 - b2) * g * g, state.nu, grads)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, c)
    bc2 = 1.0 - jnp.power(b2, c)
    new_params = tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, mu, nu)
    return new_params, AdamState(mu=mu, nu=nu, count=count)


def ppo_loss(params, mb: buffers.TrainBatch, *, clip_eps: float,
             vf_coef: float, ent_coef: float):
    """Clipped PPO surrogate + clipped value loss + entropy bonus."""
    import jax.numpy as jnp

    logits, value = apply_actor_critic(params, mb.obs)
    logp = logits_log_prob(logits, mb.action)
    ratio = jnp.exp(logp - mb.log_prob)
    adv = (mb.adv - mb.adv.mean()) / (mb.adv.std() + 1e-8)
    pg = -jnp.minimum(ratio * adv,
                      jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv)
    pg_loss = pg.mean()
    v_clip = mb.value + jnp.clip(value - mb.value, -clip_eps, clip_eps)
    v_loss = 0.5 * jnp.maximum(jnp.square(value - mb.ret),
                               jnp.square(v_clip - mb.ret)).mean()
    entropy = logits_entropy(logits).mean()
    total = pg_loss + vf_coef * v_loss - ent_coef * entropy
    approx_kl = ((ratio - 1.0) - jnp.log(ratio)).mean()
    return total, {"loss": total, "pg_loss": pg_loss, "v_loss": v_loss,
                   "entropy": entropy, "approx_kl": approx_kl}


class PPOTrainer:
    """PPO over one :class:`MarketEnv`, compiled to a single executable.

    The compiled train fn (plus the carried actor and greedy-eval
    policies) is cached on the env's engine-wide trace cache keyed by the
    config — a second trainer on a *different scenario mixture of the
    same shape* reuses the warm executable, exactly like rollouts.
    """

    def __init__(self, env: MarketEnv, config: PPOConfig = PPOConfig()):
        if not env._traceable:
            raise ValueError(
                f"PPO needs a traceable backend (got "
                f"{env._engine.backend!r}); gradients cannot flow through "
                "the NumPy host loop")
        if config.num_envs > 1 and not env._runner.env_runtime_seed:
            raise ValueError(
                f"backend {env._engine.backend!r} bakes the RNG seed into "
                "its executable, so rollouts cannot vmap over runtime "
                "seeds; use num_envs=1 (the market axis is the batch, and "
                "devices=N shards it) or a counter-RNG jax backend")
        n = (config.num_envs * config.rollout_len * env.spec.num_markets)
        if n % config.num_minibatches:
            raise ValueError(
                f"num_envs*rollout_len*num_markets = {n} transitions per "
                f"update must divide into num_minibatches="
                f"{config.num_minibatches}")
        self.env = env
        self.config = config
        self.quote = QuoteGrid(k_max=config.k_max, qty=config.qty)
        self.num_actions = self.quote.num_actions
        self.obs_dim = env.obs_size()
        cached = env._cache.get(("train", config))
        if cached is None:
            cached = env._cache[("train", config)] = self._build()
        self._train_fn, self._actor_step, self._eval_step = cached

    # ---- lifecycle ----
    def init(self, seed: Optional[int] = None) -> TrainState:
        """Fresh TrainState: params, Adam state, PRNG key, env state(s)."""
        import jax
        import jax.numpy as jnp

        cfg = self.config
        key = jax.random.PRNGKey(cfg.seed if seed is None else int(seed))
        key, k_init = jax.random.split(key)
        params = init_actor_critic(k_init, self.obs_dim, self.num_actions,
                                   cfg.hidden)
        mesh = getattr(self.env._runner, "_mesh", None)
        if mesh is not None:
            from repro.launch.sharding import replicate_tree

            params = replicate_tree(params, mesh)
        opt_state = adam_init(params)
        if cfg.num_envs > 1:
            base = np.uint32(self.env.spec.seed)
            seeds = jnp.asarray(
                base + np.arange(cfg.num_envs, dtype=np.uint32))
            env_state, _ = jax.vmap(self.env.reset)(seeds)
        else:
            env_state, _ = self.env.reset()
        return TrainState(params=params, opt_state=opt_state, key=key,
                          env_state=env_state, update_idx=jnp.int32(0))

    def train(self, ts: TrainState, num_updates: Optional[int] = None):
        """Run ``num_updates`` PPO updates as ONE jitted call.

        Returns ``(ts, metrics)`` where metrics is a dict of [U] arrays
        (reward, value, loss, pg_loss, v_loss, entropy, approx_kl).
        Repeat calls with the same ``num_updates`` reuse the warm
        executable — assert ``engine.trace_count`` stays flat.
        """
        u = self.config.num_updates if num_updates is None \
            else int(num_updates)
        return self._train_fn(ts, u)

    def evaluate(self, params, env: Optional[MarketEnv] = None,
                 n_steps: Optional[int] = None):
        """Greedy (argmax) rollout of the learned policy; returns the
        RolloutBatch. Pass a held-out env of the same shape to reuse the
        warm executable."""
        env = self.env if env is None else env
        _, batch, _ = rollout(env, self._eval_step, n_steps,
                              policy_carry=params)
        return batch

    # ---- graph construction ----
    def _build(self):
        import jax
        import jax.numpy as jnp

        env, cfg, quote = self.env, self.config, self.quote
        runner = env._runner
        L = env.spec.num_levels
        B, T = cfg.num_envs, cfg.rollout_len
        n_total = B * T * env.spec.num_markets

        def actor_step(carry, obs, t):
            params, key = carry
            logits, value = apply_actor_critic(params, obs)
            key, k_act = jax.random.split(key)
            action = jax.random.categorical(k_act, logits, axis=-1)
            log_prob = logits_log_prob(logits, action)
            orders = quote.to_orders(action, obs[:, 0], L)
            extras = buffers.ActorExtras(obs=obs, action=action,
                                         log_prob=log_prob, value=value)
            return (params, key), orders, extras

        def eval_step(params, obs, t):
            logits, value = apply_actor_critic(params, obs)
            action = jnp.argmax(logits, axis=-1)
            orders = quote.to_orders(action, obs[:, 0], L)
            return params, orders, {"action": action, "value": value}

        def collect(params, key, env_state):
            """One rollout (or B vmapped rollouts) -> [B, T, ...] leaves."""
            if B == 1:
                final, batch, _ = rollout(env, actor_step, T,
                                          state=env_state,
                                          policy_carry=(params, key))
                add_b = lambda x: x[None]
                return final, (
                    jax.tree_util.tree_map(add_b, batch.extras),
                    batch.reward[None], batch.done[None],
                    batch.obs[-1][None])
            keys = jax.random.split(key, B)

            def one(env_state, key):
                final, batch, _ = rollout(env, actor_step, T,
                                          state=env_state,
                                          policy_carry=(params, key))
                return final, (batch.extras, batch.reward, batch.done,
                               batch.obs[-1])

            return jax.vmap(one)(env_state, keys)

        def update_step(ts: TrainState, _):
            params = ts.params
            key, k_roll, k_train = jax.random.split(ts.key, 3)
            env_state, (extras, reward, done, last_obs) = collect(
                params, k_roll, ts.env_state)
            # Bootstrap from the value of the post-rollout observation.
            _, last_value = apply_actor_critic(params, last_obs)
            done_f = jnp.broadcast_to(
                done[..., None].astype(jnp.float32), reward.shape)
            adv, ret = jax.vmap(
                lambda r, v, d, lv: buffers.gae(r, v, d, lv, cfg.gamma,
                                                cfg.gae_lambda)
            )(reward, extras.value, done_f, last_value)
            flat = buffers.TrainBatch(
                obs=extras.obs.reshape((-1, self.obs_dim)),
                action=extras.action.reshape((-1,)),
                log_prob=extras.log_prob.reshape((-1,)),
                value=extras.value.reshape((-1,)),
                adv=adv.reshape((-1,)), ret=ret.reshape((-1,)))

            def mb_step(carry, mb_idx):
                params, opt_state = carry
                mb = buffers.take(flat, mb_idx)
                grad_fn = jax.value_and_grad(ppo_loss, has_aux=True)
                (_, metrics), grads = grad_fn(
                    params, mb, clip_eps=cfg.clip_eps, vf_coef=cfg.vf_coef,
                    ent_coef=cfg.ent_coef)
                params, opt_state = adam_apply(
                    params, grads, opt_state, lr=cfg.lr,
                    max_grad_norm=cfg.max_grad_norm)
                return (params, opt_state), metrics

            def epoch_step(carry, _):
                params, opt_state, key = carry
                key, k_perm = jax.random.split(key)
                idx = buffers.minibatch_indices(k_perm, n_total,
                                                cfg.num_minibatches)
                (params, opt_state), metrics = jax.lax.scan(
                    mb_step, (params, opt_state), idx)
                return (params, opt_state, key), metrics

            (params, opt_state, _), loss_metrics = jax.lax.scan(
                epoch_step, (params, ts.opt_state, k_train), None,
                length=cfg.num_epochs)
            metrics = {k: v.mean() for k, v in loss_metrics.items()}
            metrics["reward"] = reward.mean()
            metrics["value"] = extras.value.mean()
            new_ts = TrainState(params=params, opt_state=opt_state, key=key,
                                env_state=env_state,
                                update_idx=ts.update_idx + 1)
            return new_ts, metrics

        def train(ts: TrainState, num_updates: int):
            runner._trace_count += 1  # python side effect: trace-time only
            return jax.lax.scan(update_step, ts, None, length=num_updates)

        train_fn = jax.jit(train, static_argnums=(1,))
        return train_fn, actor_step, eval_step
