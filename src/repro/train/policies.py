"""Policy zoo for the market env: scripted baselines + a learned trader.

Two families live here:

* **Scripted archetypes** (`make_market_maker`, `make_random_policy`) —
  the stateless reference policies previously duplicated between
  ``examples/rl_rollout.py`` and the test fixtures. They are
  xp-polymorphic (NumPy host loop or traced JAX, picked from the obs
  dtype) so one function object serves every backend, and the factories
  return *stable* closures — build them once and reuse, or the rollout
  executable cache retraces.

* **A learned actor-critic** — a small pure-JAX MLP (`init_actor_critic`
  / `apply_actor_critic`) over a discrete quote grid (`QuoteGrid`). The
  parameter pytree is plain nested dicts/tuples of arrays: it jits, vmaps,
  grads, and flattens through ``CheckpointManager`` with no framework
  dependency beyond jax itself.

The discrete action space is deliberately market-maker shaped: action 0
holds; actions ``1..k_max`` quote a buy ``k`` ticks below mid; actions
``k_max+1..2*k_max`` quote a sell ``k - k_max`` ticks above mid. Lowering
to the book grid rides the same :class:`ExternalOrders` path as every
scripted policy, so learned and scripted traders are bitwise-comparable
workloads on the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import numpy as np

from repro.core import rng
from repro.core.session import ExternalOrders


def _xp(x):
    """NumPy for host-loop backends, jax.numpy for traced arrays."""
    if isinstance(x, np.ndarray):
        return np
    import jax.numpy as jnp

    return jnp


# ---------------------------------------------------------------------------
# Scripted archetypes (factored out of examples/ and test fixtures).
# ---------------------------------------------------------------------------

def make_market_maker(num_levels: int):
    """Quote one lot one tick inside the spread, alternating sides.

    The scripted maker archetype: earns the spread, carries inventory,
    no risk control — the baseline the learned maker has to beat.
    """

    def market_maker(obs, t):
        xp = _xp(obs)
        mid = obs[:, 0]
        buy = (t % 2) == 0
        tick = xp.clip(xp.round(mid + xp.where(buy, -1.0, 1.0))
                       .astype(xp.int32), 0, num_levels - 1)
        return ExternalOrders(side_buy=xp.broadcast_to(buy, mid.shape),
                              price=tick, qty=xp.ones_like(mid))

    return market_maker


def make_random_policy(num_levels: int, stream: int = 101):
    """Uniform random orders from the stateless counter RNG.

    Pure function of (stream, market, step) — no host randomness, so the
    rollout stays one fused graph and replays bitwise on every
    counter-RNG backend.
    """

    def random_policy(obs, t):
        xp = _xp(obs)
        M = obs.shape[0]
        gid = xp.arange(M, dtype=xp.uint32)
        u_side = rng.uniform32(xp.uint32(stream), gid, t, 0, xp)
        u_tick = rng.uniform32(xp.uint32(stream), gid, t, 1, xp)
        mid = obs[:, 0]
        tick = xp.clip(xp.round(mid + (u_tick * 8.0 - 4.0))
                       .astype(xp.int32), 0, num_levels - 1)
        return ExternalOrders(side_buy=u_side < 0.5, price=tick,
                              qty=xp.ones_like(mid))

    return random_policy


# ---------------------------------------------------------------------------
# Discrete quote grid: action index -> ExternalOrders.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuoteGrid:
    """Discrete market-making action space around the mid.

    ``num_actions = 2 * k_max + 1``: index 0 holds, ``1..k_max`` buys
    ``k`` ticks below mid, ``k_max+1..2*k_max`` sells ``k - k_max`` ticks
    above. Frozen + hashable so it can key trace caches.
    """

    k_max: int = 3
    qty: float = 1.0

    @property
    def num_actions(self) -> int:
        return 2 * self.k_max + 1

    def to_orders(self, action, mid, num_levels: int) -> ExternalOrders:
        xp = _xp(mid)
        a = action.astype(xp.int32)
        buy = (a >= 1) & (a <= self.k_max)
        off = xp.where(buy, -a, a - self.k_max).astype(xp.float32)
        price = xp.clip(xp.round(mid + off).astype(xp.int32),
                        0, num_levels - 1)
        q = xp.where(a > 0, xp.float32(self.qty), xp.float32(0.0))
        return ExternalOrders(side_buy=buy, price=price, qty=q)


# ---------------------------------------------------------------------------
# Pure-JAX actor-critic MLP.
# ---------------------------------------------------------------------------

def init_actor_critic(key, obs_dim: int, num_actions: int,
                      hidden: Tuple[int, ...] = (32, 32)):
    """Init a {torso, pi, v} parameter pytree (orthogonal init).

    ``key`` is a jax PRNG key or an int seed. The returned tree is nested
    dicts/tuples of float32 arrays — exactly the structure
    ``CheckpointManager`` flattens losslessly.
    """
    import jax
    import jax.numpy as jnp

    if not hasattr(key, "shape"):
        key = jax.random.PRNGKey(int(key))
    ortho = jax.nn.initializers.orthogonal

    def dense(key, n_in, n_out, scale):
        return (ortho(scale)(key, (n_in, n_out), jnp.float32),
                jnp.zeros((n_out,), jnp.float32))

    keys = jax.random.split(key, len(hidden) + 2)
    torso, n_in = [], obs_dim
    for k, n_out in zip(keys[:-2], hidden):
        torso.append(dense(k, n_in, n_out, np.sqrt(2.0)))
        n_in = n_out
    return {
        "torso": tuple(torso),
        "pi": dense(keys[-2], n_in, num_actions, 0.01),
        "v": dense(keys[-1], n_in, 1, 1.0),
    }


def apply_actor_critic(params, obs):
    """(logits[..., A], value[...]) from obs[..., D]; any leading dims."""
    import jax.numpy as jnp

    x = obs
    for W, b in params["torso"]:
        x = jnp.tanh(x @ W + b)
    Wp, bp = params["pi"]
    Wv, bv = params["v"]
    return x @ Wp + bp, (x @ Wv + bv)[..., 0]


def logits_log_prob(logits, action):
    """log pi(action | obs) from raw logits."""
    import jax
    import jax.numpy as jnp

    logp = jax.nn.log_softmax(logits)
    return jnp.take_along_axis(logp, action[..., None], axis=-1)[..., 0]


def logits_entropy(logits):
    """Per-row policy entropy from raw logits."""
    import jax
    import jax.numpy as jnp

    logp = jax.nn.log_softmax(logits)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


Policy = Any  # docs alias: policy_fn(obs, t) or policy_fn(carry, obs, t)
