"""Host-side training driver: checkpointed spans of jitted updates.

``fit()`` is the only loop that runs on the host: it calls the trainer's
single-executable ``train()`` in equal-sized spans (equal so every span
reuses one warm trace), reads back metrics *between* spans, and threads
the full :class:`TrainState` through ``CheckpointManager`` — policy and
optimizer pytrees alongside the env state, inheriting the COMMIT-marker
crash-consistency protocol. A restore bitwise-continues the learning
curve: params, Adam moments, PRNG key, and every env leaf round-trip
exactly, so update k after a resume equals update k of an uninterrupted
run.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint.manager import (CheckpointCorruptError, meta_leaf,
                                      read_meta)
from repro.env.core import state_from_tree, state_tree
from repro.train.ppo import AdamState, PPOTrainer, TrainState

#: format tag for the trainer wire format (versioning rides in meta_leaf).
TRAIN_FORMAT = "ppo-train"


# ---------------------------------------------------------------------------
# Checkpoint wire format.
# ---------------------------------------------------------------------------

def _split_env_states(env, env_state, num_envs: int):
    import jax

    if num_envs == 1:
        return [env_state]
    return [jax.tree_util.tree_map(lambda x: x[i], env_state)
            for i in range(num_envs)]


def train_state_tree(trainer: PPOTrainer, ts: TrainState) -> Dict[str, Any]:
    """Pack a :class:`TrainState` into a checkpointable pytree.

    Policy params and Adam moments go in as their native nested
    dict/tuple structure (the manager flattens tuples losslessly); each
    env in the batch is packed through the env's own wire format under
    ``envs/<i>``, so every RNG/book/portfolio leaf keeps the engine's
    exact-round-trip guarantees.
    """
    import jax

    host = jax.tree_util.tree_map(np.asarray, ts.params)
    opt = {"mu": jax.tree_util.tree_map(np.asarray, ts.opt_state.mu),
           "nu": jax.tree_util.tree_map(np.asarray, ts.opt_state.nu),
           "count": np.asarray(ts.opt_state.count)}
    B = trainer.config.num_envs
    envs = {
        f"{i:04d}": state_tree(trainer.env.snapshot(s))
        for i, s in enumerate(_split_env_states(trainer.env, ts.env_state,
                                                B))}
    meta = {"format": TRAIN_FORMAT, "num_envs": B,
            "update_idx": int(np.asarray(ts.update_idx))}
    return {"train_meta": meta_leaf(meta), "policy": host, "opt": opt,
            "key": np.asarray(ts.key), "envs": envs}


def train_state_from_tree(trainer: PPOTrainer,
                          tree: Dict[str, Any]) -> TrainState:
    """Inverse of :func:`train_state_tree` — bitwise TrainState rebuild."""
    import jax
    import jax.numpy as jnp

    meta = read_meta(tree["train_meta"], what="trainer checkpoint")
    if meta.get("format") != TRAIN_FORMAT:
        raise CheckpointCorruptError(
            f"not a trainer checkpoint (format={meta.get('format')!r})")
    B = int(meta["num_envs"])
    if B != trainer.config.num_envs:
        raise CheckpointCorruptError(
            f"checkpoint was written with num_envs={B}; trainer config "
            f"has num_envs={trainer.config.num_envs}")
    to_dev = lambda tr: jax.tree_util.tree_map(jnp.asarray, tr)
    params = to_dev(tree["policy"])
    opt_state = AdamState(mu=to_dev(tree["opt"]["mu"]),
                          nu=to_dev(tree["opt"]["nu"]),
                          count=jnp.asarray(tree["opt"]["count"]))
    states = [trainer.env.restore(state_from_tree(tree["envs"][k]))
              for k in sorted(tree["envs"])]
    if B == 1:
        env_state = states[0]
    else:
        env_state = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *states)
    return TrainState(params=params, opt_state=opt_state,
                      key=jnp.asarray(tree["key"]), env_state=env_state,
                      update_idx=jnp.int32(meta["update_idx"]))


def save_train_checkpoint(manager, trainer: PPOTrainer, ts: TrainState,
                          step: Optional[int] = None) -> int:
    """Persist a TrainState through a ``CheckpointManager`` (blocking)."""
    step = int(np.asarray(ts.update_idx)) if step is None else int(step)
    manager.save(step, train_state_tree(trainer, ts))
    manager.wait()
    return step


def restore_train_checkpoint(manager, trainer: PPOTrainer,
                             step: Optional[int] = None) -> TrainState:
    """Load a TrainState from a ``CheckpointManager``."""
    tree = manager.restore(step)
    if tree is None:
        raise FileNotFoundError(f"no checkpoint found in {manager.dir}")
    return train_state_from_tree(trainer, tree)


# ---------------------------------------------------------------------------
# fit(): spans of jitted updates with host-side bookkeeping between them.
# ---------------------------------------------------------------------------

def fit(trainer: PPOTrainer, ts: Optional[TrainState] = None, *,
        total_updates: Optional[int] = None,
        updates_per_call: Optional[int] = None,
        reward_threshold: Optional[float] = None,
        ckpt_manager=None, ckpt_every: int = 0,
        log_fn=None) -> Dict[str, Any]:
    """Train in equal jitted spans; returns ``{ts, history, ...}``.

    ``total_updates`` defaults to the config's ``num_updates``;
    ``updates_per_call`` (default: one span) must divide it — every span
    then reuses the same warm executable. ``reward_threshold`` stops
    early once a span's mean reward/step/market crosses it and records
    the wall-clock time to reach it; ``ckpt_every`` > 0 checkpoints the
    TrainState every that-many updates (and at the end).
    """
    cfg = trainer.config
    total = cfg.num_updates if total_updates is None else int(total_updates)
    span = total if updates_per_call is None else int(updates_per_call)
    if span <= 0 or total % span:
        raise ValueError(
            f"updates_per_call={span} must divide total_updates={total} "
            "(equal spans keep every call on one warm trace)")
    if ts is None:
        ts = trainer.init()
    history: Dict[str, list] = {}
    t0 = time.perf_counter()
    time_to_threshold = None
    done_updates = 0
    while done_updates < total:
        ts, metrics = trainer.train(ts, span)
        done_updates += span
        host = {k: np.asarray(v) for k, v in metrics.items()}
        for k, v in host.items():
            history.setdefault(k, []).extend(v.tolist())
        span_reward = float(host["reward"].mean())
        if log_fn is not None:
            log_fn(done_updates, host)
        if ckpt_manager is not None and ckpt_every > 0 \
                and done_updates % ckpt_every == 0:
            save_train_checkpoint(ckpt_manager, trainer, ts)
        if reward_threshold is not None and time_to_threshold is None \
                and span_reward >= reward_threshold:
            time_to_threshold = time.perf_counter() - t0
            break
    seconds = time.perf_counter() - t0
    if ckpt_manager is not None and ckpt_every > 0:
        save_train_checkpoint(ckpt_manager, trainer, ts)
    env_steps = (done_updates * cfg.rollout_len * cfg.num_envs
                 * trainer.env.spec.num_markets)
    return {"ts": ts, "history": {k: np.asarray(v)
                                  for k, v in history.items()},
            "updates": done_updates, "seconds": seconds,
            "env_steps": env_steps,
            "env_steps_per_s": env_steps / max(seconds, 1e-9),
            "time_to_threshold": time_to_threshold}
