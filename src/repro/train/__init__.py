"""repro.train — end-to-end on-device RL training over the market env.

Public surface:

    from repro.train import PPOConfig, PPOTrainer, fit
    from repro.train.policies import make_market_maker, make_random_policy

The trainer compiles rollout + GAE + minibatched gradient updates into
ONE jitted executable (see :mod:`repro.train.ppo` for the design notes);
:func:`fit` drives checkpointed spans of it from the host. Scripted
baseline policies and the pure-JAX actor-critic live in
:mod:`repro.train.policies`.
"""
from repro.train.buffers import ActorExtras, TrainBatch, gae  # noqa: F401
from repro.train.loop import (  # noqa: F401
    fit,
    restore_train_checkpoint,
    save_train_checkpoint,
    train_state_from_tree,
    train_state_tree,
)
from repro.train.policies import (  # noqa: F401
    QuoteGrid,
    apply_actor_critic,
    init_actor_critic,
    make_market_maker,
    make_random_policy,
)
from repro.train.ppo import (  # noqa: F401
    AdamState,
    PPOConfig,
    PPOTrainer,
    TrainState,
    adam_apply,
    adam_init,
    ppo_loss,
)
