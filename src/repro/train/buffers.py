"""On-device experience handling: transitions, GAE, minibatch plumbing.

Nothing here owns memory — a "buffer" is just the transitions pytree the
rollout already produced (``RolloutBatch.extras`` + reward/done), kept on
device and reshaped in-graph. GAE is a reverse ``lax.scan`` over the time
axis; minibatching is a permutation + reshape. All of it traces into the
same executable as the rollout and the gradient step.
"""
from __future__ import annotations

from typing import Any, NamedTuple


class ActorExtras(NamedTuple):
    """Per-step policy outputs a carried actor stacks into the rollout."""

    obs: Any       # f32[..., M, D] — the PRE-step obs the action saw
    action: Any    # i32[..., M]
    log_prob: Any  # f32[..., M]
    value: Any     # f32[..., M]


class TrainBatch(NamedTuple):
    """Flattened training set for one update: leaves [N, ...]."""

    obs: Any
    action: Any
    log_prob: Any
    value: Any
    adv: Any
    ret: Any


def gae(rewards, values, dones, last_value, gamma: float, lam: float):
    """Generalized advantage estimation as one reverse scan.

    ``rewards``/``values``/``dones`` are [T, M] (dones broadcastable),
    ``last_value`` is [M] — the bootstrap V(s_T). Returns (adv, returns),
    both [T, M]. Episode boundaries (done) zero the bootstrap, matching
    the env's in-graph auto-reset.
    """
    import jax
    import jax.numpy as jnp

    def step(carry, xs):
        acc, next_value = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * next_value * nonterm - v
        acc = delta + gamma * lam * nonterm * acc
        return (acc, v), acc

    zeros = jnp.zeros_like(last_value)
    (_, _), adv = jax.lax.scan(step, (zeros, last_value),
                               (rewards, values, dones), reverse=True)
    return adv, adv + values


def flatten_leading(tree, n_dims: int):
    """Collapse the first ``n_dims`` axes of every leaf into one N axis."""
    import jax

    def flat(x):
        return x.reshape((-1,) + x.shape[n_dims:])

    return jax.tree_util.tree_map(flat, tree)


def take(tree, idx):
    """Gather rows ``idx`` from every [N, ...] leaf."""
    import jax

    return jax.tree_util.tree_map(lambda x: x[idx], tree)


def minibatch_indices(key, n: int, num_minibatches: int):
    """A fresh permutation of [0, n) split into equal minibatches."""
    import jax

    perm = jax.random.permutation(key, n)
    return perm.reshape(num_minibatches, -1)
