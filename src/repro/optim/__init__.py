from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, make_optimizer,
)
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
