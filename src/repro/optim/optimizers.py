"""Functional optimizers: AdamW and Adafactor (factored second moment).

Adafactor is what makes the 1T-parameter kimi-k2 cell fit: second-moment
state is O(rows + cols) instead of O(rows x cols), and params/grads can stay
bf16 (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * g32 * g32
            step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        out = [upd(g, m, n, p)
               for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu, "count": count}

    return Optimizer(init, update)


def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8,
              weight_decay=0.0) -> Optimizer:
    """Adafactor without momentum (memory-lean; Shazeer & Stern 2018)."""
    def factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree_util.tree_map(one, params,
                                        is_leaf=lambda x: hasattr(x, "ndim")),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-decay)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if factored(p):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True),
                                          eps))[..., None] * vc[..., None, :]
                step = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                step = g32 * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
            # update clipping (RMS of step <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
