"""gemma2-27b [dense]: local(4096)/global alternating attention, logit
softcaps 50/30, GeGLU [arXiv:2408.00118; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="gemma2-27b", family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        activation="geglu", attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096, window_pattern=2, post_norm=True,
        embed_scale=True, tie_embeddings=True,
    )


def smoke_config():
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=192, vocab_size=256,
        activation="geglu", attn_softcap=50.0, final_softcap=30.0,
        sliding_window=32, window_pattern=2, post_norm=True,
        embed_scale=True, tie_embeddings=True, remat="none",
    )
