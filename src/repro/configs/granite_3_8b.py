"""granite-3-8b [dense]: GQA kv=8 [hf:ibm-granite/granite-3.0; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="granite-3-8b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=12800, vocab_size=49155,
    )


def smoke_config():
    return ModelConfig(
        name="granite-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, remat="none",
    )
