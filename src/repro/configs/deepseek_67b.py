"""deepseek-67b [dense]: llama-arch, GQA kv=8 [arXiv:2401.02954; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="deepseek-67b", family="dense",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=102400,
    )


def smoke_config():
    return ModelConfig(
        name="deepseek-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=256, remat="none",
    )
