"""whisper-large-v3 [audio enc-dec]: conv frontend STUB (input_specs feeds
precomputed frame embeddings) [arXiv:2212.04356]. 32 encoder + 32 decoder
layers at the published width; MHA (kv=20); LayerNorm + GELU; sinusoidal
positions (simplification noted in DESIGN.md)."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        num_layers=32, encoder_layers=32, d_model=1280, num_heads=20,
        num_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51866,
        norm="layernorm", activation="gelu", use_rope=False,
        qkv_bias=True, source_len=1500,
    )


def smoke_config():
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        norm="layernorm", activation="gelu", use_rope=False,
        qkv_bias=True, source_len=32, remat="none",
    )
