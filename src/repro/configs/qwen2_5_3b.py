"""qwen2.5-3b [dense]: GQA kv=2, QKV bias [hf:Qwen/Qwen2.5; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        head_dim=128, d_ff=11008, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    )


def smoke_config():
    return ModelConfig(
        name="qwen2.5-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        qkv_bias=True, tie_embeddings=True, remat="none",
    )
