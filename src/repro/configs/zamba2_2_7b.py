"""zamba2-2.7b [hybrid]: Mamba2 backbone + weight-tied shared attention
blocks every 6 layers [arXiv:2411.15242; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, mamba_version=2,
        attn_every=6, supports_long_context=True,
    )


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, mamba_version=2,
        attn_every=2, supports_long_context=True, remat="none",
    )
