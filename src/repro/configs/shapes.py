"""Assigned input shapes (same four for every LM-family architecture)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shapes for CPU smoke tests (same phases, tiny sizes).
SMOKE_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


def long_context_skip_reason(cfg) -> str | None:
    """Why long_500k is skipped for this arch (None = runs); see DESIGN.md §5."""
    if cfg.supports_long_context:
        return None
    if cfg.family == "encdec":
        return "enc-dec: decoder positions capped by published architecture"
    return "full-attention decode at 500k has no sub-quadratic path"
