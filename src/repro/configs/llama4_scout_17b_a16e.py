"""llama4-scout-17b-a16e [moe]: 16 experts top-1, early fusion (text-only
backbone here) [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        num_experts=16, top_k=1, parallelism="tp",
    )


def smoke_config():
    return ModelConfig(
        name="llama4-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=256,
        num_experts=4, top_k=1, moe_group_size=64, remat="none",
    )
