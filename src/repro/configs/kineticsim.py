"""The paper's own workload as a selectable config (market ensembles)."""
from repro.core.config import MarketConfig


def config():
    # Paper fixed reference workload (Table IV)
    return MarketConfig(num_markets=8192, num_agents=256, num_levels=128,
                        num_steps=500)


def smoke_config():
    return MarketConfig(num_markets=16, num_agents=32, num_levels=64,
                        num_steps=10)
