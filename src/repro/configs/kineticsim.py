"""The paper's own workload as a selectable config (market ensembles)."""
from repro.core.config import MarketConfig, scenario_config, scenario_names


def config():
    # Paper fixed reference workload (Table IV)
    return MarketConfig(num_markets=8192, num_agents=256, num_levels=128,
                        num_steps=500)


def smoke_config():
    return MarketConfig(num_markets=16, num_agents=32, num_levels=64,
                        num_steps=10)


def scenario(name: str, **overrides) -> MarketConfig:
    """Paper workload under a named scenario preset (see scenario_names())."""
    base = dict(num_markets=8192, num_agents=256, num_levels=128,
                num_steps=500)
    base.update(overrides)
    return scenario_config(name, **base)


def scenario_smoke(name: str, **overrides) -> MarketConfig:
    """CPU-tractable scenario config (same presets, reduced shape)."""
    base = dict(num_markets=16, num_agents=32, num_levels=64, num_steps=10)
    base.update(overrides)
    return scenario_config(name, **base)


def all_scenarios():
    return scenario_names()
