"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_config(name,
smoke=True)`` returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHITECTURES: List[str] = [
    "zamba2-2.7b",
    "deepseek-67b",
    "qwen2.5-3b",
    "gemma2-27b",
    "granite-3-8b",
    "whisper-large-v3",
    "kimi-k2-1t-a32b",
    "llama4-scout-17b-a16e",
    "falcon-mamba-7b",
    "qwen2-vl-72b",
    "kineticsim",  # the paper's own workload expressed as a config
]


def _module(name: str):
    return importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))


def get_config(name: str, smoke: bool = False):
    mod = _module(name)
    return mod.smoke_config() if smoke else mod.config()


def all_configs(smoke: bool = False) -> Dict[str, object]:
    return {n: get_config(n, smoke) for n in ARCHITECTURES if n != "kineticsim"}
