"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8, per-expert
d_ff=2048 [arXiv:2501.kimi2]. Dry-run uses bf16 params + Adafactor
(DESIGN.md §6 memory realism)."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=2048, vocab_size=163840,
        num_experts=384, top_k=8, parallelism="tp",
        param_dtype="bfloat16",
    )


def smoke_config():
    return ModelConfig(
        name="kimi-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=256,
        num_experts=8, top_k=2, moe_group_size=64, remat="none",
    )
