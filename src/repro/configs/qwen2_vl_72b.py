"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; patch frontend STUB
(input_specs feeds precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=29568, vocab_size=152064,
        qkv_bias=True, mrope=True, num_vision_tokens=256,
        rope_theta=1e6,
    )


def smoke_config():
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        qkv_bias=True, mrope=True, num_vision_tokens=8, remat="none",
    )
