"""falcon-mamba-7b [ssm]: attention-free mamba1, d_inner=8192, state=16
[arXiv:2410.05355]."""
from repro.models.model_config import ModelConfig


def config():
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=65024,
        ssm_state=16, ssm_expand=2, mamba_version=1,
        supports_long_context=True,
    )


def smoke_config():
    return ModelConfig(
        name="falcon-mamba-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm_state=8, ssm_expand=2, mamba_version=1,
        supports_long_context=True, remat="none",
    )
