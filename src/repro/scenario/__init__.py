"""Coupled multi-asset scenarios over the KineticSim engine.

The scenario layer composes the core engine's primitives into studies:

  * :mod:`repro.scenario.coupling` — :class:`CouplingSpec`, the
    cross-market arbitrage graph lowered onto the ``coupling_peer``
    params column (gather on one device, ``ppermute`` ring halo exchange
    when the market axis is sharded).
  * :mod:`repro.scenario.validate` — the stylized-facts validation gate:
    typed :class:`FactCheck` / :class:`ValidationReport` results over the
    pinned CI mixtures.
  * :mod:`repro.scenario.sequential` — the sequential-clearing reference
    (Steinbacher et al.) and the parallel-vs-sequential mechanism-gap
    report.

Everything here is values over the warm engine: applying a coupling,
swapping a mixture, or validating a scenario never retraces a compiled
executable.
"""
from repro.scenario.coupling import CouplingSpec, coupled_ensemble
from repro.scenario.sequential import (
    mechanism_gap,
    simulate_reference_sequential,
    simulate_step_sequential,
)
from repro.scenario.validate import (
    PINNED_MIXTURES,
    FactCheck,
    ValidationReport,
    stylized_facts,
    validate_pinned,
    validate_spec,
)

__all__ = [
    "CouplingSpec",
    "coupled_ensemble",
    "mechanism_gap",
    "simulate_reference_sequential",
    "simulate_step_sequential",
    "PINNED_MIXTURES",
    "FactCheck",
    "ValidationReport",
    "stylized_facts",
    "validate_pinned",
    "validate_spec",
]
