"""Mechanism-gap reporting: parallel call auction vs sequential clearing.

The engine's uniform-price call auction is what makes a step
embarrassingly parallel; the classical ABM literature clears order by
order (Steinbacher et al.), and the choice of mechanism itself shifts the
emergent dynamics. :mod:`repro.core.sequential` implements the sequential
reference with the *identical* agent decisions; this module runs both
mechanisms on one configuration and reports the gap as a typed artifact —
the scenario tier's evidence that mechanism differences are measured, not
assumed.

Both runs use the NumPy backend's kinetic counter RNG (the sequential
reference is host-loop/``lax.scan`` only), so every decision draw is
bitwise shared between the two mechanisms and the reported deltas are
attributable to clearing alone.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import engine
from repro.core.sequential import match_order, simulate_step_sequential
from repro.kernels.ref import simulate_reference_sequential

__all__ = ["match_order", "simulate_step_sequential",
           "simulate_reference_sequential", "mechanism_gap"]

#: The stylized metrics both mechanisms report; the gap rows carry one
#: ``<metric>_parallel`` / ``<metric>_sequential`` / ``<metric>_delta``
#: triple per entry.
GAP_METRICS = ("mean_clearing_price", "volume_per_market", "trade_count",
               "volatility", "excess_kurtosis")


def _metrics(result) -> Dict[str, float]:
    r = result.to_numpy()
    return {m: float(getattr(r, m)()) for m in GAP_METRICS}


def mechanism_gap(cfg, backend: str = "numpy") -> Dict[str, float]:
    """Run ``cfg`` under both clearing mechanisms; return the flat gap row.

    ``backend`` must be a numpy-family backend (``numpy``,
    ``numpy-splitmix64``, ``numpy-pcg64`` — the sequential reference is
    host-driven). Keys: ``<metric>_parallel``, ``<metric>_sequential``,
    ``<metric>_delta`` (sequential minus parallel) for every
    :data:`GAP_METRICS` entry. Decision draws are shared (same backend,
    same RNG stream), so the deltas isolate the clearing rule.
    """
    par = _metrics(engine.simulate(cfg, backend=backend))
    seq = _metrics(engine.simulate(cfg, backend=backend,
                                   clearing="sequential"))
    row: Dict[str, float] = {}
    for m in GAP_METRICS:
        row[f"{m}_parallel"] = par[m]
        row[f"{m}_sequential"] = seq[m]
        d = seq[m] - par[m]
        row[f"{m}_delta"] = float(d if np.isfinite(d) else np.nan)
    return row
