"""Cross-market coupling: who is whose arbitrage peer.

A :class:`CouplingSpec` is a pure description of the coupling graph — one
peer id per market, ``-1`` meaning *self-coupled* (the arbitrageur gap is
identically zero, so an uncoupled market is bitwise the baseline). It
lowers onto the :class:`repro.core.params.MarketParams` ``coupling_peer``
column via :meth:`apply`, so coupling is a *value*, never a trace: turning
it on, off, or rewiring it between chunks reuses the warm executable.

Runtime semantics (every backend, same freeze boundary): at each chunk
entry the engine gathers ``prev_mid`` at the peer row — a plain gather
over the market axis on one device, a ``lax.ppermute`` ring halo exchange
under ``shard_map`` when the market axis is sharded (see
``repro.kernels.ops``) — and arbitrageur agents trade toward that frozen
peer mid for the whole chunk. Coupled runs are therefore
bitwise-identical across device topologies, and across backends whenever
the chunk lengths agree (the freeze boundaries are part of the
semantics, exactly like the RNG step coordinate).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.params import EnsembleSpec


@dataclasses.dataclass(frozen=True)
class CouplingSpec:
    """Peer map over the market axis: ``peer[m]`` is the market whose
    previous-chunk mid market ``m``'s arbitrageurs track (``-1``: self)."""

    peer: np.ndarray  # int32[M]

    def __post_init__(self):
        arr = np.asarray(self.peer, dtype=np.int32).reshape(-1)
        object.__setattr__(self, "peer", arr)
        M = arr.size
        if M == 0:
            raise ValueError("CouplingSpec needs at least one market")
        bad = (arr < -1) | (arr >= M)
        if bad.any():
            rows = np.where(bad)[0]
            raise ValueError(
                f"coupling peer ids must be -1 (self) or in [0, {M}); "
                f"markets {rows[:8].tolist()} have "
                f"{arr[rows[:8]].tolist()}")

    # ---- constructors ----
    @classmethod
    def none(cls, num_markets: int) -> "CouplingSpec":
        """Fully decoupled (every market self-coupled) — the baseline."""
        return cls(np.full(num_markets, -1, np.int32))

    @classmethod
    def ring(cls, num_markets: int, offset: int = 1) -> "CouplingSpec":
        """Each market tracks its neighbor ``offset`` rows ahead (mod M) —
        the canonical sharded-coupling stress: with markets sharded
        contiguously, every shard boundary is a cross-device edge."""
        if num_markets < 2:
            raise ValueError("ring coupling needs >= 2 markets")
        if offset % num_markets == 0:
            raise ValueError(
                f"ring offset {offset} is a multiple of num_markets="
                f"{num_markets}: every market would track itself")
        idx = np.arange(num_markets, dtype=np.int32)
        return cls((idx + offset) % num_markets)

    @classmethod
    def pairs(cls, num_markets: int,
              pairs: Sequence[Sequence[int]]) -> "CouplingSpec":
        """Mutually coupled pairs ``(a, b)``; unlisted markets stay self-
        coupled. A market may appear in at most one pair."""
        peer = np.full(num_markets, -1, np.int32)
        for a, b in pairs:
            a, b = int(a), int(b)
            if a == b:
                raise ValueError(f"pair ({a}, {b}) couples a market to "
                                 "itself; omit it instead")
            for m in (a, b):
                if not 0 <= m < num_markets:
                    raise ValueError(
                        f"pair market {m} out of range [0, {num_markets})")
                if peer[m] != -1:
                    raise ValueError(
                        f"market {m} appears in more than one pair")
            peer[a], peer[b] = b, a
        return cls(peer)

    @classmethod
    def explicit(cls, mapping: Mapping[int, int],
                 num_markets: int) -> "CouplingSpec":
        """Arbitrary directed peer map ``{market: peer}``; unlisted markets
        stay self-coupled."""
        peer = np.full(num_markets, -1, np.int32)
        for m, p in mapping.items():
            if not 0 <= int(m) < num_markets:
                raise ValueError(
                    f"market {m} out of range [0, {num_markets})")
            peer[int(m)] = int(p)
        return cls(peer)

    # ---- derived ----
    @property
    def num_markets(self) -> int:
        return int(self.peer.size)

    @property
    def coupled_markets(self) -> np.ndarray:
        """Indices of markets with a real (non-self) peer."""
        idx = np.arange(self.num_markets)
        return idx[(self.peer >= 0) & (self.peer != idx)]

    def apply(self, spec: EnsembleSpec) -> EnsembleSpec:
        """Lower onto ``spec``'s ``coupling_peer`` params column.

        Pure value update (:meth:`EnsembleSpec.with_values`): the result
        shares the source spec's static key, hence its warm executable.
        The spec's arbitrageur population (``alpha_arbitrageur`` /
        ``num_arbitrageurs``) decides whether the coupling has any effect;
        applying a coupling to an arbitrageur-free spec is bitwise inert.
        """
        if spec.num_markets != self.num_markets:
            raise ValueError(
                f"coupling is over {self.num_markets} markets but the spec "
                f"has {spec.num_markets}")
        return spec.with_values(coupling_peer=self.peer)


def coupled_ensemble(spec: EnsembleSpec,
                     coupling: CouplingSpec) -> EnsembleSpec:
    """Convenience: ``coupling.apply(EnsembleSpec.coerce(spec))``."""
    return coupling.apply(EnsembleSpec.coerce(spec))
