"""Stylized-facts validation gate (paper §IV-J, grown into a subsystem).

The emergent-dynamics benchmark measured the paper's stylized-fact battery
(fat tails, volatility clustering, volume/volatility correlation); this
module turns those measurements into a typed pass/fail *gate* that CI runs
on pinned scenario mixtures — the realism regression test for the
archetype engine.

Layers:

  * :func:`stylized_facts` — the per-configuration measurement (moved here
    from ``benchmarks/emergent_dynamics.py``, which now re-exports it):
    volatility, kurtosis, volume/volatility correlation, return ACFs.
  * :class:`FactCheck` / :class:`ValidationReport` — typed pass/fail
    results; a report serializes to the ``BENCH_scenario_realism.json``
    artifact rows.
  * :func:`validate_spec` — run one config and check the battery: excess
    kurtosis above threshold (fat tails; Gaussian = 0), positive
    volume/volatility correlation, and a decaying ``|r|`` ACF
    (``lag-1 > lag-10``, the volatility-clustering signature).
  * :data:`PINNED_MIXTURES` / :func:`validate_pinned` — the mixtures CI
    pins: the high-vol momentum preset plus the whale / HFT / informed
    archetype mixtures introduced with the scenario engine.

The ``stats_check`` option cross-validates the path-derived moments
against the in-kernel :mod:`repro.core.stats` accumulators (a second
session run in ``stats_only`` mode): the mid-price mean/variance and the
total volume must agree to float32 accumulation tolerance, tying the
gate's inputs to the zero-copy statistics path used at scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import engine
from repro.core.config import MarketConfig, scenario_config
from repro.core.params import EnsembleSpec

#: Number of ensemble markets in the pinned CI mixtures.
PINNED_MARKETS = 64
#: Steps in the pinned mixtures: shorter runs leave the volume/volatility
#: correlation inside seed noise (see benchmarks/emergent_dynamics.py).
PINNED_STEPS = 500


def _mean_corr(a: np.ndarray, b: np.ndarray) -> float:
    """Mean-over-markets Pearson correlation of two [M, S] series."""
    ac = a - a.mean(axis=1, keepdims=True)
    bc = b - b.mean(axis=1, keepdims=True)
    num = (ac * bc).sum(axis=1)
    den = np.sqrt((ac * ac).sum(axis=1) * (bc * bc).sum(axis=1))
    with np.errstate(invalid="ignore", divide="ignore"):
        return float(np.nanmean(num / den))


def _mean_acf(x: np.ndarray, lag: int) -> float:
    """Mean-over-markets lag-``lag`` autocorrelation of an [M, S] series."""
    xc = x - x.mean(axis=1, keepdims=True)
    den = (xc * xc).sum(axis=1)
    with np.errstate(invalid="ignore", divide="ignore"):
        return float(np.nanmean(
            (xc[:, lag:] * xc[:, :-lag]).sum(axis=1) / den))


def stylized_facts(cfg, backend: str = "jax-scan", lags: int = 20,
                   eng: Optional[engine.Engine] = None) -> dict:
    """Run ``cfg`` once and measure the paper's stylized-fact battery.

    ``cfg`` is a :class:`MarketConfig` or :class:`EnsembleSpec`. Returns
    volatility, excess/raw kurtosis, the volume/volatility correlation
    (positive = volume stimulates with |returns|), mean volume per step,
    and lag-1/lag-10 ACFs of ``r_t`` and ``|r_t|``.

    Returns are measured on the **mid-price path**, not the per-step
    clearing price. The clearing price holds at the last trade whenever a
    step fails to cross and pins at deep-crossing levels when it does, so
    its return series carries a strong bid-ask-bounce artifact (negative
    lag-1 ``|r|`` ACF) and a mechanically negative volume/volatility
    correlation — the uniform-price auction's discretization, not the
    dynamics of interest. The mid is the continuous price proxy, and on it
    the three canonical facts (fat tails, volatility clustering, positive
    volume/volatility correlation) can hold jointly.
    """
    spec = EnsembleSpec.coerce(cfg)
    if eng is None:
        eng = engine.Engine(backend)
    with eng.open(spec) as sess:
        batch = sess.run(spec.num_steps)
        mid = np.asarray(batch.mid, np.float64)
        vol = np.asarray(batch.volume, np.float64)
    r = np.diff(mid, axis=1)
    absr = np.abs(r)
    rc = r - r.mean(axis=1, keepdims=True)
    with np.errstate(invalid="ignore", divide="ignore"):
        kurt = float(np.nanmean(
            (rc ** 4).mean(axis=1) / (rc ** 2).mean(axis=1) ** 2))
    return {
        "volatility": float(np.nanmean(r.std(axis=1))),
        "excess_kurtosis": kurt - 3.0,
        "kurtosis": kurt,  # raw kurtosis; Gaussian = 3
        "volume_volatility_corr": _mean_corr(absr, vol[:, 1:]),
        "volume_per_step": float(vol.mean()),
        "acf_r_lag1": _mean_acf(r, 1),
        "acf_abs_lag1": _mean_acf(absr, 1),
        "acf_abs_lag10": _mean_acf(absr, min(10, max(lags, 2))),
    }


@dataclasses.dataclass(frozen=True)
class FactCheck:
    """One stylized-fact assertion: ``value <op> threshold``."""

    name: str
    value: float
    op: str           # ">" or "<"
    threshold: float
    passed: bool

    @classmethod
    def check(cls, name: str, value: float, op: str,
              threshold: float) -> "FactCheck":
        if op not in (">", "<"):
            raise ValueError(f"FactCheck op must be '>' or '<', got {op!r}")
        v = float(value)
        ok = math.isfinite(v) and (v > threshold if op == ">"
                                   else v < threshold)
        return cls(name=name, value=v, op=op, threshold=float(threshold),
                   passed=ok)

    def __str__(self) -> str:
        mark = "PASS" if self.passed else "FAIL"
        return (f"{mark} {self.name}: {self.value:.4f} {self.op} "
                f"{self.threshold:g}")


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    """The gate's result for one configuration: every check + raw facts."""

    scenario: str
    backend: str
    checks: Tuple[FactCheck, ...]
    facts: Dict[str, float]

    @property
    def passed(self) -> bool:
        return all(c.passed for c in self.checks)

    @property
    def failures(self) -> Tuple[FactCheck, ...]:
        return tuple(c for c in self.checks if not c.passed)

    def summary(self) -> str:
        head = ("PASS" if self.passed else "FAIL")
        lines = [f"{head} {self.scenario} [{self.backend}]"]
        lines += [f"  {c}" for c in self.checks]
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario,
            "backend": self.backend,
            "passed": self.passed,
            "checks": [dataclasses.asdict(c) for c in self.checks],
            "facts": dict(self.facts),
        }


def _stats_crosscheck(cfg, backend: str, facts: dict,
                      checks: list) -> None:
    """Tie the path-derived facts to the in-kernel MarketStats path.

    Kurtosis/ACF need the full per-step paths, but the first two mid
    moments and the total volume are exactly what the ``stats_only``
    accumulators carry — re-run in that mode and require agreement to
    float32 accumulation tolerance.
    """
    spec = EnsembleSpec.coerce(cfg)
    with engine.Engine(backend, stats_only=True).open(spec) as sess:
        sess.run(spec.num_steps)
        st = sess.stats
    with engine.Engine(backend).open(spec) as sess:
        batch = sess.run(spec.num_steps)
        mids = np.asarray(batch.mid, np.float64)
        vols = np.asarray(batch.volume, np.float64)
    stats_mean = float(np.asarray(st.mean_mid()).mean())
    stats_var = float(np.asarray(st.var_mid()).mean())
    stats_vol = float(np.asarray(st.sum_volume).sum())
    path_mean = float(mids.mean())
    path_var = float(mids.var(axis=1).mean())
    path_vol = float(vols.sum())
    checks.append(FactCheck.check(
        "stats_mean_mid_agrees",
        abs(stats_mean - path_mean) / max(abs(path_mean), 1.0), "<", 1e-3))
    checks.append(FactCheck.check(
        "stats_var_mid_agrees",
        abs(stats_var - path_var) / max(abs(path_var), 1e-6), "<", 1e-2))
    checks.append(FactCheck.check(
        "stats_volume_agrees",
        abs(stats_vol - path_vol) / max(path_vol, 1.0), "<", 1e-3))
    facts.update(stats_mean_mid=stats_mean, stats_var_mid=stats_var,
                 stats_sum_volume=stats_vol)


def validate_spec(cfg, backend: str = "jax-scan", *,
                  scenario: Optional[str] = None,
                  min_excess_kurtosis: float = 0.0,
                  min_vv_corr: float = 0.0,
                  require_acf_decay: bool = True,
                  stats_check: bool = False,
                  lags: int = 20,
                  eng: Optional[engine.Engine] = None) -> ValidationReport:
    """Run the stylized-facts battery on ``cfg`` and gate it.

    Checks (each a :class:`FactCheck` in the report):

      * ``excess_kurtosis > min_excess_kurtosis`` — fat tails. The default
        threshold ``0`` asserts super-Gaussian tails (raw kurtosis > 3).
      * ``volume_volatility_corr > min_vv_corr`` — volume stimulates with
        volatility.
      * ``acf_abs_lag1 > acf_abs_lag10`` — the |return| ACF decays from a
        positive short-lag value: volatility clustering without long-memory
        artifacts (only when ``require_acf_decay``).

    ``stats_check=True`` adds the in-kernel statistics cross-validation
    (one extra ``stats_only`` run; see module doc). Pass ``eng`` to run
    every gated mixture over one warm engine (the realism benchmark uses
    this to assert zero warm retraces across the pinned set).
    """
    name = scenario if scenario is not None else (
        getattr(cfg, "scenario", None) or "custom")
    facts = stylized_facts(cfg, backend=backend, lags=lags, eng=eng)
    checks = [
        FactCheck.check("excess_kurtosis", facts["excess_kurtosis"], ">",
                        min_excess_kurtosis),
        FactCheck.check("volume_volatility_corr",
                        facts["volume_volatility_corr"], ">", min_vv_corr),
    ]
    if require_acf_decay:
        checks.append(FactCheck.check(
            "acf_abs_decay",
            facts["acf_abs_lag1"] - facts["acf_abs_lag10"], ">", 0.0))
        checks.append(FactCheck.check(
            "acf_abs_lag1", facts["acf_abs_lag1"], ">", 0.0))
    if stats_check:
        _stats_crosscheck(cfg, backend, facts, checks)
    return ValidationReport(scenario=str(name), backend=backend,
                            checks=tuple(checks), facts=facts)


# ---------------------------------------------------------------------------
# Pinned CI mixtures. Builders, not configs, so the step count stays
# overridable for fast local smokes; CI runs the defaults.
# ---------------------------------------------------------------------------


def high_vol_momentum_config(num_steps: int = PINNED_STEPS) -> MarketConfig:
    """The historical smoke pin: high-vol preset, momentum-heavy mix."""
    return scenario_config("high-vol", num_markets=PINNED_MARKETS,
                           num_agents=256, num_steps=num_steps,
                           alpha_maker=0.15, alpha_momentum=0.5, seed=1)


def whale_mixture_config(num_steps: int = PINNED_STEPS) -> MarketConfig:
    """Whale preset over the momentum-rich base: infrequent large sweeps
    thicken the tails on top of the clustering regime."""
    return scenario_config("whale", num_markets=PINNED_MARKETS,
                           num_agents=256, num_steps=num_steps,
                           alpha_momentum=0.5, seed=1)


def hft_mixture_config(num_steps: int = PINNED_STEPS) -> MarketConfig:
    """HFT preset over the momentum-rich base: imbalance chasers amplify
    one-sided books."""
    return scenario_config("hft", num_markets=PINNED_MARKETS,
                           num_agents=256, num_steps=num_steps,
                           alpha_momentum=0.5, seed=1)


def informed_mixture_config(num_steps: int = PINNED_STEPS) -> MarketConfig:
    """Informed preset: front-running of a mid-run shock adds an event-time
    volatility burst to the clustering regime."""
    return scenario_config("informed", num_markets=PINNED_MARKETS,
                           num_agents=256, num_steps=num_steps,
                           alpha_momentum=0.5, seed=1)


PINNED_MIXTURES: Dict[str, Callable[[], MarketConfig]] = {
    "high-vol-momentum": high_vol_momentum_config,
    "whale": whale_mixture_config,
    "hft": hft_mixture_config,
    "informed": informed_mixture_config,
}


def validate_pinned(backend: str = "jax-scan", *,
                    num_steps: int = PINNED_STEPS,
                    stats_check: bool = False,
                    ) -> Dict[str, ValidationReport]:
    """Run the gate on every pinned mixture; the CI realism job fails if
    any report fails."""
    return {
        name: validate_spec(build(num_steps), backend=backend,
                            scenario=name, stats_check=stats_check)
        for name, build in PINNED_MIXTURES.items()
    }
