"""Chunked (flash-style) attention with GQA, sliding window, softcap, M-RoPE.

Training/prefill uses a blockwise online-softmax implementation: the score
matrix is never materialized beyond (q_chunk x kv_chunk) tiles, and causal /
sliding-window structure skips out-of-range KV blocks *statically* (the KV
loop length is computed per Q chunk at trace time), so the compiled FLOPs
reflect only the needed blocks. Decode uses a dense single-row path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers


class AttnDims(NamedTuple):
    num_heads: int
    num_kv_heads: int
    head_dim: int


def attn_init(key, d_model, dims: AttnDims, *, qkv_bias=False, dtype=jnp.float32):
    H, KV, hd = dims
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers._init(ks[0], (d_model, H * hd), dtype=dtype),
        "wk": layers._init(ks[1], (d_model, KV * hd), dtype=dtype),
        "wv": layers._init(ks[2], (d_model, KV * hd), dtype=dtype),
        "wo": layers._init(ks[3], (H * hd, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(params, x, dims: AttnDims):
    B, T, _ = x.shape
    H, KV, hd = dims
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    return (q.reshape(B, T, H, hd), k.reshape(B, T, KV, hd),
            v.reshape(B, T, KV, hd))


def _block_scores(q, k, scale, cap):
    # q: [B, qc, KV, G, hd]; k: [B, kc, KV, hd] -> scores [B, KV, G, qc, kc]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    return layers.softcap(s, cap)


def _chunk(T: int, target: int) -> int:
    c = min(target, T)
    while T % c:
        c -= 1
    return c


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Blockwise attention. q: [B,T,H,hd]; k,v: [B,S,KV,hd] (GQA aware)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    qc = _chunk(T, q_chunk)
    kc = _chunk(S, kv_chunk)
    nq = T // qc
    q = q.reshape(B, nq, qc, KV, G, hd)
    nk_total = S // kc
    k = k.reshape(B, nk_total, kc, KV, hd)
    v = v.reshape(B, nk_total, kc, KV, hd)
    offset = S - T if causal else 0  # self-attn on a suffix (prefill continuation)

    out_chunks = []
    for qi in range(nq):
        # bf16 operands / f32 accumulation (EXPERIMENTS §Perf: f32 operand
        # casts materialized hidden-sized f32 q/k/v and forced f32 cotangent
        # all-reduces at every TP boundary).
        q_blk = q[:, qi]
        q_pos = offset + qi * qc + jnp.arange(qc)
        if causal:
            hi = min(nk_total, (offset + (qi + 1) * qc + kc - 1) // kc)
        else:
            hi = nk_total
        lo = 0
        if window is not None and causal:
            lo = max(0, (offset + qi * qc - window) // kc)
        n_blocks = hi - lo

        def kv_step(carry, inputs):
            acc, m, l = carry
            k_blk, v_blk, k_start = inputs
            s = _block_scores(q_blk, k_blk, scale, cap)
            k_pos = k_start + jnp.arange(kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        k_starts = (lo + jnp.arange(n_blocks)) * kc
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (k[:, lo:hi].swapaxes(0, 1), v[:, lo:hi].swapaxes(0, 1), k_starts),
        )
        l = jnp.maximum(l, 1e-37)
        out = (acc / l[..., None])  # [B, KV, G, qc, hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc, KV * G, hd)
        out_chunks.append(out)
    o = jnp.concatenate(out_chunks, axis=1) if nq > 1 else out_chunks[0]
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=None, cap=None,
                     scale=None):
    """Single-token attention against a cache.

    q: [B,1,H,hd]; k_cache/v_cache: [B,Smax,KV,hd]; pos: int32[B] index of the
    current token (cache entries > pos are invalid).
    """
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = (hd ** -0.5) if scale is None else scale
    qh = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = layers.softcap(s, cap)
    k_pos = jnp.arange(Smax)[None, :]  # [1, Smax]
    mask = k_pos <= pos[:, None]
    if window is not None:
        mask &= (pos[:, None] - k_pos) < window
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention_block(
    params, x, dims: AttnDims, *,
    positions=None, mrope_positions=None, rope_theta=10000.0,
    causal=True, window=None, cap=None, scale=None, use_rope=True,
    cache=None, cache_pos=None,
):
    """Full attention sub-layer: project -> rope -> attend -> out-proj.

    Train/prefill: cache=None -> flash path; returns (out, new_kv or None).
    Decode: cache=(k_cache, v_cache), cache_pos int32[B] -> dense path;
    returns (out, (k_cache, v_cache) updated).
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, x, dims)
    q, k, v = (layers.grad_cast(q), layers.grad_cast(k),
               layers.grad_cast(v))
    if use_rope:
        if mrope_positions is not None:
            q = layers.apply_mrope(q, mrope_positions, rope_theta)
            k = layers.apply_mrope(k, mrope_positions, rope_theta)
        else:
            if positions is None:
                positions = jnp.arange(T, dtype=jnp.int32)[None, :]
            q = layers.apply_rope(q, positions, rope_theta)
            k = layers.apply_rope(k, positions, rope_theta)

    if cache is None:
        o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                            scale=scale)
        new_cache = (k, v)
    else:
        k_cache, v_cache = cache
        # insert current k/v at cache_pos (T==1 decode). A where() over the
        # cache rewrites the whole buffer every step (EXPERIMENTS §Perf
        # deepseek decode iteration 1); the vmapped dynamic_update_slice
        # lowers to a scatter touching only the new token's row.
        bidx = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[bidx, cache_pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, cache_pos].set(v[:, 0].astype(v_cache.dtype))
        o = decode_attention(q, k_cache, v_cache, cache_pos, window=window,
                             cap=cap, scale=scale)
        new_cache = (k_cache, v_cache)
    out = o.reshape(B, T, -1) @ params["wo"]
    return out, new_cache


def cross_attention_block(params, x, dims: AttnDims, enc_kv, *, cap=None):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    B, T, _ = x.shape
    H, KV, hd = dims
    q = (x @ params["wq"]).reshape(B, T, H, hd)
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype).reshape(H, hd)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False, cap=cap)
    return o.reshape(B, T, -1) @ params["wo"]


def encode_kv(params, enc_out, dims: AttnDims):
    """Project encoder output into cross-attention K/V once per sequence."""
    B, S, _ = enc_out.shape
    H, KV, hd = dims
    k = (enc_out @ params["wk"]).reshape(B, S, KV, hd)
    v = (enc_out @ params["wv"]).reshape(B, S, KV, hd)
    if "bk" in params:
        k = k + params["bk"].astype(k.dtype).reshape(KV, hd)
        v = v + params["bv"].astype(v.dtype).reshape(KV, hd)
    return k, v
