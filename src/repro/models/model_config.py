"""Architecture configuration dataclass shared by all ten assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.models.attention import AttnDims
from repro.models.moe import MoEDims
from repro.models.ssm import SSMDims

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 1024

    norm: str = "rmsnorm"
    activation: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    mrope: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0      # >0: window size for "local" layers
    window_pattern: int = 0      # gemma2: group of N layers, first N-1 local
    post_norm: bool = False      # gemma2 post-layer norms
    embed_scale: bool = False    # gemma: scale embeddings by sqrt(d_model)
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 512
    capacity_factor: float = 1.25

    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    mamba_version: int = 1
    # 'sequential' (TPU-optimized persistent-state scan) or 'associative'
    # (paper-faithful log-depth scan) — see EXPERIMENTS.md §Perf.
    ssm_scan: str = "sequential"

    # Hybrid (zamba2): one weight-tied shared attention block applied at the
    # start of every group of `attn_every` SSM layers.
    attn_every: int = 0

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    source_len: int = 1500

    # VLM stub frontend
    num_vision_tokens: int = 0

    # Parallelism layout: "tp" (Megatron TP over the model axis) or
    # "ep" (MoE: pure DP over every axis + expert parallelism; no TP).
    parallelism: str = "tp"

    # Numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # none | full | dots
    loss_chunk: int = 1024       # CE computed in seq chunks of this size

    supports_long_context: bool = False  # sub-quadratic decode state
    # Unroll the layer loop in decode and keep each layer's KV cache as its
    # own donated buffer: scan-collected caches rewrite a full layer slice
    # per token (EXPERIMENTS §Perf deepseek decode iteration 2).
    unroll_decode: bool = True

    # ------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 512 (Megatron-style): keeps the
        embedding shardable by any mesh axis <=512 and MXU-aligned. Logit
        columns beyond vocab_size are masked in logits_fn."""
        return (self.vocab_size + 511) // 512 * 512

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def attn_dims(self) -> AttnDims:
        return AttnDims(self.num_heads, self.num_kv_heads, self.resolved_head_dim)

    @property
    def moe_dims(self) -> Optional[MoEDims]:
        if not self.num_experts:
            return None
        return MoEDims(self.num_experts, self.top_k, self.d_ff,
                       self.capacity_factor, self.moe_group_size)

    @property
    def ssm_dims(self) -> Optional[SSMDims]:
        if not self.ssm_state:
            return None
        return SSMDims(
            d_inner=self.ssm_expand * self.d_model,
            d_state=self.ssm_state,
            d_conv=self.ssm_conv,
            dt_rank=max(self.d_model // 16, 1),
            head_dim=self.ssm_head_dim,
            version=self.mamba_version,
        )

    @property
    def group_size(self) -> int:
        """Layers per scan group (pattern periodicity)."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.window_pattern:
            return self.window_pattern
        return 1

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"group size {self.group_size}")
        return self.num_layers // self.group_size

    def dtype(self, which: str):
        return _DTYPES[getattr(self, which + "_dtype")]

    def layer_is_local(self, idx_in_group: int) -> bool:
        """gemma2 pattern: local layers first in each group, last is global."""
        if not self.window_pattern or not self.sliding_window:
            return False
        return idx_in_group < self.window_pattern - 1

    # ---- parameter accounting for MODEL_FLOPS (6·N·D) ----
    def param_counts(self) -> Tuple[int, int]:
        """(total, active) non-embedding parameter counts."""
        D, F = self.d_model, self.d_ff
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        attn = D * (H + 2 * KV) * hd + H * hd * D
        total = active = 0
        if self.family in ("dense", "vlm"):
            mlp = 3 * D * F if self.activation in ("swiglu", "geglu") else 2 * D * F
            per = attn + mlp
            total = active = self.num_layers * per
        elif self.family == "moe":
            per_exp = 3 * D * F
            router = D * self.num_experts
            per_layer_total = attn + router + self.num_experts * per_exp
            per_layer_active = attn + router + self.top_k * per_exp
            total = self.num_layers * per_layer_total
            active = self.num_layers * per_layer_active
        elif self.family == "ssm":
            sd = self.ssm_dims
            di, N = sd.d_inner, sd.d_state
            per = (D * 2 * di + sd.d_conv * di + di * (sd.dt_rank + 2 * N)
                   + sd.dt_rank * di + di * N + di * D)
            total = active = self.num_layers * per
        elif self.family == "hybrid":
            sd = self.ssm_dims
            di, N = sd.d_inner, sd.d_state
            per = (D * 2 * di + sd.d_conv * di + D * 2 * N + D * sd.num_heads
                   + di * D)
            shared = attn  # one weight-tied block
            total = active = self.num_layers * per + shared
        elif self.family == "encdec":
            mlp = 2 * D * F
            enc = self.encoder_layers * (attn + mlp)
            dec = self.num_layers * (2 * attn + mlp)
            total = active = enc + dec
        embed = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return total + embed, active + embed
