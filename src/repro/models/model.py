"""Model facade: init / loss / prefill / decode for every architecture."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding
from repro.models import attention, layers, transformer
from repro.models.model_config import ModelConfig


def cast_params(params, cfg: ModelConfig):
    """Mixed precision: cast matrix params to the compute dtype; keep small
    vectors (norm scales, biases, SSM A/dt/D) in float32 for numerics."""
    cdt = cfg.dtype("compute")

    def cast(leaf):
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(cdt)
        return leaf

    return jax.tree_util.tree_map(cast, params)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return transformer.init_params(key, self.cfg)

    def abstract_params(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(lambda k: transformer.init_params(k, self.cfg),
                              key)

    # ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Chunked cross-entropy LM loss (logits never fully materialized)."""
        cfg = self.cfg
        params = cast_params(params, cfg)
        hidden, aux = transformer.forward_train(params, cfg, batch)
        labels = batch["labels"]
        B, T, D = hidden.shape
        c = min(cfg.loss_chunk, T)
        while T % c:
            c -= 1
        nc = T // c
        hidden = hidden.reshape(B, nc, c, D).swapaxes(0, 1)
        labels_c = labels.reshape(B, nc, c).swapaxes(0, 1)

        def ce_chunk(carry, xs):
            h, y = xs
            logits = transformer.logits_fn(params, cfg, h)  # [B, c, V] f32
            logits = sharding.constrain(logits, "dp", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
            return carry + jnp.sum(lse - gold), None

        total, _ = jax.lax.scan(
            ce_chunk, jnp.zeros((), jnp.float32), (hidden, labels_c))
        ntok = B * T
        loss = total / ntok + 0.01 * aux
        return loss, {"ce": total / ntok, "aux": aux}

    # ------------------------------------------------------------------
    def prefill(self, params, batch):
        """Forward over a full prompt; returns (last_logits, seq-length cache)."""
        cfg = self.cfg
        params = cast_params(params, cfg)
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        if cfg.family == "encdec":
            enc_out = transformer.encode(params, cfg, batch["frames"])
            x = transformer.embed_tokens(params, cfg, tokens)
            x = x + transformer._sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)
            x, self_kv = transformer.decode_stack(
                params, cfg, x, enc_out=enc_out, positions=positions)
            cache = {"kv": self_kv, "cross_kv": self._cross_kv(params, enc_out)}
        else:
            mrope = batch.get("mrope_positions") if cfg.mrope else None
            x = transformer.embed_tokens(params, cfg, tokens,
                                         batch.get("vision_embeds"))
            x, _, cache = transformer.backbone(
                params, cfg, x, positions=positions, mrope_positions=mrope,
                cache=None, cache_pos=None, collect=True)
        logits = transformer.logits_fn(params, cfg, x[:, -1:, :])
        return logits, cache

    def _cross_kv(self, params, enc_out):
        cfg = self.cfg

        def f(carry, p):
            return carry, attention.encode_kv(p["cross"], enc_out,
                                              cfg.attn_dims)

        _, ckv = jax.lax.scan(f, 0, params["blocks"])
        return ckv

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        """Zeroed decode cache sized for ``max_len`` positions."""
        cfg = self.cfg
        cdt = cfg.dtype("compute")
        nG, gl = cfg.num_groups, cfg.group_size
        H, KV, hd = cfg.attn_dims

        def kv(extra=()):
            shape = extra + (batch, max_len, KV, hd)
            return (jnp.zeros(shape, cdt), jnp.zeros(shape, cdt))

        if cfg.family in ("dense", "vlm", "moe"):
            if cfg.unroll_decode:
                extra = () if gl == 1 else (gl,)
                return {"kv": tuple(kv(extra) for _ in range(nG))}
            extra = (nG,) if gl == 1 else (nG, gl)
            return {"kv": kv(extra)}
        if cfg.family == "ssm":
            sd = cfg.ssm_dims
            conv, h = _ssm_zeros(sd, batch, nG, gl, cdt)
            return {"conv": conv, "h": h}
        if cfg.family == "hybrid":
            sd = cfg.ssm_dims
            conv, h = _ssm_zeros(sd, batch, nG, gl, cdt)
            return {"conv": conv, "h": h, "attn": kv((nG,))}
        if cfg.family == "encdec":
            L = cfg.num_layers
            return {
                "kv": kv((L,)),
                "cross_kv": (
                    jnp.zeros((L, batch, cfg.source_len, KV, hd), cdt),
                    jnp.zeros((L, batch, cfg.source_len, KV, hd), cdt),
                ),
            }
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------
    def decode_step(self, params, cache, tokens, pos):
        """One decode step. tokens: int32[B,1]; pos: int32[B]."""
        cfg = self.cfg
        params = cast_params(params, cfg)
        positions = pos[:, None]
        x = transformer.embed_tokens(params, cfg, tokens)
        if cfg.family == "encdec":
            x = x + jnp.take(
                transformer._sinusoidal(int(cache["kv"][0].shape[2]),
                                        cfg.d_model),
                pos, axis=0)[:, None, :].astype(x.dtype)
            x, new_kv = transformer.decode_stack(
                params, cfg, x, positions=positions, cache=cache["kv"],
                cache_pos=pos, cross_kv=cache["cross_kv"])
            new_cache = {"kv": new_kv, "cross_kv": cache["cross_kv"]}
        else:
            mrope = None
            if cfg.mrope:
                mrope = jnp.broadcast_to(pos[:, None, None],
                                         (pos.shape[0], 3, 1)).astype(jnp.int32)
            if (cfg.family in ("dense", "vlm", "moe") and cfg.unroll_decode
                    and isinstance(cache.get("kv"), tuple)):
                new_kv = []
                for g in range(cfg.num_groups):
                    gp = jax.tree_util.tree_map(lambda a: a[g],
                                                params["blocks"])
                    x, _, ncache = transformer.apply_group_external(
                        cfg, {}, gp, x, positions=positions,
                        mrope_positions=mrope,
                        group_cache={"kv": cache["kv"][g]}, cache_pos=pos)
                    new_kv.append(ncache["kv"])
                new_cache = {"kv": tuple(new_kv)}
            else:
                x, _, new_cache = transformer.backbone(
                    params, cfg, x, positions=positions,
                    mrope_positions=mrope, cache=cache, cache_pos=pos)
        logits = transformer.logits_fn(params, cfg, x)
        return logits, new_cache

    # ------------------------------------------------------------------
    def param_count(self, params=None) -> int:
        tree = params if params is not None else self.abstract_params()
        return sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(tree))


def _ssm_zeros(sd, batch, nG, gl, cdt):
    extra = (nG,) if gl == 1 else (nG, gl)
    conv = jnp.zeros(extra + (batch, sd.d_conv - 1, sd.d_inner), jnp.float32)
    if sd.version == 1:
        h = jnp.zeros(extra + (batch, sd.d_inner, sd.d_state), jnp.float32)
    else:
        h = jnp.zeros(extra + (batch, sd.num_heads, sd.head_dim, sd.d_state),
                      jnp.float32)
    return conv, h
