"""Mixture-of-Experts layer with GShard-style grouped one-hot dispatch.

The dispatch is deliberately the same TPU idiom as the market engine's order
aggregation (DESIGN.md §4): token->expert assignment is materialized as a
one-hot tensor and resolved with MXU contractions, and position-in-expert is
a *prefix scan* over the assignment mask — the paper's aggregation + scan
pattern applied to MoE routing.

Experts are sharded over the "model"/"expert" mesh axis (EP); groups over the
data axes. XLA inserts the all-to-alls from the sharding constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch import sharding
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEDims:
    num_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden width
    capacity_factor: float = 1.25
    group_size: int = 512      # tokens per dispatch group


def moe_init(key, d_model, dims: MoEDims, dtype=jnp.float32):
    E, F = dims.num_experts, dims.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": layers._init(ks[0], (d_model, E), dtype=jnp.float32),
        "we_gate": layers._init(ks[1], (E, d_model, F), dtype=dtype),
        "we_up": layers._init(ks[2], (E, d_model, F), dtype=dtype),
        "we_out": layers._init(ks[3], (E, F, d_model), dtype=dtype),
    }


def capacity(dims: MoEDims, tokens_per_group: int) -> int:
    c = int(tokens_per_group * dims.top_k * dims.capacity_factor / dims.num_experts)
    c = max(c, 4)
    return (c + 3) // 4 * 4  # pad to a multiple of 4 lanes


def moe_apply(params, x, dims: MoEDims):
    """x: [B, T, D] -> ([B, T, D], aux_loss scalar)."""
    B, T, D = x.shape
    E, K = dims.num_experts, dims.top_k
    n_tokens = B * T
    g = min(dims.group_size, n_tokens)
    while n_tokens % g:
        g -= 1
    G = n_tokens // g
    C = capacity(dims, g)

    xt = x.reshape(G, g, D)
    # §Perf kimi iteration 1: groups over dp ONLY (a 256-way dp_sp group
    # sharding forces SPMD into replicate-then-repartition against the
    # (model x data)-sharded expert weights).
    xt = sharding.constrain(xt, "dp", None, None)

    # Router matmul in the compute dtype (an f32 cast here materializes a
    # hidden-sized f32 tensor per layer); softmax still in f32.
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)     # [G, g, K]
    # renormalize selected gates
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # GShard slot-by-slot position assignment (prefix scan over the mask —
    # the paper's aggregation pattern).
    dispatch = jnp.zeros((G, g, E, C), dtype=x.dtype)
    combine = jnp.zeros((G, g, E, C), dtype=jnp.float32)
    counts_so_far = jnp.zeros((G, 1, E), jnp.float32)
    slots = jnp.arange(C, dtype=jnp.float32)
    for j in range(K):
        mask_j = jax.nn.one_hot(expert_idx[..., j], E, dtype=jnp.float32)
        pos_j = jnp.cumsum(mask_j, axis=1) - mask_j + counts_so_far  # [G,g,E]
        counts_so_far = counts_so_far + mask_j.sum(axis=1, keepdims=True)
        within = (pos_j < C) & (mask_j > 0)
        oh_pos = (pos_j[..., None] == slots) & within[..., None]  # [G,g,E,C]
        dispatch = dispatch + oh_pos.astype(x.dtype)
        combine = combine + oh_pos.astype(jnp.float32) * gate_vals[..., j, None, None]

    # Dispatch: one-hot contraction onto expert slots (MXU binning).
    slots_in = jnp.einsum("gtec,gtd->gecd", dispatch, xt)  # [G, E, C, D]
    slots_in = sharding.constrain(slots_in, "dp_data", "tp", None, None)

    h = jnp.einsum("gecd,edf->gecf", slots_in,
                   params["we_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", slots_in,
                   params["we_up"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    slots_out = jnp.einsum("gecf,efd->gecd", h,
                           params["we_out"].astype(x.dtype))
    slots_out = sharding.constrain(slots_out, "dp_data", "tp", None, None)

    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), slots_out)
    y = sharding.constrain(y, "dp", None, None)

    # Load-balancing auxiliary loss (Switch/GShard form).
    top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac_tokens = top1.mean(axis=1)          # [G, E]
    frac_probs = probs.mean(axis=1)          # [G, E]
    aux = (frac_tokens * frac_probs).sum(axis=-1).mean() * E
    return y.reshape(B, T, D), aux


def moe_param_counts(d_model, dims: MoEDims):
    """(total, active) parameter counts for MODEL_FLOPS accounting."""
    per_expert = 3 * d_model * dims.d_ff
    total = dims.num_experts * per_expert + d_model * dims.num_experts
    active = dims.top_k * per_expert + d_model * dims.num_experts
    return total, active
