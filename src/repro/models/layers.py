"""Shared neural layers (functional, explicit param pytrees)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0] if len(shape) > 1 else 1.0)
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_cv(x, scale, eps):
    y, _ = _rmsnorm_fwd(x, scale, eps)
    return y


def _rmsnorm_fwd(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    y = (x32 * rstd * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    return y, (x, scale, rstd)


def _rmsnorm_bwd(eps, res, g):
    # Compact backward (EXPERIMENTS §Perf kimi iteration 2): autodiff of the
    # f32-internal forward materializes ~8 hidden-sized f32 tensors per norm
    # (and forces f32 TP all-reduces of cotangents); this hand-written VJP
    # keeps the boundary tensors in the compute dtype and saves rstd instead
    # of recomputing the variance.
    x, scale, rstd = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    s1 = 1.0 + scale.astype(jnp.float32)
    gy = g32 * s1
    proj = jnp.mean(gy * x32, axis=-1, keepdims=True)  # [..., 1] f32
    dx = (rstd * (gy - x32 * (proj * rstd * rstd))).astype(x.dtype)
    dscale = jnp.sum(g32 * x32 * rstd,
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx, dscale


_rmsnorm_cv.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(params, x, eps=1e-6):
    return _rmsnorm_cv(x, params["scale"], eps)


@jax.custom_vjp
def grad_cast(x):
    """Identity whose cotangent is cast back to the primal dtype.

    Placed at TP boundaries (e.g. q/k/v projection outputs) it keeps f32
    accumulation *inside* attention while guaranteeing the dgrad dots, their
    weight all-gathers, and the dX all-reduces run in the compute dtype —
    i.e. structural bf16 gradient compression (EXPERIMENTS §Perf).
    """
    return x


def _gc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)


def _gc_bwd(marker, g):
    return (g.astype(marker.dtype),)


grad_cast.defvjp(_gc_fwd, _gc_bwd)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Softcap (gemma2)
# ---------------------------------------------------------------------------
def softcap(x, cap: Optional[float]):
    if cap is None or cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    angles = angles[..., None, :]  # add head axis -> [..., T, 1, hd/2]
    # Trig in f32; rotation applied in the compute dtype. An f32 rotation
    # here turns the q/k/v projection dgrads (and their TP all-reduces) f32
    # (EXPERIMENTS §Perf kimi iteration 3).
    sin = jnp.sin(angles).astype(x.dtype)
    cos = jnp.cos(angles).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def apply_mrope(x, positions_thw, theta: float = 10000.0,
                sections=(0.25, 0.375, 0.375)):
    """Multimodal RoPE (Qwen2-VL §3): rotary dims split into (t, h, w) sections.

    positions_thw: int32[..., 3, T] — temporal / height / width position ids
    (for pure text all three are the token index). Each section of the
    frequency spectrum rotates by its own coordinate.
    """
    hd = x.shape[-1]
    half = hd // 2
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    n_w = half - n_t - n_h
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [half]
    pos_t = positions_thw[..., 0, :]
    pos_h = positions_thw[..., 1, :]
    pos_w = positions_thw[..., 2, :]
    # Build per-dim positions by section.
    sec = jnp.concatenate([
        jnp.zeros((n_t,), jnp.int32),
        jnp.ones((n_h,), jnp.int32),
        jnp.full((n_w,), 2, jnp.int32),
    ])
    pos_stack = jnp.stack([pos_t, pos_h, pos_w], axis=-1)  # [..., T, 3]
    pos_per_dim = jnp.take_along_axis(
        pos_stack[..., None, :],  # [..., T, 1, 3]
        jnp.broadcast_to(sec[None, :, None], pos_stack.shape[:-1] + (half, 1)),
        axis=-1,
    )[..., 0]  # [..., T, half]
    angles = pos_per_dim.astype(jnp.float32) * freqs  # [..., T, half]
    angles = angles[..., None, :]
    sin = jnp.sin(angles).astype(x.dtype)
    cos = jnp.cos(angles).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / vanilla GELU)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, activation="swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_out": _init(ks[2], (d_ff, d_model), dtype=dtype)}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = _init(ks[0], (d_model, d_ff), dtype=dtype)
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
    else:
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(params, x, activation="swiglu"):
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_out"]
    if activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"], approximate=True) * (x @ params["w_up"])
        return h @ params["w_out"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"], approximate=False)
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embed_init(key, vocab, d_model, dtype=jnp.float32):
    # ~N(0, d^-1/2): keeps tied-unembedding logits O(1) at init
    return {"table": _init(key, (vocab, d_model), scale=d_model ** -0.5,
                           dtype=dtype)}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, *, tied_table=None):
    table = tied_table if tied_table is not None else params["table"]
    return x @ table.astype(x.dtype).T
