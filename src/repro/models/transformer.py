"""Model assembly: decoder-only / MoE / SSM / hybrid / enc-dec, train & decode.

Layers are scanned in *groups* (the pattern periodicity: 1 for homogeneous
stacks, 2 for gemma2 local/global, 6 for zamba2's shared-attention cadence)
with per-group stacked parameters, which keeps the compiled HLO independent
of depth. Decode threads per-group caches through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding
from repro.models import attention, layers, moe, ssm
from repro.models.model_config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, idx_in_group: int):
    """Params of one layer (attention/moe/ssm mixer + mlp + norms)."""
    dt = cfg.dtype("param")
    ninit, _ = layers.make_norm(cfg.norm)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "moe"):
        p["attn_norm"] = ninit(cfg.d_model, dt)
        p["attn"] = attention.attn_init(ks[0], cfg.d_model, cfg.attn_dims,
                                        qkv_bias=cfg.qkv_bias, dtype=dt)
        p["mlp_norm"] = ninit(cfg.d_model, dt)
        if cfg.family == "moe":
            p["moe"] = moe.moe_init(ks[1], cfg.d_model, cfg.moe_dims, dtype=dt)
        else:
            p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                       cfg.activation, dtype=dt)
        if cfg.post_norm:
            p["attn_post_norm"] = ninit(cfg.d_model, dt)
            p["mlp_post_norm"] = ninit(cfg.d_model, dt)
    elif cfg.family in ("ssm", "hybrid"):
        p["ssm_norm"] = ninit(cfg.d_model, dt)
        p["ssm"] = ssm.ssm_init(ks[0], cfg.d_model, cfg.ssm_dims, dtype=dt)
    else:
        raise ValueError(cfg.family)
    return p


def _group_init(key, cfg: ModelConfig):
    gl = cfg.group_size
    ks = jax.random.split(key, gl)
    return [_block_init(ks[i], cfg, i) for i in range(gl)]


def _stack_groups(groups):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


def init_params(key, cfg: ModelConfig):
    dt = cfg.dtype("param")
    ninit, _ = layers.make_norm(cfg.norm)
    keys = jax.random.split(key, cfg.num_groups + 8)
    p: Dict[str, Any] = {
        "embed": layers.embed_init(keys[-1], cfg.padded_vocab_size,
                                   cfg.d_model, dt),
        "final_norm": ninit(cfg.d_model, dt),
    }
    if cfg.family != "encdec":
        p["blocks"] = _stack_groups(
            [_group_init(keys[g], cfg) for g in range(cfg.num_groups)])
    if not cfg.tie_embeddings:
        p["head"] = layers.embed_init(keys[-2], cfg.padded_vocab_size,
                                      cfg.d_model, dt)
    if cfg.family == "hybrid":
        p["shared_attn_norm"] = ninit(cfg.d_model, dt)
        p["shared_attn"] = attention.attn_init(
            keys[-3], cfg.d_model, cfg.attn_dims, qkv_bias=cfg.qkv_bias,
            dtype=dt)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[-4], cfg.encoder_layers)
        p["enc_blocks"] = _stack_groups(
            [_enc_block_init(k, cfg) for k in enc_keys])
        p["enc_final_norm"] = ninit(cfg.d_model, dt)
        dec_keys = jax.random.split(keys[-5], cfg.num_layers)
        p["blocks"] = _stack_groups([_dec_block_init(k, cfg) for k in dec_keys])
    return p


def _enc_block_init(key, cfg: ModelConfig):
    dt = cfg.dtype("param")
    ninit, _ = layers.make_norm(cfg.norm)
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": ninit(cfg.d_model, dt),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn_dims,
                                    qkv_bias=True, dtype=dt),
        "mlp_norm": ninit(cfg.d_model, dt),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                               dtype=dt),
    }


def _dec_block_init(key, cfg: ModelConfig):
    dt = cfg.dtype("param")
    ninit, _ = layers.make_norm(cfg.norm)
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": ninit(cfg.d_model, dt),
        "attn": attention.attn_init(ks[0], cfg.d_model, cfg.attn_dims,
                                    qkv_bias=True, dtype=dt),
        "cross_norm": ninit(cfg.d_model, dt),
        "cross": attention.attn_init(ks[1], cfg.d_model, cfg.attn_dims,
                                     qkv_bias=True, dtype=dt),
        "mlp_norm": ninit(cfg.d_model, dt),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.activation,
                               dtype=dt),
    }


# ---------------------------------------------------------------------------
# Blocks (train/prefill and decode share code; cache=None => train)
# ---------------------------------------------------------------------------
def _norm(cfg):
    return layers.make_norm(cfg.norm)[1]


def _apply_attn_layer(cfg, p, x, *, local, positions, mrope_positions,
                      cache, cache_pos):
    nfn = _norm(cfg)
    window = cfg.sliding_window if local else None
    cap = cfg.attn_softcap or None
    h, new_cache = attention.attention_block(
        p["attn"], nfn(p["attn_norm"], x), cfg.attn_dims,
        positions=positions, mrope_positions=mrope_positions,
        rope_theta=cfg.rope_theta, causal=True, window=window, cap=cap,
        use_rope=cfg.use_rope, cache=cache, cache_pos=cache_pos)
    if cfg.post_norm:
        h = nfn(p["attn_post_norm"], h)
    return x + h, new_cache


def _apply_mlp_layer(cfg, p, x):
    nfn = _norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h, aux = moe.moe_apply(p["moe"], nfn(p["mlp_norm"], x), cfg.moe_dims)
    else:
        h = layers.mlp_apply(p["mlp"], nfn(p["mlp_norm"], x), cfg.activation)
    if cfg.post_norm:
        h = nfn(p["mlp_post_norm"], h)
    return x + h, aux


def _apply_group(cfg, shared, group_params, x, *, positions, mrope_positions,
                 group_cache, cache_pos, collect=False):
    """One scan group: cfg.group_size layers (+ optional shared attention).

    Modes: train (group_cache=None, collect=False, caches discarded),
    prefill (group_cache=None, collect=True, seq-length caches returned),
    decode (group_cache=Smax-slot cache, cache_pos=current position).
    """
    keep_cache = collect or group_cache is not None
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    nfn = _norm(cfg)

    if cfg.family == "hybrid":
        # Shared (weight-tied) attention block at the head of every group.
        attn_cache = None if group_cache is None else group_cache["attn"]
        h, attn_cache = attention.attention_block(
            shared["shared_attn"], nfn(shared["shared_attn_norm"], x),
            cfg.attn_dims, positions=positions, rope_theta=cfg.rope_theta,
            causal=True, use_rope=cfg.use_rope,
            cache=attn_cache, cache_pos=cache_pos)
        x = x + h
        if keep_cache:
            new_cache["attn"] = attn_cache

    def _slice_group_cache(name, i):
        if group_cache is None:
            return None
        entry = group_cache[name]
        if cfg.group_size > 1:
            return jax.tree_util.tree_map(lambda a: a[i], entry)
        return entry

    layer_caches = []
    for i in range(cfg.group_size):
        p = group_params[i]
        if cfg.family in ("dense", "vlm", "moe"):
            x, kv = _apply_attn_layer(
                cfg, p, x, local=cfg.layer_is_local(i), positions=positions,
                mrope_positions=mrope_positions,
                cache=_slice_group_cache("kv", i), cache_pos=cache_pos)
            x, aux = _apply_mlp_layer(cfg, p, x)
            aux_total = aux_total + aux
            layer_caches.append(kv)
        else:  # ssm / hybrid
            cache_i = None
            if group_cache is not None:
                cache_i = (_slice_group_cache("conv", i),
                           _slice_group_cache("h", i))
            h, new_ssm = ssm.ssm_apply(
                p["ssm"], nfn(p["ssm_norm"], x), cfg.ssm_dims, cache=cache_i,
                scan_mode=cfg.ssm_scan)
            x = x + h
            layer_caches.append(new_ssm)
    x = sharding.constrain(x, "dp", None, None)

    if keep_cache:
        def _restack(entries):
            if cfg.group_size > 1:
                return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *entries)
            return entries[0]

        if cfg.family in ("dense", "vlm", "moe"):
            new_cache["kv"] = _restack(layer_caches)
        else:
            new_cache["conv"] = _restack([c[0] for c in layer_caches])
            new_cache["h"] = _restack([c[1] for c in layer_caches])
    return x, aux_total, (new_cache if keep_cache else None)


def apply_group_external(cfg, shared, group_params, x, *, positions,
                         mrope_positions, group_cache, cache_pos):
    """Public entry for the unrolled decode path (model.decode_step)."""
    return _apply_group(cfg, shared, group_params, x, positions=positions,
                        mrope_positions=mrope_positions,
                        group_cache=group_cache, cache_pos=cache_pos)


def _shared_params(params, cfg: ModelConfig):
    if cfg.family == "hybrid":
        return {"shared_attn": params["shared_attn"],
                "shared_attn_norm": params["shared_attn_norm"]}
    return {}


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def backbone(params, cfg: ModelConfig, x, *, positions=None,
             mrope_positions=None, cache=None, cache_pos=None, collect=False):
    """Scan the block stack. x: [B, T, D]. Returns (x, aux, new_cache)."""
    shared = _shared_params(params, cfg)

    def group_fn(carry, xs):
        x = carry
        gp, gcache = xs
        x, aux, ncache = _apply_group(
            cfg, shared, gp, x,
            positions=positions, mrope_positions=mrope_positions,
            group_cache=gcache, cache_pos=cache_pos, collect=collect)
        return x, (aux, ncache)

    group_fn = _remat_wrap(cfg, group_fn)
    x, (auxes, new_cache) = jax.lax.scan(
        group_fn, x, (params["blocks"], cache))
    return x, jnp.sum(auxes), new_cache


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------
def _sinusoidal(T, D):
    pos = np.arange(T)[:, None]
    i = np.arange(D // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / D)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def encode(params, cfg: ModelConfig, frames):
    """frames: [B, S_src, D] precomputed conv-frontend embeddings (STUB)."""
    nfn = _norm(cfg)
    x = frames.astype(cfg.dtype("compute"))
    x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)

    def enc_fn(x, p):
        h, _ = attention.attention_block(
            p["attn"], nfn(p["attn_norm"], x), cfg.attn_dims,
            causal=False, use_rope=False)
        x = x + h
        x = x + layers.mlp_apply(p["mlp"], nfn(p["mlp_norm"], x),
                                 cfg.activation)
        return x, None

    enc_fn = _remat_wrap(cfg, enc_fn)
    x, _ = jax.lax.scan(enc_fn, x, params["enc_blocks"])
    return nfn(params["enc_final_norm"], x)


def decode_stack(params, cfg: ModelConfig, x, enc_out=None, *, positions=None,
                 cache=None, cache_pos=None, cross_kv=None):
    """Whisper decoder stack (self + cross attention)."""
    nfn = _norm(cfg)

    def dec_fn(x, xs):
        p = xs[0]
        self_cache = xs[1] if cache is not None else None
        ckv = xs[2] if cross_kv is not None else None
        h, new_kv = attention.attention_block(
            p["attn"], nfn(p["attn_norm"], x), cfg.attn_dims,
            positions=positions, causal=True, use_rope=False,
            cache=self_cache, cache_pos=cache_pos)
        x = x + h
        if ckv is None:
            ckv_local = attention.encode_kv(p["cross"], enc_out, cfg.attn_dims)
        else:
            ckv_local = ckv
        x = x + attention.cross_attention_block(
            p["cross"], nfn(p["cross_norm"], x), cfg.attn_dims, ckv_local)
        x = x + layers.mlp_apply(p["mlp"], nfn(p["mlp_norm"], x),
                                 cfg.activation)
        return x, new_kv

    dec_fn = _remat_wrap(cfg, dec_fn)
    xs = (params["blocks"],)
    xs += ((cache,) if cache is not None else (None,))
    xs += ((cross_kv,) if cross_kv is not None else (None,))
    x, new_cache = jax.lax.scan(dec_fn, x, xs)
    return x, new_cache


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg: ModelConfig, tokens, vision_embeds=None):
    x = layers.embed_apply(params["embed"], tokens).astype(cfg.dtype("compute"))
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if vision_embeds is not None and cfg.num_vision_tokens:
        nv = cfg.num_vision_tokens
        x = x.at[:, :nv, :].set(vision_embeds.astype(x.dtype))
    return sharding.constrain(x, "dp", None, None)


def logits_fn(params, cfg: ModelConfig, x):
    nfn = _norm(cfg)
    x = nfn(params["final_norm"], x)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["table"])
    logits = x @ table.astype(x.dtype).T
    logits = layers.softcap(logits.astype(jnp.float32),
                            cfg.final_softcap or None)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # mask vocab-padding columns (Megatron-style padded embedding)
        col = jnp.arange(cfg.padded_vocab_size)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def forward_train(params, cfg: ModelConfig, batch):
    """Teacher-forced forward. Returns (hidden [B,T,D], aux)."""
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"])
        x = embed_tokens(params, cfg, tokens)
        x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)
        x, _ = decode_stack(params, cfg, x, enc_out=enc_out,
                            positions=positions)
        return x, jnp.zeros((), jnp.float32)
    mrope = batch.get("mrope_positions") if cfg.mrope else None
    x = embed_tokens(params, cfg, tokens, batch.get("vision_embeds"))
    x, aux, _ = backbone(params, cfg, x, positions=positions,
                         mrope_positions=mrope)
    return x, aux
