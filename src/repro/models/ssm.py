"""Mamba1 / Mamba2 state-space layers — the LM-side instance of the paper's
persistent, state-carrying reduction pattern (DESIGN.md §4).

Training uses *chunked* scans: the recurrent state is carried across chunk
boundaries (the "persistent state" of the pattern) while intra-chunk work is
either a log-depth associative scan (Mamba1) or a dense MXU-friendly
decay-weighted matmul (Mamba2 / SSD) — the same Θ(T) -> Θ(T/C + log C) depth
transformation the paper applies to auction clearing.

Decoding carries (conv_state, ssm_state) in O(1) memory — no KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_inner: int
    d_state: int
    d_conv: int = 4
    dt_rank: int = 0            # mamba1 only
    head_dim: int = 64          # mamba2 only
    version: int = 1

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def ssm_init(key, d_model, dims: SSMDims, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    di, N = dims.d_inner, dims.d_state
    p = {
        "in_proj": layers._init(ks[0], (d_model, 2 * di), dtype=dtype),
        "conv_w": layers._init(ks[1], (dims.d_conv, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "out_proj": layers._init(ks[2], (di, d_model), dtype=dtype),
        "D_skip": jnp.ones((di if dims.version == 1 else dims.num_heads,), jnp.float32),
    }
    if dims.version == 1:
        R = dims.dt_rank
        p["x_proj"] = layers._init(ks[3], (di, R + 2 * N), dtype=dtype)
        p["dt_proj"] = layers._init(ks[4], (R, di), dtype=dtype)
        p["dt_bias"] = jnp.zeros((di,), jnp.float32)
        # S4D-real init: A = -(1..N) per channel
        p["A_log"] = jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)))
    else:
        nh = dims.num_heads
        p["bc_proj"] = layers._init(ks[3], (d_model, 2 * N), dtype=dtype)
        p["dt_in"] = layers._init(ks[4], (d_model, nh), dtype=dtype)
        p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
        p["A_log"] = jnp.zeros((nh,), jnp.float32)  # A = -exp(0) = -1
        p["norm_scale"] = jnp.zeros((di,), dtype)
    return p


# ---------------------------------------------------------------------------
# Depthwise causal conv via taps (decode-friendly)
# ---------------------------------------------------------------------------
def _causal_conv(x, w, b, conv_state=None):
    """x: [B, T, di]; w: [K, di]; conv_state: [B, K-1, di] or None.

    Returns (y, new_conv_state). new_conv_state holds the last K-1 inputs.
    """
    K = w.shape[0]
    if conv_state is not None:
        xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        xx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    T = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xx[:, i:i + T, :] * w[i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_state = xx[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu(y), new_state


# ---------------------------------------------------------------------------
# Mamba1: chunked selective scan (per-channel decay)
# ---------------------------------------------------------------------------
def _ssm1_params(params, x, dims: SSMDims):
    R, N = dims.dt_rank, dims.d_state
    dbc = x @ params["x_proj"]                       # [B, T, R+2N]
    dt_raw, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dt_raw @ params["dt_proj"] + params["dt_bias"].astype(x.dtype))
    A = -jnp.exp(params["A_log"])                    # [di, N]
    return dt.astype(jnp.float32), Bc.astype(jnp.float32), Cc.astype(jnp.float32), A


def _scan_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, b1 * a2 + b2


def mamba1_scan(x, dt, Bc, Cc, A, h0, chunk: int = 64,
                mode: str = "sequential"):
    """Chunked selective scan. x:[B,T,di] f32; dt:[B,T,di]; Bc/Cc:[B,T,N];
    A:[di,N]; h0:[B,di,N]. Returns (y [B,T,di], h_final).

    Modes (EXPERIMENTS.md §Perf, falcon-mamba iteration 1):
      * 'associative' — log-depth Hillis-Steele scan over the chunk. Matches
        the paper's depth analysis but XLA materializes ~2*log2(c) chunk-
        sized (B,c,di,N) tensors per stage -> the memory roofline term is
        ~10x the useful traffic.
      * 'sequential' — time-major lax.scan with the state as a (B,di,N)
        carry, vectorized over (B,di,N). This is the paper's persistent-
        state pattern mapped to TPU: per-step parallelism B*di*N >> VPU
        width, so the Θ(T) depth costs nothing while HBM traffic collapses
        to the inputs/outputs (+ small carry).
    """
    B, T, di = x.shape
    N = A.shape[-1]

    if mode == "sequential":
        # NOTE (§Perf falcon-mamba iteration 2, REFUTED): time-blocking with
        # unrolled+checkpointed inner steps was tried here and measured
        # WORSE (23.3s vs 17.3s memory term) — XLA materializes each
        # unrolled step's (B,di,N) tensor anyway and the checkpoint
        # recompute doubles the traffic. The per-step scan below is the best
        # XLA-level form; the remaining gap to the traffic floor is closed
        # by the Pallas persistent-state kernel (kernels/ssm_scan.py).
        def t_step(h, inp):
            xt, dtt, bct, cct = inp                  # [B,di],[B,di],[B,N],[B,N]
            decay = jnp.exp(dtt[..., None] * A)      # [B, di, N]
            h = decay * h + (dtt * xt)[..., None] * bct[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, cct)
            return h, y

        xs = tuple(a.swapaxes(0, 1) for a in (x, dt, Bc, Cc))
        h_final, ys = jax.lax.scan(t_step, h0, xs)
        return ys.swapaxes(0, 1), h_final

    c = min(chunk, T)
    while T % c:
        c -= 1
    nc = T // c

    def chunk_step(h, inp):
        xc, dtc, bcc, ccc = inp                      # [B, c, ...]
        decay = jnp.exp(dtc[..., None] * A)          # [B, c, di, N]
        inc = (dtc * xc)[..., None] * bcc[:, :, None, :]  # [B, c, di, N]
        # log-depth intra-chunk associative scan (the paper's H-S analogue)
        a_run, b_run = jax.lax.associative_scan(
            _scan_combine, (decay, inc), axis=1)
        h_all = a_run * h[:, None] + b_run           # [B, c, di, N]
        y = jnp.einsum("bcdn,bcn->bcd", h_all, ccc)
        return h_all[:, -1], y

    xs = tuple(a.reshape((B, nc, c) + a.shape[2:]).swapaxes(0, 1)
               for a in (x, dt, Bc, Cc))
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, T, di)
    return y, h_final


def mamba1_apply(params, x_in, dims: SSMDims, cache=None, chunk: int = 64,
                 mode: str = "sequential"):
    """Full mamba1 mixer. x_in: [B, T, D]. cache: None or (conv_state, h)."""
    xz = x_in @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                 # [B, T, di]
    conv_state = cache[0] if cache is not None else None
    x, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"], conv_state)
    dt, Bc, Cc, A = _ssm1_params(params, x, dims)
    x32 = x.astype(jnp.float32)
    Bsz = x.shape[0]
    h0 = (cache[1] if cache is not None
          else jnp.zeros((Bsz, dims.d_inner, dims.d_state), jnp.float32))
    y, h = mamba1_scan(x32, dt, Bc, Cc, A, h0, chunk=chunk, mode=mode)
    y = y + params["D_skip"] * x32
    y = (y.astype(x_in.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, (new_conv, h)


# ---------------------------------------------------------------------------
# Mamba2 (SSD): scalar decay per head, dense intra-chunk matmul form
# ---------------------------------------------------------------------------
def mamba2_apply(params, x_in, dims: SSMDims, cache=None, chunk: int = 128):
    """SSD layer. x_in: [B, T, D]. cache: (conv_state, h [B,nh,hd,N])."""
    B, T, D = x_in.shape
    di, N, nh, hd = dims.d_inner, dims.d_state, dims.num_heads, dims.head_dim

    xz = x_in @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache[0] if cache is not None else None
    x, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"], conv_state)

    bc = (x_in @ params["bc_proj"]).astype(jnp.float32)       # [B, T, 2N]
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        (x_in @ params["dt_in"]).astype(jnp.float32)
        + params["dt_bias"])                                  # [B, T, nh]
    A = -jnp.exp(params["A_log"])                             # [nh]

    xh = x.astype(jnp.float32).reshape(B, T, nh, hd)
    c = min(chunk, T)
    while T % c:
        c -= 1
    nc = T // c

    log_a = dt * A                                            # [B, T, nh] (<0)

    def chunk_step(h, inp):
        xc, dtc, bcc, ccc, la = inp   # [B,c,nh,hd], [B,c,nh], [B,c,N], [B,c,N], [B,c,nh]
        cum = jnp.cumsum(la, axis=1)                          # [B, c, nh]
        # L[t,s] = exp(cum[t] - cum[s]) for s<=t  (segment-sum decay matrix)
        diff = cum[:, :, None, :] - cum[:, None, :, :]        # [B, c, c, nh]
        mask = jnp.tril(jnp.ones((c, c), bool))
        Lmat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        # intra-chunk: Y_intra = (C B^T ⊙ L) · (dt ⊙ X)
        cb = jnp.einsum("btn,bsn->bts", ccc, bcc)             # [B, c, c]
        w = cb[..., None] * Lmat                              # [B, c, c, nh]
        xdt = xc * dtc[..., None]                             # [B, c, nh, hd]
        y = jnp.einsum("btsh,bshp->bthp", w, xdt)             # [B, c, nh, hd]
        # inter-chunk: contribution of carried state
        decay_to_t = jnp.exp(cum)                             # [B, c, nh]
        y = y + jnp.einsum("btn,bhpn,bth->bthp",
                           ccc, h, decay_to_t)
        # update carried state: h' = exp(sum la) h + sum_s exp(cum[-1]-cum[s]) dt_s x_s B_s^T
        tail = jnp.exp(cum[:, -1:, :] - cum)                  # [B, c, nh]
        h_new = (jnp.exp(cum[:, -1])[:, :, None, None] * h
                 + jnp.einsum("bshp,bsn,bsh->bhpn", xdt, bcc, tail))
        return h_new, y

    xs = (
        xh.reshape(B, nc, c, nh, hd).swapaxes(0, 1),
        dt.reshape(B, nc, c, nh).swapaxes(0, 1),
        Bc.reshape(B, nc, c, N).swapaxes(0, 1),
        Cc.reshape(B, nc, c, N).swapaxes(0, 1),
        log_a.reshape(B, nc, c, nh).swapaxes(0, 1),
    )
    h0 = (cache[1] if cache is not None
          else jnp.zeros((B, nh, hd, N), jnp.float32))
    h, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, T, nh, hd)
    y = y + params["D_skip"][:, None] * xh
    y = y.reshape(B, T, di).astype(x_in.dtype) * jax.nn.silu(z)
    y = layers.rmsnorm({"scale": params["norm_scale"]}, y)
    return y @ params["out_proj"], (new_conv, h)


def ssm_apply(params, x, dims: SSMDims, cache=None,
              chunk: Optional[int] = None, scan_mode: str = "sequential"):
    if dims.version == 1:
        return mamba1_apply(params, x, dims, cache=cache,
                            chunk=chunk or 64, mode=scan_mode)
    return mamba2_apply(params, x, dims, cache=cache, chunk=chunk or 128)


def ssm_cache_shape(dims: SSMDims, batch: int):
    conv = (batch, dims.d_conv - 1, dims.d_inner)
    if dims.version == 1:
        h = (batch, dims.d_inner, dims.d_state)
    else:
        h = (batch, dims.num_heads, dims.head_dim, dims.d_state)
    return conv, h
