"""Bounded fan-out bus: per-client queues with explicit backpressure.

The simulation side of the gateway must never block on a consumer — the
paper's warm step loop is the asset being served, and one slow WebSocket
reader stalling it would stall *every* client. So delivery is strictly
non-blocking: each subscriber owns a bounded ``asyncio.Queue`` and
:meth:`FrameBus.publish` uses ``put_nowait`` only. When a queue is full
the subscription's policy decides:

  * ``"drop-oldest"`` (default) — evict the oldest queued frame, count it
    (``frames_dropped_total`` + per-client ``dropped``), enqueue the new
    one. A stalled client loses history but reconverges on the live edge;
    frame ``seq`` gaps tell it exactly what it missed.
  * ``"disconnect"``  — close the subscription with a ``closed`` event
    (reason ``"backpressure"``). Strictest latency guarantee: a client
    that can't keep up is shed rather than served stale data.

Either way the publisher returns in O(1) per subscriber and the step loop
never waits — the property the stalled-client test in
``tests/test_serve.py`` asserts with a deliberately frozen consumer.
"""
from __future__ import annotations

import asyncio
import itertools
from typing import Any, Dict, Iterable, Optional, Tuple

from repro.serve.frames import Event, Frame

#: Queue policies a subscription may choose from.
POLICIES = ("drop-oldest", "disconnect")

#: Sentinel pushed to wake a consumer after close() (never user-visible).
_CLOSED = object()


class Subscription:
    """One client's bounded view of the bus (an async iterator).

    Yields :class:`Frame` and :class:`Event` objects in publish order.
    Iteration ends after a ``closed`` event (which is still delivered) or
    :meth:`close`.
    """

    def __init__(self, bus: "FrameBus", client: str, slot: int,
                 maxsize: int, policy: str) -> None:
        if policy not in POLICIES:
            raise ValueError(
                f"unknown backpressure policy {policy!r}; have {POLICIES}")
        self.bus = bus
        self.client = client
        self.slot = slot
        self.policy = policy
        self.queue: asyncio.Queue = asyncio.Queue(maxsize=max(1, maxsize))
        self.dropped = 0          # frames evicted by drop-oldest
        self.delivered = 0        # messages handed to the consumer
        self.closed = False

    def qsize(self) -> int:
        return self.queue.qsize()

    # ---- producer side (called by FrameBus only; never blocks) ----
    def _offer(self, item: Any) -> None:
        if self.closed:
            return
        try:
            self.queue.put_nowait(item)
        except asyncio.QueueFull:
            if self.policy == "drop-oldest":
                try:
                    evicted = self.queue.get_nowait()
                except asyncio.QueueEmpty:   # consumer raced us; retry once
                    evicted = None
                if isinstance(evicted, (Frame, Event)):
                    self.dropped += 1
                    self.bus._on_drop(self)
                try:
                    self.queue.put_nowait(item)
                except asyncio.QueueFull:
                    self.dropped += 1
                    self.bus._on_drop(self)
            else:  # disconnect: shed the slow client, keep the loop hot
                self.bus.close_subscription(
                    self, reason="backpressure",
                    detail=f"queue full at {self.queue.maxsize}")

    def _force(self, item: Any) -> None:
        """Deliver a control item even over a full queue (evicting a frame
        if needed) so ``closed``/``reconnect`` events are never lost."""
        try:
            self.queue.put_nowait(item)
        except asyncio.QueueFull:
            try:
                evicted = self.queue.get_nowait()
                if isinstance(evicted, (Frame, Event)):
                    self.dropped += 1
            except asyncio.QueueEmpty:
                pass
            try:
                self.queue.put_nowait(item)
            except asyncio.QueueFull:
                pass

    # ---- consumer side ----
    async def get(self) -> Optional[Any]:
        """Next frame/event, or ``None`` once the subscription is closed
        and drained."""
        while True:
            if self.closed and self.queue.empty():
                return None
            item = await self.queue.get()
            if item is _CLOSED:
                continue  # wake-up marker; loop re-checks closed+empty
            self.delivered += 1
            return item

    def __aiter__(self) -> "Subscription":
        return self

    async def __anext__(self):
        item = await self.get()
        if item is None:
            raise StopAsyncIteration
        return item

    def close(self) -> None:
        self.bus.close_subscription(self, reason="client")


class FrameBus:
    """Routes per-slot frames and broadcast events to subscribers.

    All methods must run on the event-loop thread (the gateway publishes
    from its async step loop after the executor hop); the data structures
    are plain dicts, and non-blocking puts are the only queue operations.
    An optional :class:`repro.ops.metrics.MetricsRegistry` receives the
    gateway series documented in :mod:`repro.ops.metrics`.
    """

    def __init__(self, metrics=None) -> None:
        self.metrics = metrics
        self._subs: Dict[str, Subscription] = {}
        self._ids = itertools.count()

    # ---- membership ----
    def subscribe(self, slot: int, *, client: Optional[str] = None,
                  maxsize: int = 8,
                  policy: str = "drop-oldest") -> Subscription:
        name = client if client is not None else f"client-{next(self._ids)}"
        if name in self._subs:
            raise ValueError(f"client id {name!r} already subscribed")
        sub = Subscription(self, name, slot, maxsize, policy)
        self._subs[name] = sub
        if self.metrics is not None:
            self.metrics.inc("sessions_opened_total")
            self.metrics.gauge("clients_connected", len(self._subs))
        return sub

    def close_subscription(self, sub: Subscription, *, reason: str,
                           detail: str = "") -> None:
        if sub.closed:
            return
        sub.closed = True
        self._subs.pop(sub.client, None)
        sub._force(Event("closed", {"reason": reason, "detail": detail,
                                    "client": sub.client}))
        # Wake a blocked get() — plain put, never evicting: a consumer can
        # only be blocked when the queue is empty, and evicting here could
        # displace the closed event itself on a maxsize-1 queue.
        try:
            sub.queue.put_nowait(_CLOSED)
        except asyncio.QueueFull:
            pass
        if self.metrics is not None:
            self.metrics.inc("sessions_closed_total")
            self.metrics.gauge("clients_connected", len(self._subs))
            self.metrics.gauge(f"queue_depth.{sub.client}", 0)

    def close_all(self, reason: str = "shutdown") -> None:
        for sub in list(self._subs.values()):
            self.close_subscription(sub, reason=reason)

    # ---- introspection ----
    @property
    def clients(self) -> Tuple[str, ...]:
        return tuple(self._subs)

    def subscribers_of(self, slot: int) -> Tuple[Subscription, ...]:
        return tuple(s for s in self._subs.values() if s.slot == slot)

    def queue_depths(self) -> Dict[str, int]:
        return {name: sub.qsize() for name, sub in self._subs.items()}

    # ---- delivery (producer side; never blocks, never awaits) ----
    def publish(self, frames: Iterable[Tuple[int, Frame]]) -> int:
        """Fan one chunk's ``(slot, frame)`` pairs out to every subscriber
        of each slot; returns the number of frames enqueued."""
        by_slot: Dict[int, Frame] = dict(frames)
        published = 0
        for sub in list(self._subs.values()):
            frame = by_slot.get(sub.slot)
            if frame is None:
                continue
            sub._offer(frame)
            published += 1
        if self.metrics is not None:
            self.metrics.inc("frames_published_total", published)
            for name, sub in self._subs.items():
                self.metrics.gauge(f"queue_depth.{name}", sub.qsize())
        return published

    def broadcast(self, event: Event) -> None:
        """Deliver a control event to every subscriber (never dropped)."""
        for sub in list(self._subs.values()):
            sub._force(event)

    def _on_drop(self, sub: Subscription) -> None:
        if self.metrics is not None:
            self.metrics.inc("frames_dropped_total")
            self.metrics.inc(f"frames_dropped.{sub.client}")
