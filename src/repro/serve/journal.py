"""Durable splice journal: WAL for the gateway's slot mutations.

The serving gateway mutates the running ensemble exclusively through
coalesced :meth:`Session.swap_markets` splices at chunk boundaries. PR 7
kept the splice record in memory, which covers *device* loss (the process
survives and replays its own list) but not *process* death. This module
makes the record durable: an append-only, fsync'd JSON-lines file living
next to the checkpoint ladder, written **before** the splice is applied
(write-ahead ordering), so a gateway restart can

  1. restore the newest committed checkpoint (step ``r``),
  2. replay every journaled splice with boundary ``t >= r`` at its
     original chunk boundary, and
  3. resume each client stream bitwise — the engine's determinism
     (RNG keyed on (seed, market, step, channel)) does the rest.

Entries carry the full replacement :class:`~repro.core.params.EnsembleSpec`
bitwise (base64 of each leaf's raw bytes + dtype/shape), because "the same
scenario label" is not enough for bitwise replay once ``with_values`` or
custom configs are in play.

Durability cost sits on the engine thread (one line + ``fsync`` per
splice) but splices are *rare* — admission events, not per-chunk work —
so this never touches steady-state chunk latency.

Compaction (the checkpoint GC hook): entries older than the oldest
retained checkpoint can never be replayed (every restore starts at a
committed step ``>=`` that) and are dropped by :meth:`compact`, which
rewrites the file crash-atomically via the checkpoint module's
tmp + fsync + rename primitive. Appends and compaction may race (engine
thread vs checkpoint-writer thread) — an internal lock serializes them.

A torn *trailing* line (process died mid-append) is tolerated and
dropped on read: the splice it described was never applied before the
crash, per the write-ahead ordering... unless it was — in which case the
restored checkpoint predates it only if the checkpoint ladder lost a
race it cannot lose (checkpoints only commit at chunk boundaries already
past the splice). Any *non-trailing* damage raises
:class:`JournalCorruptError` — silent partial replay would break the
bitwise guarantee.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.manager import _durable_write
from repro.core.params import EnsembleSpec, MarketParams

JOURNAL_NAME = "splices.journal"


class JournalCorruptError(IOError):
    """A non-trailing journal line is damaged — replay would be partial."""


def _array_to_wire(arr: np.ndarray) -> dict:
    arr = np.ascontiguousarray(arr)
    return {"b64": base64.b64encode(arr.tobytes()).decode("ascii"),
            "dtype": str(arr.dtype), "shape": list(arr.shape)}


def _array_from_wire(wire: dict) -> np.ndarray:
    return np.frombuffer(
        base64.b64decode(wire["b64"]), dtype=np.dtype(wire["dtype"]),
    ).reshape(wire["shape"]).copy()


def spec_to_wire(spec: EnsembleSpec) -> dict:
    """Bitwise-exact JSON encoding of an :class:`EnsembleSpec`."""
    return {
        "num_markets": spec.num_markets, "num_agents": spec.num_agents,
        "num_levels": spec.num_levels, "num_steps": spec.num_steps,
        "seed": spec.seed,
        "params": {f: _array_to_wire(np.asarray(getattr(spec.params, f)))
                   for f in MarketParams._fields},
        "initial_quote_qty": _array_to_wire(
            np.asarray(spec.initial_quote_qty)),
        "initial_spread": _array_to_wire(np.asarray(spec.initial_spread)),
        "scenarios": list(spec.scenarios),
    }


def spec_from_wire(wire: dict) -> EnsembleSpec:
    return EnsembleSpec(
        num_markets=int(wire["num_markets"]),
        num_agents=int(wire["num_agents"]),
        num_levels=int(wire["num_levels"]),
        num_steps=int(wire["num_steps"]),
        seed=int(wire["seed"]),
        params=MarketParams(**{f: _array_from_wire(wire["params"][f])
                               for f in MarketParams._fields}),
        initial_quote_qty=_array_from_wire(wire["initial_quote_qty"]),
        initial_spread=_array_from_wire(wire["initial_spread"]),
        scenarios=tuple(wire["scenarios"]),
    )


@dataclasses.dataclass(frozen=True)
class SpliceEntry:
    """One journaled splice: apply ``spec`` to ``slots`` at boundary ``t``.

    ``labels`` records, per slot, the client-visible scenario label (or
    None for a detach-to-parked row) so a restart can rebuild the slot
    scheduler's attachment table without guessing from ``spec.scenarios``.
    """

    t: int                              # step boundary the splice landed on
    slots: Tuple[int, ...]
    labels: Tuple[Optional[str], ...]   # per-slot attachment label
    spec: EnsembleSpec                  # replacement rows (len(slots) markets)

    def to_json(self) -> str:
        return json.dumps({"t": self.t, "slots": list(self.slots),
                           "labels": list(self.labels),
                           "spec": spec_to_wire(self.spec)},
                          separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "SpliceEntry":
        obj = json.loads(line)
        return cls(t=int(obj["t"]), slots=tuple(obj["slots"]),
                   labels=tuple(obj["labels"]),
                   spec=spec_from_wire(obj["spec"]))


class SpliceJournal:
    """Append-only fsync'd splice log next to the checkpoint ladder."""

    def __init__(self, directory):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / JOURNAL_NAME
        self._lock = threading.Lock()
        self._fh = None
        self.appended = 0       # entries appended by this process
        self.compactions = 0

    # -- write side (engine thread) ------------------------------------
    def append(self, entry: SpliceEntry) -> None:
        """Durably append one entry (line + flush + fsync) — called
        *before* the splice is applied to the live session (WAL order)."""
        line = entry.to_json() + "\n"
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line)
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.appended += 1

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def reset(self) -> None:
        """Drop every entry (fresh checkpoint ladder: a journal left by a
        process that died before its step-0 anchor committed has nothing
        to replay onto)."""
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            if self.path.exists():
                self.path.unlink()

    # -- read side (restart / recovery) --------------------------------
    def entries(self) -> List[SpliceEntry]:
        """All journaled splices, oldest first.

        Tolerates a torn trailing line (crash mid-append: that splice was
        never applied). Damage anywhere else raises
        :class:`JournalCorruptError` — partial replay must never load.
        """
        with self._lock:
            if not self.path.exists():
                return []
            raw = self.path.read_text(encoding="utf-8")
        lines = raw.split("\n")
        # A complete file ends with "\n" → last element is "". Anything
        # else in the final slot is a torn tail.
        torn_tail = lines.pop() if lines else ""
        out: List[SpliceEntry] = []
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(SpliceEntry.from_json(line))
            except (json.JSONDecodeError, KeyError, ValueError) as exc:
                raise JournalCorruptError(
                    f"splice journal line {i + 1} is damaged "
                    f"({type(exc).__name__}: {exc}); refusing partial "
                    "replay") from exc
        if torn_tail.strip():
            try:
                out.append(SpliceEntry.from_json(torn_tail))
            except (json.JSONDecodeError, KeyError, ValueError):
                pass  # torn trailing append: the splice never applied
        return out

    # -- compaction (checkpoint-writer thread, via on_gc) ---------------
    def compact(self, oldest_retained_step: int) -> int:
        """Drop entries with ``t < oldest_retained_step``; returns the
        number dropped.

        Safe because every restore starts from a committed checkpoint
        ``>= oldest_retained_step``, and a splice at boundary ``t`` is
        already baked into any checkpoint taken at a step ``> t`` (the
        journal is written before the splice, the splice before the
        steps that follow it). The rewrite is crash-atomic (tmp + fsync +
        rename), so a crash mid-compaction leaves the old journal intact.
        """
        with self._lock:
            if not self.path.exists():
                return 0
            raw = self.path.read_text(encoding="utf-8")
            lines = [ln for ln in raw.split("\n") if ln.strip()]
            keep: List[str] = []
            dropped = 0
            for ln in lines:
                try:
                    t = int(json.loads(ln)["t"])
                except (json.JSONDecodeError, KeyError, ValueError):
                    keep.append(ln)  # torn tail: preserved, read-side drops
                    continue
                if t < oldest_retained_step:
                    dropped += 1
                else:
                    keep.append(ln)
            if not dropped:
                return 0
            # Close the append handle around the rename so later appends
            # reopen the new inode rather than the unlinked one.
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            _durable_write(self.path,
                           ("\n".join(keep) + "\n" if keep else "").encode())
            self.compactions += 1
            return dropped

    def __len__(self) -> int:
        return len(self.entries())
