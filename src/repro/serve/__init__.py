"""Streaming serving gateway: multi-tenant sessions over one warm engine.

Public surface:

  * :class:`Gateway` / :class:`ClientSession` / :func:`parked_template` —
    the serving core (in-process transport);
  * :class:`SlotScheduler` / :class:`GatewayFull` /
    :class:`GatewayRecovering` / :class:`GatewayDegraded` — slot
    multiplexing + typed admission refusals;
  * :class:`SpliceJournal` / :class:`SpliceEntry` — the durable
    write-ahead splice log (process-crash recovery + bitwise restart);
  * :class:`FrameBus` / :class:`Subscription` — bounded backpressure bus;
  * :class:`Frame` / :class:`Event` / :func:`decode` — wire shapes;
  * :class:`DoubleBuffer` — the lag-one device→host pipeline;
  * :class:`HealthServer` (and, with the optional ``websockets`` package,
    :class:`WebSocketServer`) in :mod:`repro.serve.transport`.

``Engine.warm()`` runs inside :meth:`Gateway.start` before the first
frame — serving never pays a compile, and ``Gateway.traces_delta`` stays
0 for any mixture of client scenarios (the shape-semantic cache
guarantee; CI asserts it).
"""
from repro.serve.bus import POLICIES, FrameBus, Subscription
from repro.serve.frames import Event, Frame, decode, slice_frames
from repro.serve.gateway import ClientSession, Gateway, parked_template
from repro.serve.journal import SpliceEntry, SpliceJournal
from repro.serve.pipeline import DoubleBuffer
from repro.serve.slots import (GatewayDegraded, GatewayFull,
                               GatewayRecovering, SlotScheduler)

__all__ = [
    "POLICIES", "FrameBus", "Subscription",
    "Event", "Frame", "decode", "slice_frames",
    "ClientSession", "Gateway", "parked_template",
    "SpliceEntry", "SpliceJournal",
    "DoubleBuffer",
    "GatewayDegraded", "GatewayFull", "GatewayRecovering", "SlotScheduler",
]
