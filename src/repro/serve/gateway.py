"""The streaming serving gateway: many client sessions, one warm engine.

``Gateway`` multiplexes N concurrent client sessions onto ONE persistent
ensemble session (the paper's compile-once, device-resident regime) so the
marginal cost of a client is a slot assignment, never a compile:

  * admission   — :class:`repro.serve.slots.SlotScheduler` maps each client
    onto an ensemble row; attach/detach land as ONE coalesced
    ``Session.swap_markets`` splice per chunk boundary (zero retraces —
    the shape-semantic trace cache guarantees it, the gateway asserts it);
  * the hot loop — a dedicated single engine thread dispatches chunk after
    chunk; a lag-one :class:`repro.serve.pipeline.DoubleBuffer` materializes
    chunk ``k-1`` on host while chunk ``k`` computes on device, so
    streaming never blocks the next chunk's dispatch;
  * fan-out     — per-chunk :class:`repro.serve.frames.Frame` slices go
    through :class:`repro.serve.bus.FrameBus` with bounded per-client
    queues and non-blocking delivery (drop-oldest or disconnect), so a
    stalled consumer can never stall the simulation or other clients;
  * operations  — ``Engine.warm`` runs before serving (no client request
    ever pays a compile), :meth:`health` wraps ``Engine.readiness`` for the
    HTTP probe, every gateway series lands in the session's
    :class:`~repro.ops.metrics.MetricsRegistry`, and optional periodic
    checkpoints make device-loss recovery (:meth:`inject_fault`) bitwise:
    a splice journal replays post-checkpoint attach/detach at their
    original boundaries, so the post-``reconnect`` stream equals a
    fault-free run's.

In-process transport (tests, benchmarks, and same-process consumers)::

    gw = Gateway(parked_template(slots=32, num_agents=64, num_levels=64,
                                 num_steps=10_000), backend="jax-scan")
    await gw.start()
    cs = gw.open_session("flash-crash")      # attach -> next chunk boundary
    async for frame in cs.subscription:       # Frames + control Events
        ...
    await gw.stop()

Real sockets are one layer up in :mod:`repro.serve.transport` (HTTP health
endpoint; WebSocket fan-out when the ``websockets`` package is present).
"""
from __future__ import annotations

import asyncio
import itertools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.config import MarketConfig, scenario_config
from repro.core.params import EnsembleSpec
from repro.core.session import Engine, Session, StepBatch
from repro.serve.bus import FrameBus, Subscription
from repro.serve.frames import Event, Frame, slice_frames
from repro.serve.pipeline import DoubleBuffer
from repro.serve.slots import GatewayFull, SlotScheduler  # noqa: F401


def parked_template(slots: int, *, num_agents: int, num_levels: int,
                    num_steps: int, seed: int = 0) -> EnsembleSpec:
    """An all-parked ``slots``-market serving template.

    The template fixes the static shape — and therefore the one warm trace
    — every client session will share; clients vary only the per-market
    parameter rows. ``num_steps`` is the horizon scenario events are
    validated against (the gateway itself streams indefinitely).
    """
    like = EnsembleSpec.homogeneous(scenario_config(
        "baseline", num_markets=slots, num_agents=num_agents,
        num_levels=num_levels, num_steps=num_steps, seed=seed))
    return EnsembleSpec.parked(like, slots)


class ClientSession:
    """One client's handle: a slot assignment + a bounded frame queue."""

    def __init__(self, gateway: "Gateway", sub: Subscription) -> None:
        self._gateway = gateway
        self.subscription = sub
        self.events: List[Event] = []    # control events seen by frames()

    @property
    def client(self) -> str:
        return self.subscription.client

    @property
    def slot(self) -> int:
        return self.subscription.slot

    @property
    def closed(self) -> bool:
        return self.subscription.closed

    async def next_frame(self) -> Optional[Frame]:
        """Next data frame (control events are recorded on ``.events``);
        ``None`` once the subscription is closed and drained."""
        while True:
            item = await self.subscription.get()
            if item is None:
                return None
            if isinstance(item, Event):
                self.events.append(item)
                if item.kind == "closed":
                    return None
                continue
            return item

    async def frames(self, n: int) -> List[Frame]:
        """Collect the next ``n`` data frames."""
        out: List[Frame] = []
        while len(out) < n:
            frame = await self.next_frame()
            if frame is None:
                break
            out.append(frame)
        return out

    def close(self) -> None:
        self._gateway.close_session(self)


class Gateway:
    """Asyncio serving gateway over one warm :class:`Engine` session.

    ``template`` is the serving ensemble (see :func:`parked_template`);
    its market count is the session capacity. ``queue_maxsize``/``policy``
    set the default per-client backpressure bounds
    (:mod:`repro.serve.bus`); ``ckpt_dir`` + ``checkpoint_every`` (in
    chunks) enable the fault-recovery path. All public methods must be
    called from the event-loop thread; device work runs on a dedicated
    single-thread executor ("the engine thread") so the loop stays
    responsive — and consumers keep draining — while chunks compute.
    """

    def __init__(self, template: Union[EnsembleSpec, MarketConfig],
                 backend: str = "jax-scan", *, chunk_size: int = 16,
                 queue_maxsize: int = 8, policy: str = "drop-oldest",
                 ckpt_dir: Optional[Any] = None, checkpoint_every: int = 0,
                 metrics: bool = True,
                 engine_opts: Optional[Dict[str, Any]] = None) -> None:
        self.template = EnsembleSpec.coerce(template)
        self.backend = backend
        self.chunk = int(chunk_size)
        self.queue_maxsize = int(queue_maxsize)
        self.policy = policy
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt_dir = ckpt_dir
        self._ckpt = None
        self._metrics_enabled = bool(metrics)
        self._engine_opts = dict(engine_opts or {})
        self.engine: Optional[Engine] = None
        self.session: Optional[Session] = None
        self.scheduler = SlotScheduler(self.template)
        self.bus: Optional[FrameBus] = None
        self.metrics = None
        self._buffer: Optional[DoubleBuffer] = None
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine")
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._seq = itertools.count()
        self._chunks_remaining: Optional[int] = None
        self._warm_traces = 0
        self._pending_faults: List[Any] = []
        self._sessions: Dict[str, ClientSession] = {}
        # Splice journal: (boundary step, slots, sub-spec) of every applied
        # swap, so fault recovery can replay post-checkpoint attach/detach
        # at their original boundaries (bitwise resume).
        self._splices: List[Tuple[int, Tuple[int, ...], EnsembleSpec]] = []

    # ---- lifecycle ----
    async def start(self, chunks: Optional[int] = None) -> None:
        """Warm the engine, open the serving session, start the step loop.

        ``Engine.warm`` runs *before* the first frame so no client request
        ever pays a compile (``traces_delta`` stays 0 from here on — the
        invariant CI's serve smoke asserts). ``chunks`` bounds the run for
        tests/benchmarks; ``None`` streams until :meth:`stop`.
        """
        if self._running:
            raise RuntimeError("gateway already started")
        loop = asyncio.get_running_loop()
        self._chunks_remaining = chunks
        await loop.run_in_executor(self._exec, self._open_engine,
                                   self._engine_opts)
        self.bus = FrameBus(metrics=self.metrics)
        self._running = True
        self._task = asyncio.create_task(self._run_loop(), name="gateway")

    def _open_engine(self, engine_opts: Dict[str, Any]) -> None:
        """(engine thread) Build + warm the engine, open the session, and
        take the step-0 checkpoint anchor on *first* start (recovery keeps
        the existing checkpoint ladder — the anchor must never be
        overwritten with a fresh template state)."""
        self.engine = Engine(self.backend, chunk_size=self.chunk,
                             metrics=self._metrics_enabled, **engine_opts)
        ready = self.engine.warm(self.template, include_step=False)
        assert ready.ready, f"warm() left cold keys: {ready.cold_keys()}"
        self.session = self.engine.open(self.template)
        if self.metrics is None:
            self.metrics = self.session.metrics
        else:
            self.session.metrics = self.metrics   # lifetime series survive
        if self.bus is not None:
            self.bus.metrics = self.metrics
        self._warm_traces = self.engine.trace_count
        self._buffer = DoubleBuffer(self._to_host)
        if self._ckpt_dir is not None and self._ckpt is None:
            from repro.checkpoint.manager import CheckpointManager

            self._ckpt = CheckpointManager(self._ckpt_dir, keep=64,
                                           async_write=False)
            self.session.save_checkpoint(self._ckpt)

    async def stop(self) -> None:
        """Stop the step loop, flush the pipeline tail, close every
        client."""
        self._running = False
        if self._task is not None:
            await self._task
            self._task = None
        self._exec.shutdown(wait=True)
        if self.session is not None:
            self.session.close()

    @property
    def traces_delta(self) -> int:
        """Traces since warm — 0 is the serving invariant."""
        return (self.engine.trace_count - self._warm_traces
                if self.engine is not None else 0)

    @property
    def step_count(self) -> int:
        return self.session.step_count if self.session is not None else 0

    def health(self) -> Dict[str, Any]:
        """The health-endpoint payload, backed by ``Engine.readiness()``."""
        ready = self.engine is not None and self.engine.readiness().ready
        return {
            "ready": bool(ready and self._running),
            "running": self._running,
            "backend": self.backend,
            "slots": self.scheduler.num_slots,
            "slots_attached": len(self.scheduler.attached),
            "slots_free": self.scheduler.free,
            "clients": len(self._sessions),
            "step": self.step_count,
            "traces_delta": self.traces_delta,
        }

    # ---- client admission (in-process front door) ----
    def open_session(self, spec: Union[str, MarketConfig, EnsembleSpec],
                     *, maxsize: Optional[int] = None,
                     policy: Optional[str] = None,
                     client: Optional[str] = None) -> ClientSession:
        """Attach a client's market; frames start at the next chunk
        boundary. Raises :class:`GatewayFull` when every slot is taken and
        ``ValueError`` when the spec disagrees with the template's static
        fields."""
        if not self._running:
            raise RuntimeError("gateway is not running; await start() first")
        slot = self.scheduler.attach(spec)
        sub = self.bus.subscribe(
            slot, client=client,
            maxsize=self.queue_maxsize if maxsize is None else maxsize,
            policy=self.policy if policy is None else policy)
        sub._force(Event("attached", {
            "slot": slot, "client": sub.client,
            "scenario": self.scheduler.label(slot),
            "first_step": self.step_count}))
        cs = ClientSession(self, sub)
        self._sessions[sub.client] = cs
        if self.metrics is not None:
            self.metrics.gauge("slots_attached",
                               len(self.scheduler.attached))
        return cs

    def close_session(self, cs: ClientSession) -> None:
        """Detach the client's slot (parked at the next boundary) and close
        its queue."""
        self._sessions.pop(cs.client, None)
        if cs.slot in self.scheduler.attached:
            self.scheduler.detach(cs.slot)
        self.bus.close_subscription(cs.subscription, reason="detach")
        if self.metrics is not None:
            self.metrics.gauge("slots_attached",
                               len(self.scheduler.attached))

    # ---- fault injection (the chaos tier's entry point) ----
    def inject_fault(self, fault: Any) -> None:
        """Queue a :class:`repro.ops.chaos.DeviceLoss` for the next chunk
        boundary; requires ``ckpt_dir`` (recovery restores the newest
        loadable checkpoint and replays quietly, so client streams resume
        bitwise)."""
        if self._ckpt is None:
            raise RuntimeError(
                "fault recovery needs ckpt_dir= (no checkpoint to restore)")
        self._pending_faults.append(fault)

    # ---- the step loop ----
    async def _run_loop(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while self._running and self._chunks_remaining != 0:
                if self._pending_faults:
                    fault = self._pending_faults.pop(0)
                    # The in-flight chunk completed pre-fault: deliver it
                    # before tearing the engine down, so no frame is lost.
                    tail = await loop.run_in_executor(self._exec,
                                                      self._buffer.flush)
                    if tail is not None:
                        self._complete(tail)
                    resume = await loop.run_in_executor(
                        self._exec, self._recover, fault)
                    self.bus.broadcast(Event("reconnect", {
                        "resume_step": resume, "step": self.step_count,
                        "fault": type(fault).__name__}))
                    if self.metrics is not None:
                        self.metrics.inc("reconnects_total")
                done = await loop.run_in_executor(self._exec,
                                                  self._advance_once)
                if done is not None:
                    self._complete(done)
                if self._chunks_remaining is not None:
                    self._chunks_remaining -= 1
            tail = None if self._buffer is None else self._buffer.flush()
            if tail is not None:
                self._complete(tail)
        finally:
            self._running = False
            if self.bus is not None:
                self.bus.close_all("shutdown")

    def _advance_once(self):
        """(engine thread) Apply pending slot splices, dispatch one chunk,
        and hand back the *previous* chunk still device-side (the lag-one
        pipeline; materialization happens in :meth:`_complete`)."""
        sess = self.session
        spliced = self.scheduler.drain(sess)   # coalesced boundary swap
        if spliced is not None:
            self._splices.append((sess.step_count,) + spliced)
        seq = next(self._seq)
        step0 = sess.step_count
        t0 = time.perf_counter()
        batch = sess.run(self.chunk)   # async dispatch on jax/pallas
        stats = sess.stats             # host copy; None unless stats_only
        meta = (seq, step0, self.chunk, t0, self.scheduler.attached)
        done = self._buffer.push(meta, (batch, stats))
        if (self._ckpt is not None and self.checkpoint_every
                and (seq + 1) % self.checkpoint_every == 0):
            sess.save_checkpoint(self._ckpt)
        return done

    def _to_host(self, payload: Tuple[StepBatch, Any]):
        batch, stats = payload
        return batch.to_numpy(), stats

    def _complete(self, done) -> None:
        """(event loop) Record a finished chunk's latency and fan it out;
        queue puts are non-blocking, so this never stalls the loop."""
        (seq, step0, n, t0, slots), payload = done
        if self.metrics is not None:
            self.metrics.observe_window("chunk_latency_seconds",
                                        time.perf_counter() - t0)
        host_batch, stats = payload
        self.bus.publish(slice_frames(host_batch, stats, slots, seq,
                                      step0, n))

    def _recover(self, fault) -> int:
        """(engine thread) Device-loss recovery under live client load.

        Rebuild the engine on the surviving topology (``devices_after`` /
        ``lost_device``, as in :class:`repro.ops.chaos.DeviceLoss`),
        restore the newest loadable checkpoint (walking the ladder past
        corrupt steps), then replay *quietly* back to the pre-fault cursor
        — re-applying journaled slot splices at their original boundaries
        — so published streams continue bitwise after the ``reconnect``
        event. Returns the step the session resumed from.
        """
        from repro.ops.chaos import _restore_resilient

        target = self.session.step_count
        self.session.close()
        new_opts = dict(self._engine_opts)
        new_opts.pop("devices", None)
        new_opts.pop("mesh", None)
        devices_after = getattr(fault, "devices_after", None)
        lost_device = getattr(fault, "lost_device", None)
        if devices_after is not None:
            new_opts["devices"] = devices_after
        elif lost_device is not None:
            from repro.launch.mesh import make_markets_mesh

            new_opts["mesh"] = make_markets_mesh(skip=(lost_device,))
        self._engine_opts = new_opts
        self._open_engine(new_opts)
        errors: List[str] = []
        resumed = _restore_resilient(self.session, self._ckpt, errors)
        # Quiet replay: the checkpoint predates some splices — re-apply
        # each at its original boundary while running the lost chunks.
        replay = [(t, slots, sub) for t, slots, sub in self._splices
                  if resumed <= t < target]
        for t, slots, sub in replay:
            while self.session.step_count < t:
                self.session.run(min(self.chunk,
                                     t - self.session.step_count))
            self.session.swap_markets(list(slots), sub)
        while self.session.step_count < target:
            self.session.run(min(self.chunk,
                                 target - self.session.step_count))
        return resumed
