"""The streaming serving gateway: many client sessions, one warm engine.

``Gateway`` multiplexes N concurrent client sessions onto ONE persistent
ensemble session (the paper's compile-once, device-resident regime) so the
marginal cost of a client is a slot assignment, never a compile:

  * admission   — :class:`repro.serve.slots.SlotScheduler` maps each client
    onto an ensemble row; attach/detach land as ONE coalesced
    ``Session.swap_markets`` splice per chunk boundary (zero retraces —
    the shape-semantic trace cache guarantees it, the gateway asserts it);
  * the hot loop — a dedicated single engine thread dispatches chunk after
    chunk; a lag-one :class:`repro.serve.pipeline.DoubleBuffer` materializes
    chunk ``k-1`` on host while chunk ``k`` computes on device, so
    streaming never blocks the next chunk's dispatch;
  * fan-out     — per-chunk :class:`repro.serve.frames.Frame` slices go
    through :class:`repro.serve.bus.FrameBus` with bounded per-client
    queues and non-blocking delivery (drop-oldest or disconnect), so a
    stalled consumer can never stall the simulation or other clients;
  * durability  — with ``ckpt_dir`` set, periodic checkpoints go through
    the :class:`~repro.checkpoint.manager.CheckpointManager` **async
    writer**: the engine thread only mirrors device state to host;
    serialization, fsync, and the atomic ``COMMIT`` rename happen on a
    background thread with a lag-bounded latest-wins mailbox (skipped
    saves are counted, never queued). Every applied slot splice is
    appended to a durable :class:`~repro.serve.journal.SpliceJournal`
    *before* it is applied (write-ahead), so both in-process recovery and
    a full **process crash + restart** resume every client stream bitwise:
    restore the newest committed checkpoint, replay journaled splices at
    their original boundaries, keep streaming (clients re-subscribe via
    :meth:`resume_session`).
  * resilience  — recovery is a supervised state machine
    (``serving → recovering → serving`` or ``→ degraded``): queued faults
    coalesce into ONE recovery, each attempt retries with exponential
    backoff + jitter up to ``max_recovery_attempts``, admission is paused
    (typed :class:`~repro.serve.slots.GatewayRecovering`) while recovering,
    and an exhausted retry budget degrades the gateway to a read-only
    health endpoint (503; :class:`~repro.serve.slots.GatewayDegraded` on
    admission) instead of crashing.

In-process transport (tests, benchmarks, and same-process consumers)::

    gw = Gateway(parked_template(slots=32, num_agents=64, num_levels=64,
                                 num_steps=10_000), backend="jax-scan")
    await gw.start()
    cs = gw.open_session("flash-crash")      # attach -> next chunk boundary
    async for frame in cs.subscription:       # Frames + control Events
        ...
    await gw.stop()

Real sockets are one layer up in :mod:`repro.serve.transport` (HTTP health
endpoint; WebSocket fan-out when the ``websockets`` package is present).
"""
from __future__ import annotations

import asyncio
import itertools
import random
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.config import MarketConfig, scenario_config
from repro.core.params import EnsembleSpec
from repro.core.session import Engine, Session, StepBatch
from repro.serve.bus import FrameBus, Subscription
from repro.serve.frames import Event, Frame, slice_frames
from repro.serve.journal import SpliceEntry, SpliceJournal
from repro.serve.pipeline import DoubleBuffer
from repro.serve.slots import (GatewayDegraded, GatewayFull,  # noqa: F401
                               GatewayRecovering, SlotScheduler)


def parked_template(slots: int, *, num_agents: int, num_levels: int,
                    num_steps: int, seed: int = 0) -> EnsembleSpec:
    """An all-parked ``slots``-market serving template.

    The template fixes the static shape — and therefore the one warm trace
    — every client session will share; clients vary only the per-market
    parameter rows. ``num_steps`` is the horizon scenario events are
    validated against (the gateway itself streams indefinitely).
    """
    like = EnsembleSpec.homogeneous(scenario_config(
        "baseline", num_markets=slots, num_agents=num_agents,
        num_levels=num_levels, num_steps=num_steps, seed=seed))
    return EnsembleSpec.parked(like, slots)


class ClientSession:
    """One client's handle: a slot assignment + a bounded frame queue."""

    def __init__(self, gateway: "Gateway", sub: Subscription) -> None:
        self._gateway = gateway
        self.subscription = sub
        self.events: List[Event] = []    # control events seen by frames()

    @property
    def client(self) -> str:
        return self.subscription.client

    @property
    def slot(self) -> int:
        return self.subscription.slot

    @property
    def closed(self) -> bool:
        return self.subscription.closed

    async def next_frame(self) -> Optional[Frame]:
        """Next data frame (control events are recorded on ``.events``);
        ``None`` once the subscription is closed and drained."""
        while True:
            item = await self.subscription.get()
            if item is None:
                return None
            if isinstance(item, Event):
                self.events.append(item)
                if item.kind == "closed":
                    return None
                continue
            return item

    async def frames(self, n: int) -> List[Frame]:
        """Collect the next ``n`` data frames."""
        out: List[Frame] = []
        while len(out) < n:
            frame = await self.next_frame()
            if frame is None:
                break
            out.append(frame)
        return out

    def close(self) -> None:
        self._gateway.close_session(self)


class Gateway:
    """Asyncio serving gateway over one warm :class:`Engine` session.

    ``template`` is the serving ensemble (see :func:`parked_template`);
    its market count is the session capacity. ``queue_maxsize``/``policy``
    set the default per-client backpressure bounds
    (:mod:`repro.serve.bus`); ``ckpt_dir`` + ``checkpoint_every`` (in
    chunks) enable the durability/fault-recovery path (checkpoints are
    written asynchronously — see the module docstring). ``ckpt_keep``
    bounds the on-disk ladder; ``max_recovery_attempts`` and
    ``recovery_backoff=(base_s, cap_s)`` govern the supervised recovery
    retry loop. All public methods must be called from the event-loop
    thread; device work runs on a dedicated single-thread executor ("the
    engine thread") so the loop stays responsive — and consumers keep
    draining — while chunks compute.
    """

    def __init__(self, template: Union[EnsembleSpec, MarketConfig],
                 backend: str = "jax-scan", *, chunk_size: int = 16,
                 queue_maxsize: int = 8, policy: str = "drop-oldest",
                 ckpt_dir: Optional[Any] = None, checkpoint_every: int = 0,
                 ckpt_keep: int = 64, max_recovery_attempts: int = 3,
                 recovery_backoff: Tuple[float, float] = (0.05, 1.0),
                 metrics: bool = True,
                 engine_opts: Optional[Dict[str, Any]] = None) -> None:
        self.template = EnsembleSpec.coerce(template)
        self.backend = backend
        self.chunk = int(chunk_size)
        self.queue_maxsize = int(queue_maxsize)
        self.policy = policy
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt_dir = ckpt_dir
        self._ckpt = None
        self._ckpt_keep = int(ckpt_keep)
        self._journal: Optional[SpliceJournal] = None
        self._max_attempts = max(1, int(max_recovery_attempts))
        self._backoff = (float(recovery_backoff[0]),
                         float(recovery_backoff[1]))
        self._metrics_enabled = bool(metrics)
        self._engine_opts = dict(engine_opts or {})
        self.engine: Optional[Engine] = None
        self.session: Optional[Session] = None
        self.scheduler = SlotScheduler(self.template)
        self.bus: Optional[FrameBus] = None
        self.metrics = None
        self._buffer: Optional[DoubleBuffer] = None
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine")
        self._task: Optional[asyncio.Task] = None
        self._running = False
        self._state = "idle"     # idle|serving|recovering|degraded|stopped
        self._degraded_reason: Optional[str] = None
        self._seq = itertools.count()
        self._chunks_remaining: Optional[int] = None
        self._warm_traces = 0
        self._pending_faults: List[Any] = []
        self._sessions: Dict[str, ClientSession] = {}
        # Journaled splices scheduled for replay after a process restart
        # (entries at boundaries >= the restored step, applied when the
        # cursor reaches them; see _apply_replay).
        self._replay: List[SpliceEntry] = []
        self.resumed_from: Optional[int] = None   # set by a disk restart
        self.restart_errors: Tuple[str, ...] = ()

    # ---- lifecycle ----
    async def start(self, chunks: Optional[int] = None) -> None:
        """Warm the engine, open the serving session, start the step loop.

        ``Engine.warm`` runs *before* the first frame so no client request
        ever pays a compile (``traces_delta`` stays 0 from here on — the
        invariant CI's serve smoke asserts). ``chunks`` bounds the run for
        tests/benchmarks; ``None`` streams until :meth:`stop`.

        With ``ckpt_dir`` pointing at a directory holding a committed
        checkpoint ladder (a previous gateway process died there), start
        becomes a **restart**: the newest committed checkpoint is
        restored, journaled splices replay at their original boundaries,
        and slot attachments are reconstructed — clients re-subscribe with
        :meth:`resume_session` and their streams continue bitwise.
        """
        if self._running:
            raise RuntimeError("gateway already started")
        loop = asyncio.get_running_loop()
        self._chunks_remaining = chunks
        await loop.run_in_executor(self._exec, self._open_engine,
                                   self._engine_opts)
        self.bus = FrameBus(metrics=self.metrics)
        self._running = True
        self._state = "serving"
        if self.metrics is not None:
            self.metrics.gauge("degraded", 0)
        self._task = asyncio.create_task(self._run_loop(), name="gateway")

    def _open_engine(self, engine_opts: Dict[str, Any]) -> None:
        """(engine thread) Build + warm the engine and open the session.

        On *first* open with ``ckpt_dir``: create the async checkpoint
        manager + durable splice journal, then either take the durable
        step-0 anchor (fresh directory) or run the process-restart path
        (committed ladder found). In-process recovery re-enters here with
        ``self._ckpt`` already set and keeps the existing ladder — the
        anchor must never be overwritten with a fresh template state.
        """
        self.engine = Engine(self.backend, chunk_size=self.chunk,
                             metrics=self._metrics_enabled, **engine_opts)
        ready = self.engine.warm(self.template, include_step=False)
        assert ready.ready, f"warm() left cold keys: {ready.cold_keys()}"
        self.session = self.engine.open(self.template)
        if self.metrics is None:
            self.metrics = self.session.metrics
        else:
            self.session.metrics = self.metrics   # lifetime series survive
        if self.bus is not None:
            self.bus.metrics = self.metrics
        self._warm_traces = self.engine.trace_count
        self._buffer = DoubleBuffer(self._to_host)
        if self._ckpt_dir is not None and self._ckpt is None:
            from repro.checkpoint.manager import CheckpointManager

            self._journal = SpliceJournal(self._ckpt_dir)
            self._ckpt = CheckpointManager(
                self._ckpt_dir, keep=self._ckpt_keep, async_write=True,
                on_write=self._on_ckpt_write, on_gc=self._on_ckpt_gc)
            if self._ckpt.latest_step() is None:
                # Fresh ladder: drop any stale journal (a crash before the
                # anchor committed has nothing to replay onto), then write
                # the durable step-0 anchor before taking traffic.
                self._journal.reset()
                self.session.save_checkpoint(self._ckpt, wait=True)
            else:
                self._restart_from_disk()

    def _restart_from_disk(self) -> None:
        """(engine thread) Process-restart: restore the newest committed
        checkpoint, schedule journaled splice replay, rebuild slot
        bookkeeping, and resume seq/step continuity."""
        from repro.ops.chaos import _restore_resilient

        errors: List[str] = []
        resumed = _restore_resilient(self.session, self._ckpt, errors)
        self.restart_errors = tuple(errors)
        self.resumed_from = resumed
        entries = self._journal.entries()
        self._replay = [e for e in entries if e.t >= resumed]
        # Attachment bookkeeping: the restored spec's labels cover the
        # checkpointed mixture; pending-replay entries claim their slots
        # NOW (so new admissions cannot steal them) and update labels as
        # they apply.
        for slot, label in enumerate(self.session.spec.scenarios):
            if label and label != "parked":
                self.scheduler.mark_attached(slot, label)
        final: Dict[int, Optional[str]] = {}
        for e in self._replay:
            for slot, label in zip(e.slots, e.labels):
                final[slot] = label
        for slot, label in final.items():
            if label is not None:
                self.scheduler.mark_attached(slot, label)
        self._seq = itertools.count(resumed // self.chunk)

    async def stop(self) -> None:
        """Stop the step loop, flush the pipeline tail **and the async
        checkpoint writer** (shutdown never abandons an in-flight
        checkpoint — a sticky writer failure is re-raised here), close
        every client."""
        self._running = False
        if self._task is not None:
            await self._task
            self._task = None
        try:
            if self._ckpt is not None:
                loop = asyncio.get_running_loop()
                await loop.run_in_executor(self._exec, self._ckpt.wait)
        finally:
            if self._ckpt is not None:
                self._ckpt.close()
            if self._journal is not None:
                self._journal.close()
            self._exec.shutdown(wait=True)
            if self.session is not None:
                self.session.close()
            if self._state != "degraded":
                self._state = "stopped"

    @property
    def traces_delta(self) -> int:
        """Traces since warm — 0 is the serving invariant."""
        return (self.engine.trace_count - self._warm_traces
                if self.engine is not None else 0)

    @property
    def step_count(self) -> int:
        return self.session.step_count if self.session is not None else 0

    @property
    def state(self) -> str:
        """Supervision state: idle|serving|recovering|degraded|stopped."""
        return self._state

    def health(self) -> Dict[str, Any]:
        """The health-endpoint payload, backed by ``Engine.readiness()``.

        ``ready`` is true only in the ``serving`` state — a recovering or
        degraded gateway answers 503 through
        :class:`repro.serve.transport.HealthServer` while still reporting
        full diagnostics (recovery state, checkpoint-writer lag, journal
        size) in the body.
        """
        ready = self.engine is not None and self.engine.readiness().ready
        out = {
            "ready": bool(ready and self._running
                          and self._state == "serving"),
            "running": self._running,
            "state": self._state,
            "backend": self.backend,
            "slots": self.scheduler.num_slots,
            "slots_attached": len(self.scheduler.attached),
            "slots_free": self.scheduler.free,
            "clients": len(self._sessions),
            "step": self.step_count,
            "traces_delta": self.traces_delta,
        }
        if self._degraded_reason is not None:
            out["degraded_reason"] = self._degraded_reason
        if self._ckpt is not None:
            out["checkpoint"] = {
                "pending": self._ckpt.pending,
                "writes": self._ckpt.writes,
                "skipped": self._ckpt.skipped,
                "last_write_s": self._ckpt.last_write_seconds,
                "latest_step": self._ckpt.latest_step(),
            }
        if self._journal is not None:
            out["journal_entries"] = len(self._journal)
        return out

    # ---- client admission (in-process front door) ----
    def _check_admission(self) -> None:
        # degraded outranks "not running": the loop has exited, but the
        # typed refusal is the diagnosis callers need
        if self._state == "degraded":
            raise GatewayDegraded(
                f"gateway is degraded ({self._degraded_reason}); serving "
                "health only — restart the process to recover")
        if not self._running:
            raise RuntimeError("gateway is not running; await start() first")
        if self._state == "recovering":
            raise GatewayRecovering(
                "gateway is recovering from a fault; admission resumes "
                "after the reconnect broadcast — retry shortly")

    def open_session(self, spec: Union[str, MarketConfig, EnsembleSpec],
                     *, maxsize: Optional[int] = None,
                     policy: Optional[str] = None,
                     client: Optional[str] = None) -> ClientSession:
        """Attach a client's market; frames start at the next chunk
        boundary. Raises :class:`GatewayFull` when every slot is taken,
        :class:`GatewayRecovering`/:class:`GatewayDegraded` while admission
        is paused, and ``ValueError`` when the spec disagrees with the
        template's static fields."""
        self._check_admission()
        slot = self.scheduler.attach(spec)
        sub = self.bus.subscribe(
            slot, client=client,
            maxsize=self.queue_maxsize if maxsize is None else maxsize,
            policy=self.policy if policy is None else policy)
        sub._force(Event("attached", {
            "slot": slot, "client": sub.client,
            "scenario": self.scheduler.label(slot),
            "first_step": self.step_count}))
        cs = ClientSession(self, sub)
        self._sessions[sub.client] = cs
        if self.metrics is not None:
            self.metrics.gauge("slots_attached",
                               len(self.scheduler.attached))
        return cs

    def resume_session(self, slot: int, *, maxsize: Optional[int] = None,
                       policy: Optional[str] = None,
                       client: Optional[str] = None) -> ClientSession:
        """Re-subscribe to an *already attached* slot — the restart front
        door. After a process crash + restart the slot's market is already
        live (restored from the checkpoint + journal replay), so resuming
        costs no splice: frames continue from the restored cursor, and the
        overlap with anything the client saw pre-crash is bitwise-identical
        (dedupe by ``frame.step0``). Raises ``KeyError`` for a slot that is
        not attached."""
        self._check_admission()
        label = self.scheduler.label(slot)
        if label is None:
            raise KeyError(
                f"slot {slot} is not attached; open_session() admits new "
                "clients")
        sub = self.bus.subscribe(
            slot, client=client,
            maxsize=self.queue_maxsize if maxsize is None else maxsize,
            policy=self.policy if policy is None else policy)
        sub._force(Event("attached", {
            "slot": slot, "client": sub.client, "scenario": label,
            "first_step": self.step_count, "resumed": True}))
        cs = ClientSession(self, sub)
        self._sessions[sub.client] = cs
        if self.metrics is not None:
            self.metrics.gauge("slots_attached",
                               len(self.scheduler.attached))
        return cs

    def close_session(self, cs: ClientSession) -> None:
        """Detach the client's slot (parked at the next boundary) and close
        its queue."""
        self._sessions.pop(cs.client, None)
        if cs.slot in self.scheduler.attached:
            self.scheduler.detach(cs.slot)
        self.bus.close_subscription(cs.subscription, reason="detach")
        if self.metrics is not None:
            self.metrics.gauge("slots_attached",
                               len(self.scheduler.attached))

    # ---- fault injection (the chaos tier's entry point) ----
    def inject_fault(self, fault: Any) -> None:
        """Queue a :class:`repro.ops.chaos.DeviceLoss` for the next chunk
        boundary; requires ``ckpt_dir`` (recovery restores the newest
        loadable checkpoint and replays quietly, so client streams resume
        bitwise). Faults queued while one is already pending **coalesce**
        into a single recovery pass (the last fault's topology wins)."""
        if self._ckpt is None:
            raise RuntimeError(
                "fault recovery needs ckpt_dir= (no checkpoint to restore)")
        self._pending_faults.append(fault)

    # ---- the step loop ----
    async def _run_loop(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while self._running and self._chunks_remaining != 0:
                if self._pending_faults:
                    faults = self._pending_faults[:]
                    self._pending_faults.clear()
                    # The in-flight chunk completed pre-fault: deliver it
                    # before tearing the engine down, so no frame is lost.
                    tail = await loop.run_in_executor(self._exec,
                                                      self._buffer.flush)
                    if tail is not None:
                        self._complete(tail)
                    if not await self._recover_supervised(faults):
                        break        # degraded: loop exits, health goes 503
                # Coalesce on the LOOP thread: admission (open/close_session)
                # also runs here, so whether a client's splice makes this
                # boundary or the next is decided by asyncio callback order,
                # never by a loop-vs-engine-thread race — the determinism
                # the bitwise chaos comparisons rely on.
                pending = self.scheduler.coalesce()
                attached = self.scheduler.attached
                done = await loop.run_in_executor(
                    self._exec, self._advance_once, pending, attached)
                if done is not None:
                    self._complete(done)
                if self._chunks_remaining is not None:
                    self._chunks_remaining -= 1
            tail = None if self._buffer is None else self._buffer.flush()
            if tail is not None:
                self._complete(tail)
        finally:
            self._running = False
            if self.bus is not None:
                self.bus.close_all("degraded" if self._state == "degraded"
                                   else "shutdown")

    def _advance_once(self, pending, attached):
        """(engine thread) Apply due journal replays and the loop-frozen
        pending slot splice (journal-first: write-ahead), dispatch one
        chunk, and hand back the *previous* chunk still device-side (the
        lag-one pipeline; materialization happens in :meth:`_complete`).
        ``pending``/``attached`` were coalesced/captured on the loop thread
        so splice boundaries are ordered against admission, not raced.
        Periodic checkpoints cost only the device→host mirror here —
        serialization and fsync live on the manager's writer thread."""
        sess = self.session
        self._apply_replay(sess)
        if pending is not None:                # coalesced boundary swap
            slots, sub, labels = pending
            entry = SpliceEntry(t=sess.step_count, slots=slots,
                                labels=labels, spec=sub)
            if self._journal is not None:      # WAL: durable BEFORE applied
                self._journal.append(entry)
                if self.metrics is not None:
                    self.metrics.inc("journal_entries_total")
            sess.swap_markets(list(slots), sub)
        seq = next(self._seq)
        step0 = sess.step_count
        t0 = time.perf_counter()
        batch = sess.run(self.chunk)   # async dispatch on jax/pallas
        stats = sess.stats             # host copy; None unless stats_only
        meta = (seq, step0, self.chunk, t0, attached)
        done = self._buffer.push(meta, (batch, stats))
        if (self._ckpt is not None and self.checkpoint_every
                and (seq + 1) % self.checkpoint_every == 0):
            t0c = time.perf_counter()
            sess.save_checkpoint(self._ckpt, wait=False)
            if self.metrics is not None:
                self.metrics.observe_window("checkpoint_snapshot_seconds",
                                            time.perf_counter() - t0c)
                self.metrics.gauge("checkpoint_writer_pending",
                                   self._ckpt.pending)
                self.metrics.gauge("checkpoints_skipped",
                                   self._ckpt.skipped)
        return done

    def _apply_replay(self, sess) -> None:
        """(engine thread) Apply journaled splices whose boundary the
        restored cursor has reached — the process-restart replay. Applied
        entries are NOT re-journaled (they are already on disk)."""
        while self._replay and self._replay[0].t <= sess.step_count:
            e = self._replay.pop(0)
            if e.t < sess.step_count:
                continue   # already baked into the restored checkpoint
            sess.swap_markets(list(e.slots), e.spec)
            for slot, label in zip(e.slots, e.labels):
                if label is None:
                    self.scheduler.mark_parked(slot)
                else:
                    self.scheduler.mark_attached(slot, label)

    def _to_host(self, payload: Tuple[StepBatch, Any]):
        batch, stats = payload
        return batch.to_numpy(), stats

    def _complete(self, done) -> None:
        """(event loop) Record a finished chunk's latency and fan it out;
        queue puts are non-blocking, so this never stalls the loop."""
        (seq, step0, n, t0, slots), payload = done
        if self.metrics is not None:
            self.metrics.observe_window("chunk_latency_seconds",
                                        time.perf_counter() - t0)
        host_batch, stats = payload
        self.bus.publish(slice_frames(host_batch, stats, slots, seq,
                                      step0, n))

    # ---- checkpoint-writer callbacks (writer thread; registry is
    # thread-safe) ----
    def _on_ckpt_write(self, step: int, seconds: float) -> None:
        if self.metrics is not None:
            self.metrics.observe_window("checkpoint_write_seconds", seconds)
            self.metrics.inc("checkpoints_saved_total")

    def _on_ckpt_gc(self, oldest_retained_step: int) -> None:
        if self._journal is not None:
            dropped = self._journal.compact(oldest_retained_step)
            if dropped and self.metrics is not None:
                self.metrics.inc("journal_compactions_total")
                self.metrics.inc("journal_entries_compacted_total", dropped)

    # ---- supervised recovery (the fault-storm state machine) ----
    async def _recover_supervised(self, faults: List[Any]) -> bool:
        """One coalesced recovery pass over every queued fault.

        Retries ``_recover`` up to ``max_recovery_attempts`` times with
        exponential backoff + jitter; success broadcasts ONE ``reconnect``
        (however many faults coalesced), exhaustion degrades the gateway
        (503 health, :class:`GatewayDegraded` admission) and broadcasts
        ``degraded``. Returns True when serving may resume.
        """
        loop = asyncio.get_running_loop()
        self._state = "recovering"
        if self.metrics is not None and len(faults) > 1:
            self.metrics.inc("faults_coalesced_total", len(faults) - 1)
        fault = faults[-1]                  # last fault's topology wins
        target = self.step_count
        base, cap = self._backoff
        last_error: Optional[BaseException] = None
        for attempt in range(1, self._max_attempts + 1):
            if self.metrics is not None:
                self.metrics.inc("recovery_attempts_total")
            try:
                resume = await loop.run_in_executor(
                    self._exec, self._recover, fault, target)
            except Exception as exc:
                last_error = exc
                if attempt < self._max_attempts:
                    delay = min(cap, base * (2 ** (attempt - 1)))
                    await asyncio.sleep(delay * (1.0 + random.random()))
                continue
            self._state = "serving"
            self.bus.broadcast(Event("reconnect", {
                "resume_step": resume, "step": self.step_count,
                "fault": type(fault).__name__,
                "faults_coalesced": len(faults),
                "attempts": attempt}))
            if self.metrics is not None:
                self.metrics.inc("reconnects_total")
                self.metrics.inc("recoveries_total")
            return True
        self._state = "degraded"
        self._degraded_reason = (
            f"recovery failed after {self._max_attempts} attempts: "
            f"{type(last_error).__name__}: {last_error}")
        if self.metrics is not None:
            self.metrics.gauge("degraded", 1)
        self.bus.broadcast(Event("degraded", {
            "reason": self._degraded_reason, "step": target,
            "fault": type(fault).__name__,
            "faults_coalesced": len(faults)}))
        return False

    def _recover(self, fault, target: int) -> int:
        """(engine thread) Device-loss recovery under live client load.

        Rebuild the engine on the surviving topology (``devices_after`` /
        ``lost_device``, as in :class:`repro.ops.chaos.DeviceLoss`),
        restore the newest loadable checkpoint (walking the ladder past
        corrupt steps), then replay *quietly* back to ``target`` (the
        pre-fault cursor) — re-applying splices read from the **durable
        journal** at their original boundaries — so published streams
        continue bitwise after the ``reconnect`` event. Idempotent across
        retry attempts (the supervised loop may call it repeatedly).
        Returns the step the session resumed from.
        """
        from repro.ops.chaos import _restore_resilient

        if self.session is not None:
            try:
                self.session.close()
            except Exception:
                pass               # a prior attempt already tore it down
        new_opts = dict(self._engine_opts)
        new_opts.pop("devices", None)
        new_opts.pop("mesh", None)
        devices_after = getattr(fault, "devices_after", None)
        lost_device = getattr(fault, "lost_device", None)
        if devices_after is not None:
            new_opts["devices"] = devices_after
        elif lost_device is not None:
            from repro.launch.mesh import make_markets_mesh

            new_opts["mesh"] = make_markets_mesh(skip=(lost_device,))
        self._engine_opts = new_opts
        self._open_engine(new_opts)
        errors: List[str] = []
        resumed = _restore_resilient(self.session, self._ckpt, errors)
        # Quiet replay: the checkpoint predates some splices — re-apply
        # each at its original boundary (from the durable journal, so the
        # same path covers in-process recovery and process restart) while
        # running the lost chunks.
        replay = [e for e in self._journal.entries()
                  if resumed <= e.t < target]
        for e in replay:
            while self.session.step_count < e.t:
                self.session.run(min(self.chunk,
                                     e.t - self.session.step_count))
            self.session.swap_markets(list(e.slots), e.spec)
        while self.session.step_count < target:
            self.session.run(min(self.chunk,
                                 target - self.session.step_count))
        return resumed
