"""Double-buffered device→host stats/frame pipeline (lag-one transfer).

The engine's chunk dispatch is asynchronous on the JAX/Pallas backends:
``Session.run`` returns future-backed device arrays while the chunk is
still executing. Materializing those outputs immediately
(``block_until_ready`` inside ``to_numpy``) would serialize every chunk as
``[compute | transfer | compute | transfer]``. The gateway instead runs a
two-deep pipeline:

    dispatch chunk k          (device starts computing, host returns)
    materialize chunk k-1     (its compute overlapped chunk k's dispatch —
                               usually already done, so the host copy is
                               pure transfer)
    stream chunk k-1 frames

:class:`DoubleBuffer` is that lag-one stage: :meth:`push` stores the fresh
device batch and returns the *previous* one converted to host, so
streaming per-chunk frames to clients never blocks the next chunk's
dispatch. The cost is one chunk of latency on the stream — the classic
throughput-for-latency trade of double buffering — which
:meth:`flush` repays at end of stream. Output buffers are safe to hold
across dispatches because chunk outputs are freshly allocated (only the
carried *state* buffers are donated).

On host-loop backends (numpy) conversion is free and the pipeline
degenerates to a one-item delay line — same semantics, no overlap to win.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Generic, Optional, Tuple, TypeVar

T = TypeVar("T")
U = TypeVar("U")


class DoubleBuffer(Generic[T, U]):
    """Lag-one conversion pipeline: ``push(x_k) -> convert(x_{k-1})``.

    ``convert`` is the (blocking) device→host materialization; it runs on
    the item pushed one call earlier, after the *next* chunk has already
    been dispatched. ``conversion_seconds`` accumulates the observed
    blocking time so the gateway can report how much transfer the overlap
    actually hid.
    """

    def __init__(self, convert: Callable[[T], U]) -> None:
        self._convert = convert
        self._pending: Optional[Tuple[Any, T]] = None
        self.conversions = 0
        self.conversion_seconds = 0.0

    @property
    def depth(self) -> int:
        """Items currently in flight (0 or 1)."""
        return 0 if self._pending is None else 1

    def push(self, tag: Any, item: T) -> Optional[Tuple[Any, U]]:
        """Store ``item`` (freshly dispatched, possibly still computing on
        device) and return the previously pushed ``(tag, converted)`` pair,
        or ``None`` on the first call."""
        done = self._drain()
        self._pending = (tag, item)
        return done

    def flush(self) -> Optional[Tuple[Any, U]]:
        """Convert and return the in-flight item (end of stream), if any."""
        return self._drain()

    def _drain(self) -> Optional[Tuple[Any, U]]:
        if self._pending is None:
            return None
        tag, item = self._pending
        self._pending = None
        t0 = time.perf_counter()
        out = self._convert(item)
        self.conversion_seconds += time.perf_counter() - t0
        self.conversions += 1
        return tag, out
