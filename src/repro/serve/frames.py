"""Wire shapes for the serving gateway: per-chunk frames + control events.

A :class:`Frame` is one client's slice of one completed chunk — the
per-step ``mid``/``price``/``volume`` paths for *their* market (or, on a
``stats_only`` gateway, the running :class:`~repro.core.stats.MarketStats`
row instead of paths). Frames are produced once per chunk per attached
slot and fanned out through :class:`repro.serve.bus.FrameBus`; the
in-process transport hands the NamedTuple over directly, the WebSocket
transport sends :meth:`Frame.to_json`.

An :class:`Event` is an out-of-band control message delivered on the same
per-client queue (attach/detach acknowledgements, fault-recovery
``reconnect`` markers, ``closed`` on a backpressure disconnect), so a
client observes control flow in order with its data frames.
"""
from __future__ import annotations

import json
from typing import Any, Dict, NamedTuple, Optional, Tuple

import numpy as np


class Frame(NamedTuple):
    """One chunk's outputs for one serving slot."""

    slot: int                     # ensemble row this client is attached to
    seq: int                      # gateway-global chunk index (monotonic)
    step0: int                    # absolute step of the chunk's first step
    num_steps: int                # steps in this chunk (partial tails < chunk)
    mid: np.ndarray               # f32[num_steps] pre-clearing mid path
    price: np.ndarray             # f32[num_steps] clearing-price path
    volume: np.ndarray            # f32[num_steps] transacted-volume path
    stats: Optional[Dict[str, float]] = None  # stats_only gateways only

    def to_json(self) -> str:
        payload = {
            "type": "frame", "slot": int(self.slot), "seq": int(self.seq),
            "step0": int(self.step0), "num_steps": int(self.num_steps),
            "mid": np.asarray(self.mid, np.float64).tolist(),
            "price": np.asarray(self.price, np.float64).tolist(),
            "volume": np.asarray(self.volume, np.float64).tolist(),
        }
        if self.stats is not None:
            payload["stats"] = {k: float(v) for k, v in self.stats.items()}
        return json.dumps(payload)

    @classmethod
    def from_json(cls, text: str) -> "Frame":
        d = json.loads(text)
        if d.get("type") != "frame":
            raise ValueError(f"not a frame payload: {d.get('type')!r}")
        return cls(
            slot=int(d["slot"]), seq=int(d["seq"]), step0=int(d["step0"]),
            num_steps=int(d["num_steps"]),
            mid=np.asarray(d["mid"], np.float32),
            price=np.asarray(d["price"], np.float32),
            volume=np.asarray(d["volume"], np.float32),
            stats=d.get("stats"),
        )


class Event(NamedTuple):
    """Out-of-band control message on a client's queue.

    ``kind`` is one of ``"attached"`` (slot assignment ack, carries the
    slot and scenario label), ``"detached"``, ``"reconnect"`` (the gateway
    recovered from a fault and resumed at ``payload["resume_step"]`` — the
    stream continues bitwise from there), or ``"closed"`` (the gateway
    disconnected this client: backpressure ``disconnect`` policy, detach,
    or shutdown; ``payload["reason"]`` says which).
    """

    kind: str
    payload: Dict[str, Any]

    def to_json(self) -> str:
        return json.dumps({"type": "event", "kind": self.kind,
                           "payload": self.payload})

    @classmethod
    def from_json(cls, text: str) -> "Event":
        d = json.loads(text)
        if d.get("type") != "event":
            raise ValueError(f"not an event payload: {d.get('type')!r}")
        return cls(kind=d["kind"], payload=d.get("payload", {}))


def decode(text: str):
    """Decode one wire message into a :class:`Frame` or :class:`Event`."""
    kind = json.loads(text).get("type")
    if kind == "frame":
        return Frame.from_json(text)
    if kind == "event":
        return Event.from_json(text)
    raise ValueError(f"unknown wire message type {kind!r}")


def slice_frames(batch, stats, slots, seq: int, step0: int,
                 n: int) -> Tuple[Tuple[int, Frame], ...]:
    """Cut one host-side chunk batch into per-slot frames.

    ``batch`` is a host :class:`~repro.core.session.StepBatch` (zero-width
    paths on a ``stats_only`` gateway, in which case the per-market
    ``stats`` NamedTuple supplies the payload); ``slots`` is the iterable
    of attached slot ids to emit for. Parked slots simply get no frame —
    their rows are computed (shape-static ensemble) but never leave the
    host batch.
    """
    out = []
    for slot in slots:
        s = None
        if stats is not None:
            s = {field: float(np.asarray(leaf)[slot, 0])
                 for field, leaf in zip(stats._fields, stats)}
        width = np.asarray(batch.mid).shape[-1]
        empty = np.zeros(0, np.float32)
        out.append((slot, Frame(
            slot=slot, seq=seq, step0=step0, num_steps=n,
            mid=np.asarray(batch.mid)[slot] if width else empty,
            price=np.asarray(batch.price)[slot] if width else empty,
            volume=np.asarray(batch.volume)[slot] if width else empty,
            stats=s)))
    return tuple(out)
