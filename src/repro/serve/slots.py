"""Slot scheduler: multiplexing client markets onto one warm ensemble.

The gateway's engine runs ONE ensemble of ``slots`` markets forever — the
shape never changes, so the trace never changes. A client session is an
*assignment* of one ensemble row (a slot) to that client: attaching writes
the client's per-market params row + fresh opening book into the row at
the next chunk boundary (:meth:`Session.swap_markets`), detaching parks
the row with :meth:`EnsembleSpec.parked` values. Slots are the unit of
admission control: a gateway with all slots attached refuses new sessions
(:class:`GatewayFull`) instead of retracing to a wider ensemble.

The scheduler itself is pure bookkeeping — it validates static-field
agreement eagerly (a mismatched client spec must fail at ``attach``, not
deep inside the splice), queues mutations, and coalesces everything
pending into ONE ``swap_markets`` call per chunk boundary so an attach
burst costs one host round-trip, not one per client.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

from repro.core.config import MarketConfig, scenario_config
from repro.core.params import EnsembleSpec, _STATIC_FIELDS


class GatewayFull(RuntimeError):
    """Every slot is attached — admission refused (no retrace to grow)."""


class GatewayRecovering(RuntimeError):
    """The gateway is mid-recovery — admission paused; retry after the
    ``reconnect`` broadcast (existing streams resume bitwise)."""


class GatewayDegraded(RuntimeError):
    """The recovery retry budget is exhausted — the gateway is serving
    health only (HTTP 503) and refuses sessions until restarted."""


class SlotScheduler:
    """Free-list of ensemble rows + a pending-mutation queue.

    ``template`` fixes the static shape every client must agree with; rows
    are coerced through :meth:`coerce_row` (preset name, single-market
    :class:`MarketConfig`, or single-market :class:`EnsembleSpec`).
    """

    def __init__(self, template: EnsembleSpec) -> None:
        self.template = template
        self._free: List[int] = list(range(template.num_markets))[::-1]
        self._attached: Dict[int, str] = {}      # slot -> scenario label
        self._pending: List[Tuple[int, EnsembleSpec]] = []

    # ---- introspection ----
    @property
    def num_slots(self) -> int:
        return self.template.num_markets

    @property
    def attached(self) -> Tuple[int, ...]:
        return tuple(sorted(self._attached))

    @property
    def free(self) -> int:
        return len(self._free)

    def label(self, slot: int) -> Optional[str]:
        return self._attached.get(slot)

    # ---- row coercion ----
    def coerce_row(self, spec: Union[str, MarketConfig, EnsembleSpec],
                   ) -> EnsembleSpec:
        """Normalize a client's market request to a 1-market spec agreeing
        with the template's static fields — loudly, at admission time."""
        t = self.template
        if isinstance(spec, str):
            spec = scenario_config(
                spec, num_markets=1, num_agents=t.num_agents,
                num_levels=t.num_levels, num_steps=t.num_steps, seed=t.seed)
        row = EnsembleSpec.coerce(spec)
        if row.num_markets != 1:
            raise ValueError(
                f"a client session attaches exactly one market; got a "
                f"{row.num_markets}-market spec")
        for f in _STATIC_FIELDS:
            if getattr(row, f) != getattr(t, f):
                raise ValueError(
                    f"client spec disagrees with the serving template on "
                    f"static field {f!r}: template has {getattr(t, f)}, "
                    f"client asked for {getattr(row, f)} — static fields "
                    "fix the warm trace and cannot vary per session")
        return row

    # ---- mutation queue (applied at chunk boundaries by the gateway) ----
    def attach(self, spec: Union[str, MarketConfig, EnsembleSpec]) -> int:
        """Reserve a free slot for ``spec``; the splice lands at the next
        chunk boundary. Raises :class:`GatewayFull` when no slot is free."""
        row = self.coerce_row(spec)
        if not self._free:
            raise GatewayFull(
                f"all {self.num_slots} slots attached; detach a session or "
                "serve from a wider template")
        slot = self._free.pop()
        self._attached[slot] = row.scenarios[0] if row.scenarios else "?"
        self._pending.append((slot, row))
        return slot

    def detach(self, slot: int) -> None:
        """Queue parking ``slot``; it returns to the free list now (it can
        be re-attached immediately; mutations coalesce in queue order)."""
        if slot not in self._attached:
            raise KeyError(f"slot {slot} is not attached")
        del self._attached[slot]
        self._free.append(slot)
        self._pending.append((slot, EnsembleSpec.parked(self.template, 1)))

    def coalesce(self) -> Optional[Tuple[Tuple[int, ...], EnsembleSpec,
                                         Tuple[Optional[str], ...]]]:
        """Pop every pending mutation as ONE coalesced splice — without
        applying it.

        Later mutations of the same slot win (detach-then-attach between
        two boundaries nets to the attach). Returns ``(slots, sub_spec,
        labels)`` — ``labels`` is the post-splice attachment label per
        slot (``None`` for a park/detach) — or ``None`` when nothing was
        pending. The gateway journals the splice durably *before* calling
        ``session.swap_markets`` (write-ahead ordering: a crash between
        the two replays the splice, never loses it).
        """
        if not self._pending:
            return None
        last: Dict[int, EnsembleSpec] = {}
        for slot, row in self._pending:
            last[slot] = row
        self._pending.clear()
        slots = sorted(last)
        sub = EnsembleSpec.concatenate([last[s] for s in slots])
        labels = tuple(self._attached.get(s) for s in slots)
        return tuple(slots), sub, labels

    def drain(self, session
              ) -> Optional[Tuple[Tuple[int, ...], EnsembleSpec]]:
        """Apply every pending mutation in ONE ``swap_markets`` splice
        (:meth:`coalesce` + apply, for callers without a journal)."""
        pending = self.coalesce()
        if pending is None:
            return None
        slots, sub, _ = pending
        session.swap_markets(list(slots), sub)
        return slots, sub

    # ---- restart reconstruction (journal replay / checkpoint labels) ----
    def mark_attached(self, slot: int, label: str) -> None:
        """Record ``slot`` as attached with ``label`` without queueing any
        splice — rebuilding bookkeeping after a process restart, where the
        row's params already live in the restored checkpoint (or arrive
        via journal replay)."""
        if slot not in self._attached:
            self._free.remove(slot)
        self._attached[slot] = label

    def mark_parked(self, slot: int) -> None:
        """Inverse of :meth:`mark_attached` for journal-replayed parks."""
        if slot in self._attached:
            del self._attached[slot]
            self._free.append(slot)
