"""Network transports for the gateway: HTTP health + WebSocket fan-out.

The gateway core (:mod:`repro.serve.gateway`) is transport-agnostic — the
in-process :class:`~repro.serve.gateway.ClientSession` is the canonical
front door and what tests/benchmarks use. This module adds the two wire
surfaces the serving deployment needs:

  * :class:`HealthServer` — a dependency-free asyncio HTTP/1.1 endpoint
    (``GET /healthz``) returning :meth:`Gateway.health` as JSON: ``200``
    when the engine is warm, the loop is running, and the gateway is in
    the ``serving`` state; ``503`` otherwise — including the
    ``recovering`` and ``degraded`` supervision states, where the body
    still carries full diagnostics (state, ``degraded_reason``,
    checkpoint-writer lag, journal size) for operators while the
    load-balancer routes traffic away. This is the k8s readiness probe,
    backed by ``Engine.readiness()`` — a gateway that would retrace on
    the next request reports unready *before* taking traffic.
  * :class:`WebSocketServer` — one WebSocket connection per client
    session. The handshake message selects the scenario; frames and
    control events stream as the JSON codecs in
    :mod:`repro.serve.frames`. Requires the optional ``websockets``
    package; constructing it without raises a clear error (the rest of
    the serve stack — and all of CI — works without it).

Per-client backpressure bounds (queue size, drop-oldest/disconnect) apply
identically on both transports because they live in the bus, not here.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Dict, Optional

from repro.serve.frames import Event
from repro.serve.gateway import Gateway

try:                                   # optional dependency, never required
    import websockets as _websockets
except Exception:                      # pragma: no cover - env-dependent
    _websockets = None


class HealthServer:
    """``GET /healthz`` over stdlib asyncio — no HTTP framework needed.

    Responds ``200 OK`` with the :meth:`Gateway.health` JSON payload when
    ``payload["ready"]`` is true, ``503 Service Unavailable`` (same body)
    when not. Any other path returns ``404``. The handler never touches
    the engine thread — ``health()`` reads cached readiness state — so the
    probe stays cheap under load.
    """

    def __init__(self, gateway: Gateway, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        self.gateway = gateway
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> int:
        """Bind and serve; returns the bound port (useful with port 0)."""
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.port

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request = await asyncio.wait_for(reader.readline(), timeout=5.0)
            parts = request.decode("latin-1").split()
            path = parts[1] if len(parts) >= 2 else ""
            while True:            # drain headers; we need none of them
                line = await asyncio.wait_for(reader.readline(), timeout=5.0)
                if line in (b"\r\n", b"\n", b""):
                    break
            if path in ("/healthz", "/health", "/"):
                payload = self.gateway.health()
                status = ("200 OK" if payload["ready"]
                          else "503 Service Unavailable")
            else:
                payload = {"error": f"not found: {path}"}
                status = "404 Not Found"
            body = json.dumps(payload).encode()
            writer.write(
                f"HTTP/1.1 {status}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n".encode() + body)
            await writer.drain()
        except (asyncio.TimeoutError, ConnectionError):
            pass
        finally:
            writer.close()


class WebSocketServer:
    """WebSocket fan-out: one connection per client session.

    Protocol: the client's first message is a JSON handshake
    ``{"scenario": <preset name>, "maxsize": ..., "policy": ...}``; the
    server attaches a slot and then streams ``frame``/``event`` JSON
    messages (:mod:`repro.serve.frames` codecs) until the client
    disconnects or backpressure policy closes the session. Queue bounds
    are enforced bus-side, so a slow socket drops frames (or is shed)
    without ever stalling the simulation.
    """

    def __init__(self, gateway: Gateway, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        if _websockets is None:
            raise RuntimeError(
                "the WebSocket transport needs the optional 'websockets' "
                "package, which is not installed in this environment; use "
                "the in-process transport (Gateway.open_session) or "
                "install websockets")
        self.gateway = gateway
        self.host = host
        self.port = port
        self._server = None

    async def start(self) -> int:
        self._server = await _websockets.serve(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.port

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def _handle(self, ws) -> None:   # pragma: no cover - needs dep
        try:
            hello: Dict[str, Any] = json.loads(await ws.recv())
        except Exception:
            await ws.close(code=1002, reason="bad handshake")
            return
        cs = None
        try:
            cs = self.gateway.open_session(
                hello.get("scenario", "baseline"),
                maxsize=hello.get("maxsize"),
                policy=hello.get("policy"),
                client=hello.get("client"))
            async for item in cs.subscription:
                await ws.send(item.to_json())
                if isinstance(item, Event) and item.kind == "closed":
                    break
        except Exception:
            pass
        finally:
            if cs is not None and not cs.closed:
                cs.close()
            await ws.close()
