"""Tile selection for the persistent clearing kernels: pad, don't degrade.

The seed's ``pick_tile`` required ``mb`` to *divide* M, so a prime or odd
ensemble size degraded to MB=1 — one market per grid cell, an 8× sublane
under-utilization on TPU. This module replaces that policy:

  * :func:`auto_tile` always returns a sublane-aligned tile (MB a multiple
    of 8) and the padded ensemble size ``m_padded`` that makes the grid
    exact. The kernel wrappers pad the market axis with benign zero rows
    (markets are row-independent, so real rows are bitwise unaffected) and
    slice the outputs back — M=63 runs the identical tile shape as M=64.
  * :func:`autotune_tile` optionally *sweeps* (MB, agent-chunk) candidates
    by compiling and timing each on first use, caching the winner per
    ``(device-kind, L, A, chunk)`` so every engine/runner built later in
    the process reuses the measured choice without re-sweeping.

The agent-chunk knob bounds the one-hot binning's [MB, Ac, L] VMEM
intermediate (see ``bin_orders_onehot``); f32 exact-integer adds make the
chunked accumulation bitwise-identical for any chunk size.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

SUBLANES = 8  # TPU f32 sublane count — tiles want MB ≡ 0 (mod 8)

#: Winner cache for the timed sweep: (device_kind, L, A, chunk) -> TileChoice.
_TUNE_CACHE: Dict[Tuple[str, int, int, int], "TileChoice"] = {}

#: One record per *real* sweep (cache misses only), newest last — the
#: ops/chaos harness reads these to assert an OOM-shaped sweep fell back.
_SWEEP_REPORTS: List["SweepReport"] = []

# Substrings identifying an out-of-memory-shaped backend failure. XLA spells
# device OOM "RESOURCE_EXHAUSTED"; Mosaic VMEM overflows mention VMEM.
_OOM_MARKERS = ("resource_exhausted", "out of memory", "oom", "vmem")


class SweepReport(NamedTuple):
    """Outcome of one autotune sweep (for observability + chaos tests)."""

    key: Tuple                     # the _TUNE_CACHE key that was populated
    winner: "TileChoice"           # the cached choice (fallback when fell_back)
    fell_back: bool                # True iff every candidate failed
    tried: Tuple["TileChoice", ...]
    failures: Tuple[str, ...]      # one "CandRepr: ExcType: msg" per failure


def is_oom_error(exc: BaseException) -> bool:
    """Heuristic: does this exception look like a device/VMEM OOM?"""
    text = f"{type(exc).__name__}: {exc}".lower()
    return any(m in text for m in _OOM_MARKERS)


def estimate_vmem_bytes(tile: "TileChoice", num_levels: int,
                        num_agents: int, chunk: int = 1) -> int:
    """Rough per-grid-cell VMEM working set of the clearing kernel, bytes.

    Dominated by the [MB, Ac, L] one-hot binning intermediate, plus the
    resident books/profiles (6 × [MB, L]) and the per-chunk output paths
    (3 × [MB, chunk]); all f32. An estimate for dashboards and tile-pressure
    gauges, not a lowering-accurate allocator model.
    """
    ac = tile.agent_chunk or max(1, num_agents)
    onehot = tile.mb * ac * num_levels
    books = 6 * tile.mb * num_levels
    paths = 3 * tile.mb * max(1, chunk)
    return 4 * (onehot + books + paths)


def sweep_reports() -> Tuple["SweepReport", ...]:
    return tuple(_SWEEP_REPORTS)


def last_sweep_report() -> Optional["SweepReport"]:
    return _SWEEP_REPORTS[-1] if _SWEEP_REPORTS else None


class TileChoice(NamedTuple):
    """A resolved kernel tiling: grid tile, padded M, agent-chunk length."""

    mb: int                        # markets per grid cell (sublane axis)
    m_padded: int                  # M rounded up to a multiple of mb
    agent_chunk: Optional[int]     # one-hot binning chunk (None = all of A)

    @property
    def grid(self) -> int:
        return self.m_padded // self.mb


def pad_to_multiple(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


def default_agent_chunk(num_agents: int) -> Optional[int]:
    """Bound the [MB, Ac, L] one-hot intermediate; small A stays unchunked."""
    return 128 if num_agents > 128 else None


def auto_tile(num_markets: int, num_agents: int = 0,
              target: int = SUBLANES) -> TileChoice:
    """Heuristic sublane-aligned tile: pad M up instead of shrinking MB.

    Any M maps to MB=``target`` with ``ceil(M/target)`` grid cells — the
    tile *shape* depends only on ``target``, never on M's divisors.
    """
    mb = max(1, target)
    return TileChoice(mb=mb, m_padded=pad_to_multiple(max(1, num_markets), mb),
                      agent_chunk=default_agent_chunk(num_agents))


def candidate_tiles(num_markets: int, num_agents: int,
                    target: int = SUBLANES,
                    agent_chunk: Optional[int] = ...) -> List[TileChoice]:
    """The (MB, agent-chunk) sweep grid for :func:`autotune_tile`.

    An explicit ``agent_chunk`` (including ``None`` = unchunked) pins that
    knob and sweeps MB only — a caller-set VMEM bound must never be
    overridden by the sweep.
    """
    mbs = sorted({target, 2 * target})
    if agent_chunk is not ...:
        acs = [agent_chunk if agent_chunk else num_agents]
    else:
        acs = sorted({c for c in (64, 128, num_agents)
                      if 0 < c <= num_agents}) or [num_agents]
    out = []
    for mb in mbs:
        for ac in acs:
            out.append(TileChoice(
                mb=mb, m_padded=pad_to_multiple(max(1, num_markets), mb),
                agent_chunk=None if ac >= num_agents else ac))
    # dedup while keeping sweep order deterministic
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def tune_key(num_levels: int, num_agents: int, chunk: int,
             **context) -> Tuple:
    """Winner cache key: (device-kind, L, A, chunk) plus any ``context``
    that changes what is being timed (kernel family, scan mode, stats_only,
    a pinned agent_chunk) — distinct kernel configurations must never share
    a measured winner."""
    import jax

    return ((jax.devices()[0].device_kind, num_levels, num_agents, chunk)
            + tuple(sorted(context.items())))


def autotune_tile(key: Tuple,
                  time_candidate: Callable[[TileChoice], float],
                  cands: List[TileChoice],
                  fallback: Optional[TileChoice] = None,
                  num_markets: Optional[int] = None) -> TileChoice:
    """Measure each candidate once (first compile), cache the winner.

    ``time_candidate`` compiles + runs one representative chunk call and
    returns its wall time; exceptions (e.g. a tile the backend rejects)
    disqualify the candidate rather than failing the sweep. If every
    candidate fails, ``fallback`` (the caller's heuristic choice) is used.
    Cached winners are re-padded for the caller's ``num_markets`` — only
    (mb, agent_chunk) is reused across ensemble sizes.
    """
    cached = _TUNE_CACHE.get(key)
    if cached is None:
        best, best_t = None, float("inf")
        failures = []
        for cand in cands:
            try:
                t = time_candidate(cand)
            except Exception as exc:  # a rejected/OOM tile disqualifies itself
                failures.append(f"{cand!r}: {type(exc).__name__}: {exc}")
                continue
            if t < best_t:
                best, best_t = cand, t
        fell_back = best is None
        if fell_back:  # every candidate failed: the heuristic choice
            best = fallback if fallback is not None else auto_tile(
                num_markets or 1)
        _TUNE_CACHE[key] = cached = best
        _SWEEP_REPORTS.append(SweepReport(
            key=key, winner=best, fell_back=fell_back, tried=tuple(cands),
            failures=tuple(failures)))
    if num_markets is not None:
        cached = cached._replace(
            m_padded=pad_to_multiple(max(1, num_markets), cached.mb))
    return cached


def time_call(fn: Callable[[], object], block: Callable[[object], None],
              trials: int = 2) -> float:
    """Best-of-``trials`` wall time of ``fn`` after one warmup/compile call."""
    block(fn())
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        block(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def clear_tune_cache() -> None:
    _TUNE_CACHE.clear()
    _SWEEP_REPORTS.clear()
