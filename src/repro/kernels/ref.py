"""Pure-jnp oracle for both Pallas kernels.

A single fused ``lax.scan`` over steps on full [M, L] arrays, using the same
shared step semantics. Kernel tests assert *bitwise* equality (not allclose)
against this oracle — valid because all accumulated quantities are exact
small integers in float32 (paper §IV-B's bitwise-identity argument).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.config import MarketConfig
from repro.core.result import SimResult
from repro.core.step import initial_state, simulate_step


@functools.partial(jax.jit, static_argnames=("cfg", "scan"))
def _run(bid, ask, last, pmid, *, cfg: MarketConfig, scan: str):
    from repro.core.step import MarketState

    market_ids = jnp.arange(cfg.num_markets, dtype=jnp.int32)[:, None]

    def step(state, s):
        new_state, out = simulate_step(cfg, state, s, market_ids, jnp, scan=scan)
        return new_state, (out.price[:, 0], out.volume[:, 0])

    state0 = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
    steps = jnp.arange(cfg.num_steps, dtype=jnp.int32)
    final, (pp, vp) = jax.lax.scan(step, state0, steps)
    return final.bid, final.ask, final.last_price, final.prev_mid, pp.T, vp.T


def simulate_reference(cfg: MarketConfig, scan: str = "cumsum") -> SimResult:
    state = initial_state(cfg, jnp)
    bid, ask, last, pmid, pp, vp = _run(
        state.bid, state.ask, state.last_price, state.prev_mid,
        cfg=cfg, scan=scan,
    )
    return SimResult(bid=bid, ask=ask, last_price=last, prev_mid=pmid,
                     price_path=pp, volume_path=vp)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _run_sequential(bid, ask, last, pmid, *, cfg: MarketConfig):
    from repro.core.sequential import simulate_step_sequential
    from repro.core.step import MarketState

    market_ids = jnp.arange(cfg.num_markets, dtype=jnp.int32)[:, None]

    def step(state, s):
        new_state, out = simulate_step_sequential(
            cfg, state, s, market_ids, jnp)
        return new_state, (out.price[:, 0], out.volume[:, 0])

    state0 = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
    steps = jnp.arange(cfg.num_steps, dtype=jnp.int32)
    final, (pp, vp) = jax.lax.scan(step, state0, steps)
    return final.bid, final.ask, final.last_price, final.prev_mid, pp.T, vp.T


def simulate_reference_sequential(cfg: MarketConfig) -> SimResult:
    """Jitted sequential-clearing reference (Steinbacher et al.): identical
    agent decisions, order-by-order immediate matching instead of the
    uniform-price call auction. Bitwise-identical to the NumPy host loop
    with ``clearing="sequential"`` — see :mod:`repro.core.sequential` —
    so the parallel-vs-sequential mechanism gap is attributable to the
    clearing rule alone, not to the driver."""
    state = initial_state(cfg, jnp)
    bid, ask, last, pmid, pp, vp = _run_sequential(
        state.bid, state.ask, state.last_price, state.prev_mid, cfg=cfg,
    )
    return SimResult(bid=bid, ask=ask, last_price=last, prev_mid=pmid,
                     price_path=pp, volume_path=vp)
