"""Persistent-state selective-scan kernel (mamba1 forward) — the paper's
pattern applied to the LM substrate (DESIGN.md §4.2, EXPERIMENTS §Perf
falcon-mamba iteration 3).

The XLA-level sequential scan pays ~(B·T·di·N) HBM traffic several times
over: the (B,di,N) loop carry round-trips HBM every step, backward saves
per-step states, and each step's update materializes. This kernel keeps the
recurrent state h resident in a VMEM scratch across the *entire* time loop —
exactly the market engine's shared-memory residency — collapsing HBM traffic
to the inputs/outputs:

    Θ(B·T·(di+N))  instead of  Θ(B·T·di·N)      (N-fold reduction)

Grid: (B, T/CT) with the time axis innermost ("arbitrary" = sequential on
TPU), so the scratch state carries across time chunks — the same
persistent-across-grid-steps trick as kinetic_clearing. Block layout:
di on lanes (128-multiples), N on sublanes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hT_ref, h_scratch, *, ct: int, n_t: int):
    """One (batch b, time-chunk t) grid cell; h persists in VMEM scratch."""
    t_idx = pl.program_id(1)

    # Load the initial state into the persistent scratch at the first chunk.
    @pl.when(t_idx == 0)
    def _init():
        h_scratch[...] = h0_ref[0]

    A = a_ref[...]              # [di, N]
    x = x_ref[0]                # [ct, di]
    dt = dt_ref[0]              # [ct, di]
    Bc = b_ref[0]               # [ct, N]
    Cc = c_ref[0]               # [ct, N]

    def t_step(i, h):
        dtt = dt[i]                                     # [di]
        decay = jnp.exp(dtt[:, None] * A)               # [di, N]
        h = decay * h + (dtt * x[i])[:, None] * Bc[i][None, :]
        y = jnp.sum(h * Cc[i][None, :], axis=-1)        # [di]
        y_ref[0, i, :] = y
        return h

    h = jax.lax.fori_loop(0, ct, t_step, h_scratch[...])
    h_scratch[...] = h

    # Final writeback once per batch row (paper Alg.1 line 24 analogue).
    @pl.when(t_idx == n_t - 1)
    def _done():
        hT_ref[0] = h


@functools.partial(jax.jit,
                   static_argnames=("ct", "interpret"))
def ssm_scan(x, dt, Bc, Cc, A, h0, *, ct: int = 256,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Mamba1 selective-scan forward.

    x, dt: f32[B, T, di]; Bc, Cc: f32[B, T, N]; A: f32[di, N];
    h0: f32[B, di, N]. Returns (y f32[B, T, di], hT f32[B, di, N]).
    """
    B, T, di = x.shape
    N = A.shape[-1]
    while T % ct:
        ct //= 2
    n_t = T // ct
    grid = (B, n_t)

    seq_spec = lambda w: pl.BlockSpec((1, ct, w), lambda b, t: (b, t, 0))
    state_spec = pl.BlockSpec((1, di, N), lambda b, t: (b, 0, 0))

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    y, hT = pl.pallas_call(
        functools.partial(_kernel, ct=ct, n_t=n_t),
        grid=grid,
        in_specs=[
            seq_spec(di), seq_spec(di), seq_spec(N), seq_spec(N),
            pl.BlockSpec((di, N), lambda b, t: (0, 0)),
            state_spec,
        ],
        out_specs=(seq_spec(di), state_spec),
        out_shape=(
            jax.ShapeDtypeStruct((B, T, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((di, N), jnp.float32)] if pltpu is not None
        else [],
        interpret=interpret,
        **kwargs,
    )(x, dt, Bc, Cc, A, h0)
    return y, hT


def hbm_traffic_bytes(B, T, di, N) -> dict:
    """Analytical HBM traffic of kernel vs XLA scan (per §Perf accounting)."""
    kernel = 4 * (B * T * (2 * di + 2 * N)   # x, dt, Bc, Cc reads
                  + B * T * di               # y writes
                  + 2 * B * di * N)          # h0 in, hT out
    xla_scan = 4 * (B * T * di * N * 4       # carry r/w + copies + saves
                    + B * T * (3 * di + 2 * N))
    return {"kernel": kernel, "xla_scan": xla_scan,
            "reduction": xla_scan / kernel}
