"""KineticSim persistent clearing kernel — the paper's contribution on TPU.

GPU original (paper §III): one CUDA block per market, LOB in ``__shared__``
memory for all S steps, atomicAdd order binning, Hillis–Steele scans,
tournament argmax.

TPU adaptation (DESIGN.md §2): one Pallas grid cell per *tile* of MB markets.
The entire S-step loop runs inside the kernel body; the books live in VMEM
(registers/VMEM values carried through ``lax.fori_loop``) and touch HBM only
at kernel entry/exit — HBM traffic is Θ(M·L), independent of S, exactly the
paper's claim. Order binning is a one-hot MXU contraction (the TPU-native
replacement for shared-memory atomics); clearing runs the same xp-polymorphic
``auction.clear`` / ``agents.decide`` code as every other backend, so results
are bitwise identical.

Block/tile layout: markets on sublanes (MB a multiple of 8 — the chunk
entries *pad* the market axis to a tile multiple instead of shrinking MB, so
prime/odd M keeps full sublane tiles; see :mod:`repro.kernels.autotune`),
price ticks on lanes (L multiple of 128 native; smaller L still correct,
just padded by the compiler). VMEM working set per grid cell ≈
``7·MB·L + MB·Ac·L (one-hot binning, Ac = agent_chunk ≤ A) + 2·MB·S`` f32
for path outputs, plus a negligible ``12·MB`` term for the per-market
parameter columns (the :class:`repro.core.params.MarketParams` operands,
one ``(MB, 1)`` block each) — padding adds only whole-tile rows, so the
padded-tile term is the same ``MB·(...)`` budget with ``grid =
ceil(M/MB)`` cells. In ``stats_only`` mode the ``2·MB·S`` path term is
replaced by a constant ``6·MB`` statistics-accumulator term
(count/Σmid/Σmid²/min/max/Σvolume), making both the VMEM footprint and the
HBM output traffic independent of the chunk length — see EXPERIMENTS.md
§Perf for the measured budget.

Scenario engine: archetype mixtures and scenario overlays (flash-crash
shock, volatility regimes, book seeding) are static ``cfg`` fields dispatched
branch-free inside ``simulate_step`` — every scenario traces to the same
fully fused persistent kernel, and baseline configs trace the identical
graph as before the scenario engine existed.

Sharding: the chunk entry takes an explicit per-row ``market_ids`` operand
(instead of deriving ids from the grid index), so a ``shard_map`` caller can
hand each device its true *global* market coordinates — the RNG stream is a
pure function of (seed, market id, step), which is what makes a sharded run
bitwise-identical to the single-device run. See ``repro.kernels.ops``.

Heterogeneous ensembles: every scenario-varying parameter — shock schedule
and intensities, marketable-flow probability, quantity cap, archetype
knobs, per-market population counts — enters the chunk entry as a
:class:`repro.core.params.MarketParams` operand of ``[M, 1]`` columns.
Each grid cell fetches its tile's rows (``(mb, 1)`` blocks on the sublane
axis, exactly like the ``market_ids``/``last_price`` scalars), so a single
compiled kernel serves any scenario mixture and any parameter values: only
the static shape ``(M, A, L, chunk)`` and the RNG seed are baked into the
trace. Scenario dispatch stays branch-free ``where`` selects inside
``simulate_step`` — per-market heterogeneity costs no divergence, because
there is none to diverge: the masks are just data.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional on CPU/interpret
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core import params as params_mod
from repro.core import stats as stats_mod
from repro.core.config import MarketConfig
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.step import MarketState, resolve_peer_mids, simulate_step
from repro.kernels.autotune import pad_to_multiple

#: Number of per-market parameter operands threaded into the chunk kernels.
NUM_PARAM_OPERANDS = len(MarketParams._fields)


def resolve_params(cfg, M: int, params: Optional[MarketParams],
                   xp) -> MarketParams:
    """The chunk entries' params operand: explicit > spec-owned > scalar
    broadcast of a legacy ``MarketConfig`` (value-identical constants)."""
    if params is not None:
        return params
    if isinstance(cfg, EnsembleSpec):
        return cfg.params.asarray(xp)
    return params_mod.params_from_config(cfg, M, xp)


def pad_params(params: MarketParams, m_padded: int) -> MarketParams:
    """Dtype-preserving zero-row padding of every parameter column (a pad
    row is a zero-count, zero-intensity market whose outputs are sliced
    off — see :func:`_pad_rows`)."""
    return MarketParams(*(
        _pad_rows(jnp.asarray(leaf, dtype=MarketParams.field_dtype(f)),
                  m_padded)
        for f, leaf in zip(MarketParams._fields, params)))


def _kernel_body(
    bid_ref, ask_ref, last_ref, pmid_ref,
    out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
    price_path_ref, volume_path_ref,
    *, cfg: MarketConfig, mb: int, scan: str,
):
    """Persistent scheduler (paper Alg. 1) for one tile of ``mb`` markets."""
    i = pl.program_id(0)
    S = cfg.num_steps

    # Phase 1: load opening books into VMEM-resident values (Alg.1 lines 2-3).
    bid = bid_ref[...]
    ask = ask_ref[...]
    last = last_ref[...]
    pmid = pmid_ref[...]

    market_ids = (i * mb + jnp.arange(mb, dtype=jnp.int32))[:, None]

    def body(s, carry):
        bid, ask, last, pmid, pp, vp = carry
        state = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
        # Phases 2-5 (Alg.1 lines 5-22): shared semantics, one-hot binning.
        new_state, out = simulate_step(
            cfg, state, s, market_ids, jnp, bin_orders=None, scan=scan
        )
        pp = jax.lax.dynamic_update_slice(pp, out.price, (0, s))
        vp = jax.lax.dynamic_update_slice(vp, out.volume, (0, s))
        return (new_state.bid, new_state.ask, new_state.last_price,
                new_state.prev_mid, pp, vp)

    pp0 = jnp.zeros((mb, S), jnp.float32)
    vp0 = jnp.zeros((mb, S), jnp.float32)
    bid, ask, last, pmid, pp, vp = jax.lax.fori_loop(
        0, S, body, (bid, ask, last, pmid, pp0, vp0)
    )

    # Final writeback (Alg.1 line 24) — the only per-market HBM stores.
    out_bid_ref[...] = bid
    out_ask_ref[...] = ask
    out_last_ref[...] = last
    out_pmid_ref[...] = pmid
    price_path_ref[...] = pp
    volume_path_ref[...] = vp


def pick_tile(num_markets: int, target: int = 8) -> int:
    """Largest divisor of M that is <= target (sublane-aligned when possible).

    Legacy policy for the exact-grid one-shot entries (`kinetic_clearing`,
    `naive_clearing`): prime/odd M degrades to MB=1. The session chunk
    entries instead pad the market axis and keep full sublane tiles — see
    :func:`repro.kernels.autotune.auto_tile`.
    """
    mb = min(target, num_markets)
    while num_markets % mb:
        mb -= 1
    return mb


def _pad_rows(x, m_padded: int):
    """Append zero rows up to ``m_padded`` (markets are row-independent, so
    benign zero-book pad rows never perturb real rows — branch-free mask by
    construction; the wrapper slices them off every output)."""
    pad = m_padded - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def _chunk_kernel_body(
    step0_ref, nvalid_ref, mids_ref,
    bid_ref, ask_ref, last_ref, pmid_ref, ext_buy_ref, ext_ask_ref,
    peer_ref,
    *refs,
    cfg, mb: int, chunk: int, scan: str,
    agent_chunk: Optional[int], stats_only: bool,
):
    """Session variant of the persistent scheduler: a fixed ``chunk``-length
    trace that serves *any* requested step count and *any* scenario mixture.

    ``step0`` (runtime scalar) offsets the RNG / scenario step coordinate so
    a warm session resumes mid-stream; ``n_valid`` (runtime scalar) gates the
    carried state with branch-free ``where`` masks so a partial tail chunk
    advances exactly ``n_valid`` steps without retracing. External orders
    (``ext_buy``/``ext_ask``, the RL stepping hook's reserved slot) are
    injected at the first local step only; zero arrays are bitwise no-ops.

    ``peer_ref`` is the coupling column: each row's *peer mid*, gathered by
    the chunk entry from the chunk-entry ``prev_mid`` (or by the sharded
    caller via the ring halo exchange) and held fixed for all ``chunk``
    steps — the freeze boundary every backend shares.

    ``mids_ref`` carries the per-row *global* market ids (sharded callers
    pass each device's true coordinates). The first ``NUM_PARAM_OPERANDS``
    of ``refs`` are the per-market :class:`MarketParams` columns — this
    tile's ``(mb, 1)`` rows of every scenario-varying knob, loaded into
    VMEM once and broadcast over the agent/level axes inside
    ``simulate_step``. In ``stats_only`` mode the per-step path outputs are
    replaced by six [mb, 1] running accumulators carried through the
    ``fori_loop`` — the kernel's HBM writes become Θ(MB·L) books plus
    Θ(MB) statistics, independent of ``chunk``.
    """
    step0 = step0_ref[0, 0]
    n_valid = nvalid_ref[0, 0]

    params = MarketParams(*(r[...] for r in refs[:NUM_PARAM_OPERANDS]))
    refs = refs[NUM_PARAM_OPERANDS:]

    if stats_only:
        (cnt_ref, smid_ref, ssq_ref, mn_ref, mx_ref, svol_ref,
         out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
         out_cnt_ref, out_smid_ref, out_ssq_ref, out_mn_ref, out_mx_ref,
         out_svol_ref) = refs
    else:
        (out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
         price_path_ref, volume_path_ref, mid_path_ref) = refs

    bid = bid_ref[...]
    ask = ask_ref[...]
    last = last_ref[...]
    pmid = pmid_ref[...]
    ext_b = ext_buy_ref[...]
    ext_a = ext_ask_ref[...]
    zeros_ext = jnp.zeros_like(ext_b)
    peer_mid = peer_ref[...]

    market_ids = mids_ref[...]
    # Step-invariant type lattice, hoisted out of the fori_loop.
    atype = params_mod.agent_types(params, cfg.num_agents, jnp)

    def advance(s, bid, ask, last, pmid):
        state = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
        eb = jnp.where(s == jnp.int32(0), ext_b, zeros_ext)
        ea = jnp.where(s == jnp.int32(0), ext_a, zeros_ext)
        new_state, out = simulate_step(
            cfg, state, step0 + s, market_ids, jnp, bin_orders=None,
            scan=scan, ext_buy=eb, ext_ask=ea, agent_chunk=agent_chunk,
            params=params, atype=atype, peer_mid=peer_mid,
        )
        # Steps past n_valid are computed but discarded — the carried state
        # only advances while active.
        active = s < n_valid
        bid = jnp.where(active, new_state.bid, bid)
        ask = jnp.where(active, new_state.ask, ask)
        last = jnp.where(active, new_state.last_price, last)
        pmid = jnp.where(active, new_state.prev_mid, pmid)
        return active, bid, ask, last, pmid, out

    if stats_only:
        st0 = stats_mod.MarketStats(
            count=cnt_ref[...], sum_mid=smid_ref[...], sumsq_mid=ssq_ref[...],
            min_mid=mn_ref[...], max_mid=mx_ref[...], sum_volume=svol_ref[...])

        def body(s, carry):
            bid, ask, last, pmid, st = carry
            active, bid, ask, last, pmid, out = advance(s, bid, ask, last, pmid)
            st = stats_mod.accumulate(st, out.mid, out.volume, active, jnp)
            return bid, ask, last, pmid, st

        bid, ask, last, pmid, st = jax.lax.fori_loop(
            0, chunk, body, (bid, ask, last, pmid, st0))
        out_cnt_ref[...] = st.count
        out_smid_ref[...] = st.sum_mid
        out_ssq_ref[...] = st.sumsq_mid
        out_mn_ref[...] = st.min_mid
        out_mx_ref[...] = st.max_mid
        out_svol_ref[...] = st.sum_volume
    else:
        def body(s, carry):
            bid, ask, last, pmid, pp, vp, mp = carry
            _, bid, ask, last, pmid, out = advance(s, bid, ask, last, pmid)
            # Caller slices the paths to the first n_valid columns.
            pp = jax.lax.dynamic_update_slice(pp, out.price, (0, s))
            vp = jax.lax.dynamic_update_slice(vp, out.volume, (0, s))
            mp = jax.lax.dynamic_update_slice(mp, out.mid, (0, s))
            return bid, ask, last, pmid, pp, vp, mp

        pp0 = jnp.zeros((mb, chunk), jnp.float32)
        vp0 = jnp.zeros((mb, chunk), jnp.float32)
        mp0 = jnp.zeros((mb, chunk), jnp.float32)
        bid, ask, last, pmid, pp, vp, mp = jax.lax.fori_loop(
            0, chunk, body, (bid, ask, last, pmid, pp0, vp0, mp0)
        )
        price_path_ref[...] = pp
        volume_path_ref[...] = vp
        mid_path_ref[...] = mp

    out_bid_ref[...] = bid
    out_ask_ref[...] = ask
    out_last_ref[...] = last
    out_pmid_ref[...] = pmid


def kinetic_clearing_chunk(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    step0: jax.Array, n_valid: jax.Array,
    ext_buy: jax.Array, ext_ask: jax.Array,
    *, cfg, chunk: int, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False, market_ids: Optional[jax.Array] = None,
    agent_chunk: Optional[int] = None,
    params: Optional[MarketParams] = None,
    peer_mid: Optional[jax.Array] = None,
    stats: Optional[stats_mod.MarketStats] = None, stats_only: bool = False,
) -> Tuple[jax.Array, ...]:
    """``num_steps``-parametrized persistent entry for the Session API.

    One trace (per static ``chunk`` length) serves every chunk of up to
    ``chunk`` steps: ``step0``/``n_valid`` are int32[1, 1] runtime scalars,
    and every scenario-varying parameter is a per-market ``[M, 1]`` operand
    (``params``, a :class:`repro.core.params.MarketParams`; defaults to the
    spec's own params, or to a broadcast of a legacy scalar config — the
    scalar default is value-identical to the pre-ensemble constants).
    Deliberately *not* jitted here — the session runner owns the ``jax.jit``
    wrapper so it can donate the state buffers and count traces.

    The market axis is padded to a multiple of ``mb`` with benign zero rows
    (and sliced back), so any M — prime, odd, tiny — runs full sublane-
    aligned tiles; parameter columns pad with zero rows too (a zero-count,
    shock-at-0-with-zero-intensity market whose outputs are discarded).
    ``market_ids`` (optional int32[M] / [M, 1]) carries each row's global
    coordinate for sharded callers; it defaults to ``arange(M)``.

    ``peer_mid`` (optional f32[M, 1]) is the chunk-frozen coupling column
    for arbitrageur agents. When ``None`` it is gathered here from the
    entry ``pmid`` at ``params.coupling_peer`` (self when < 0) over
    *local* row indices — correct whenever all rows are on one device.
    Sharded callers must pass the column explicitly (see the ring halo
    exchange in :mod:`repro.kernels.ops`), since a cross-shard peer is not
    addressable by a local gather.

    Returns ``(bid, ask, last, pmid, price_path[M, chunk],
    volume_path[M, chunk], mid_path[M, chunk])``, or with
    ``stats_only=True`` (which requires the carried ``stats`` accumulators)
    ``(bid, ask, last, pmid, MarketStats)`` — no per-step outputs ever
    reach HBM in that mode; only the first ``n_valid`` path columns are
    meaningful otherwise.
    """
    M, L = bid.shape
    m_padded = pad_to_multiple(M, mb)
    grid = (m_padded // mb,)

    if market_ids is None:
        market_ids = jnp.arange(M, dtype=jnp.int32)
    mids = jnp.reshape(jnp.asarray(market_ids, dtype=jnp.int32), (M, 1))
    if m_padded != M:
        pad_ids = jnp.arange(M, m_padded, dtype=jnp.int32)[:, None]
        mids = jnp.concatenate([mids, pad_ids], axis=0)
    params = resolve_params(cfg, M, params, jnp)
    if peer_mid is None:
        # Single-device default: gather the chunk-entry mids at the peer
        # rows (local indices == global ids here).
        peer_mid = resolve_peer_mids(pmid, params.coupling_peer, jnp)
    bid, ask, last, pmid, ext_buy, ext_ask, peer_mid = (
        _pad_rows(x, m_padded) for x in (bid, ask, last, pmid, ext_buy,
                                         ext_ask, peer_mid))
    params = pad_params(params, m_padded)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    step_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    path_spec = pl.BlockSpec((mb, chunk), lambda i: (i, 0))

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        )

    state_shapes = (
        jax.ShapeDtypeStruct((m_padded, L), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, L), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
    )
    in_specs = [step_spec, step_spec, scalar_spec, book_spec, book_spec,
                scalar_spec, scalar_spec, book_spec, book_spec,
                scalar_spec] + [scalar_spec] * NUM_PARAM_OPERANDS
    operands = [step0, n_valid, mids, bid, ask, last, pmid, ext_buy,
                ext_ask, peer_mid] + list(params)

    if stats_only:
        if stats is None:
            raise ValueError("stats_only=True requires the carried `stats` "
                             "accumulators (see repro.core.stats.init_stats)")
        stats = stats_mod.MarketStats(
            *(_pad_rows(jnp.asarray(x, dtype=jnp.float32), m_padded)
              for x in stats))
        stats_shape = jax.ShapeDtypeStruct((m_padded, 1), jnp.float32)
        in_specs += [scalar_spec] * 6
        operands += list(stats)
        out_specs = ((book_spec, book_spec, scalar_spec, scalar_spec)
                     + (scalar_spec,) * 6)
        out_shapes = state_shapes + (stats_shape,) * 6
    else:
        out_specs = (book_spec, book_spec, scalar_spec, scalar_spec,
                     path_spec, path_spec, path_spec)
        out_shapes = state_shapes + (
            jax.ShapeDtypeStruct((m_padded, chunk), jnp.float32),) * 3

    out = pl.pallas_call(
        functools.partial(_chunk_kernel_body, cfg=cfg, mb=mb, chunk=chunk,
                          scan=scan, agent_chunk=agent_chunk,
                          stats_only=stats_only),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
        **kwargs,
    )(*operands)

    out = tuple(x[:M] for x in out)
    if stats_only:
        return out[:4] + (stats_mod.MarketStats(*out[4:]),)
    return out


@functools.partial(
    jax.jit, static_argnames=("cfg", "mb", "scan", "interpret")
)
def kinetic_clearing(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    *, cfg: MarketConfig, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """Run the full S-step ensemble simulation in one persistent kernel.

    Args:
      bid/ask: float32[M, L] opening books; last/pmid: float32[M, 1].
    Returns:
      (bid, ask, last, pmid, price_path[M, S], volume_path[M, S]).
    """
    M, L = bid.shape
    S = cfg.num_steps
    if M % mb:
        raise ValueError(f"M={M} not divisible by tile mb={mb}")
    grid = (M // mb,)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    path_spec = pl.BlockSpec((mb, S), lambda i: (i, 0))

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        )

    out_shapes = (
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, S), jnp.float32),
        jax.ShapeDtypeStruct((M, S), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel_body, cfg=cfg, mb=mb, scan=scan),
        grid=grid,
        in_specs=[book_spec, book_spec, scalar_spec, scalar_spec],
        out_specs=(book_spec, book_spec, scalar_spec, scalar_spec,
                   path_spec, path_spec),
        out_shape=out_shapes,
        interpret=interpret,
        **kwargs,
    )(bid, ask, last, pmid)
