"""KineticSim persistent clearing kernel — the paper's contribution on TPU.

GPU original (paper §III): one CUDA block per market, LOB in ``__shared__``
memory for all S steps, atomicAdd order binning, Hillis–Steele scans,
tournament argmax.

TPU adaptation (DESIGN.md §2): one Pallas grid cell per *tile* of MB markets.
The entire S-step loop runs inside the kernel body; the books live in VMEM
(registers/VMEM values carried through ``lax.fori_loop``) and touch HBM only
at kernel entry/exit — HBM traffic is Θ(M·L), independent of S, exactly the
paper's claim. Order binning is a one-hot MXU contraction (the TPU-native
replacement for shared-memory atomics); clearing runs the same xp-polymorphic
``auction.clear`` / ``agents.decide`` code as every other backend, so results
are bitwise identical.

Block/tile layout: markets on sublanes (MB multiple of 8), price ticks on
lanes (L multiple of 128 native; smaller L still correct, just padded by the
compiler). VMEM working set ≈ (7·MB·L + MB·A·L_onehot-chunk + 2·MB·S) f32 —
see EXPERIMENTS.md §Perf for the measured budget.

Scenario engine: archetype mixtures and scenario overlays (flash-crash
shock, volatility regimes, book seeding) are static ``cfg`` fields dispatched
branch-free inside ``simulate_step`` — every scenario traces to the same
fully fused persistent kernel, and baseline configs trace the identical
graph as before the scenario engine existed.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional on CPU/interpret
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core.config import MarketConfig
from repro.core.step import MarketState, simulate_step


def _kernel_body(
    bid_ref, ask_ref, last_ref, pmid_ref,
    out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
    price_path_ref, volume_path_ref,
    *, cfg: MarketConfig, mb: int, scan: str,
):
    """Persistent scheduler (paper Alg. 1) for one tile of ``mb`` markets."""
    i = pl.program_id(0)
    S = cfg.num_steps

    # Phase 1: load opening books into VMEM-resident values (Alg.1 lines 2-3).
    bid = bid_ref[...]
    ask = ask_ref[...]
    last = last_ref[...]
    pmid = pmid_ref[...]

    market_ids = (i * mb + jnp.arange(mb, dtype=jnp.int32))[:, None]

    def body(s, carry):
        bid, ask, last, pmid, pp, vp = carry
        state = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
        # Phases 2-5 (Alg.1 lines 5-22): shared semantics, one-hot binning.
        new_state, out = simulate_step(
            cfg, state, s, market_ids, jnp, bin_orders=None, scan=scan
        )
        pp = jax.lax.dynamic_update_slice(pp, out.price, (0, s))
        vp = jax.lax.dynamic_update_slice(vp, out.volume, (0, s))
        return (new_state.bid, new_state.ask, new_state.last_price,
                new_state.prev_mid, pp, vp)

    pp0 = jnp.zeros((mb, S), jnp.float32)
    vp0 = jnp.zeros((mb, S), jnp.float32)
    bid, ask, last, pmid, pp, vp = jax.lax.fori_loop(
        0, S, body, (bid, ask, last, pmid, pp0, vp0)
    )

    # Final writeback (Alg.1 line 24) — the only per-market HBM stores.
    out_bid_ref[...] = bid
    out_ask_ref[...] = ask
    out_last_ref[...] = last
    out_pmid_ref[...] = pmid
    price_path_ref[...] = pp
    volume_path_ref[...] = vp


def pick_tile(num_markets: int, target: int = 8) -> int:
    """Largest divisor of M that is <= target (sublane-aligned when possible)."""
    mb = min(target, num_markets)
    while num_markets % mb:
        mb -= 1
    return mb


def _chunk_kernel_body(
    step0_ref, nvalid_ref,
    bid_ref, ask_ref, last_ref, pmid_ref, ext_buy_ref, ext_ask_ref,
    out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
    price_path_ref, volume_path_ref, mid_path_ref,
    *, cfg: MarketConfig, mb: int, chunk: int, scan: str,
):
    """Session variant of the persistent scheduler: a fixed ``chunk``-length
    trace that serves *any* requested step count.

    ``step0`` (runtime scalar) offsets the RNG / scenario step coordinate so
    a warm session resumes mid-stream; ``n_valid`` (runtime scalar) gates the
    carried state with branch-free ``where`` masks so a partial tail chunk
    advances exactly ``n_valid`` steps without retracing. External orders
    (``ext_buy``/``ext_ask``, the RL stepping hook's reserved slot) are
    injected at the first local step only; zero arrays are bitwise no-ops.
    """
    i = pl.program_id(0)
    step0 = step0_ref[0, 0]
    n_valid = nvalid_ref[0, 0]

    bid = bid_ref[...]
    ask = ask_ref[...]
    last = last_ref[...]
    pmid = pmid_ref[...]
    ext_b = ext_buy_ref[...]
    ext_a = ext_ask_ref[...]
    zeros_ext = jnp.zeros_like(ext_b)

    market_ids = (i * mb + jnp.arange(mb, dtype=jnp.int32))[:, None]

    def body(s, carry):
        bid, ask, last, pmid, pp, vp, mp = carry
        state = MarketState(bid=bid, ask=ask, last_price=last, prev_mid=pmid)
        eb = jnp.where(s == jnp.int32(0), ext_b, zeros_ext)
        ea = jnp.where(s == jnp.int32(0), ext_a, zeros_ext)
        new_state, out = simulate_step(
            cfg, state, step0 + s, market_ids, jnp, bin_orders=None,
            scan=scan, ext_buy=eb, ext_ask=ea,
        )
        # Steps past n_valid are computed but discarded — the carried state
        # only advances while active, and the caller slices the paths.
        active = s < n_valid
        bid = jnp.where(active, new_state.bid, bid)
        ask = jnp.where(active, new_state.ask, ask)
        last = jnp.where(active, new_state.last_price, last)
        pmid = jnp.where(active, new_state.prev_mid, pmid)
        pp = jax.lax.dynamic_update_slice(pp, out.price, (0, s))
        vp = jax.lax.dynamic_update_slice(vp, out.volume, (0, s))
        mp = jax.lax.dynamic_update_slice(mp, out.mid, (0, s))
        return bid, ask, last, pmid, pp, vp, mp

    pp0 = jnp.zeros((mb, chunk), jnp.float32)
    vp0 = jnp.zeros((mb, chunk), jnp.float32)
    mp0 = jnp.zeros((mb, chunk), jnp.float32)
    bid, ask, last, pmid, pp, vp, mp = jax.lax.fori_loop(
        0, chunk, body, (bid, ask, last, pmid, pp0, vp0, mp0)
    )

    out_bid_ref[...] = bid
    out_ask_ref[...] = ask
    out_last_ref[...] = last
    out_pmid_ref[...] = pmid
    price_path_ref[...] = pp
    volume_path_ref[...] = vp
    mid_path_ref[...] = mp


def kinetic_clearing_chunk(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    step0: jax.Array, n_valid: jax.Array,
    ext_buy: jax.Array, ext_ask: jax.Array,
    *, cfg: MarketConfig, chunk: int, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """``num_steps``-parametrized persistent entry for the Session API.

    One trace (per static ``chunk`` length) serves every chunk of up to
    ``chunk`` steps: ``step0``/``n_valid`` are int32[1, 1] runtime scalars.
    Deliberately *not* jitted here — the session runner owns the ``jax.jit``
    wrapper so it can donate the state buffers and count traces.

    Returns ``(bid, ask, last, pmid, price_path[M, chunk],
    volume_path[M, chunk], mid_path[M, chunk])``; only the first ``n_valid``
    path columns are meaningful.
    """
    M, L = bid.shape
    if M % mb:
        raise ValueError(f"M={M} not divisible by tile mb={mb}")
    grid = (M // mb,)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    step_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    path_spec = pl.BlockSpec((mb, chunk), lambda i: (i, 0))

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        )

    out_shapes = (
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, chunk), jnp.float32),
        jax.ShapeDtypeStruct((M, chunk), jnp.float32),
        jax.ShapeDtypeStruct((M, chunk), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_chunk_kernel_body, cfg=cfg, mb=mb, chunk=chunk,
                          scan=scan),
        grid=grid,
        in_specs=[step_spec, step_spec, book_spec, book_spec, scalar_spec,
                  scalar_spec, book_spec, book_spec],
        out_specs=(book_spec, book_spec, scalar_spec, scalar_spec,
                   path_spec, path_spec, path_spec),
        out_shape=out_shapes,
        interpret=interpret,
        **kwargs,
    )(step0, n_valid, bid, ask, last, pmid, ext_buy, ext_ask)


@functools.partial(
    jax.jit, static_argnames=("cfg", "mb", "scan", "interpret")
)
def kinetic_clearing(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    *, cfg: MarketConfig, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """Run the full S-step ensemble simulation in one persistent kernel.

    Args:
      bid/ask: float32[M, L] opening books; last/pmid: float32[M, 1].
    Returns:
      (bid, ask, last, pmid, price_path[M, S], volume_path[M, S]).
    """
    M, L = bid.shape
    S = cfg.num_steps
    if M % mb:
        raise ValueError(f"M={M} not divisible by tile mb={mb}")
    grid = (M // mb,)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    path_spec = pl.BlockSpec((mb, S), lambda i: (i, 0))

    kwargs = {}
    if pltpu is not None and not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        )

    out_shapes = (
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, S), jnp.float32),
        jax.ShapeDtypeStruct((M, S), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel_body, cfg=cfg, mb=mb, scan=scan),
        grid=grid,
        in_specs=[book_spec, book_spec, scalar_spec, scalar_spec],
        out_specs=(book_spec, book_spec, scalar_spec, scalar_spec,
                   path_spec, path_spec),
        out_shape=out_shapes,
        interpret=interpret,
        **kwargs,
    )(bid, ask, last, pmid)
