"""Jit'd public wrappers for the Pallas engines + backend registration.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as pure JAX ops — bit-exact semantics); on TPU the same
entry points lower via Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import jax

from repro.core import engine
from repro.core.config import MarketConfig
from repro.core.result import SimResult
from repro.core.step import initial_state
from repro.kernels.kinetic_clearing import kinetic_clearing, pick_tile
from repro.kernels.naive_clearing import naive_clearing


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _simulate_with(kernel_fn, cfg: MarketConfig, mb=None, scan="cumsum",
                   interpret=None) -> SimResult:
    import jax.numpy as jnp

    state = initial_state(cfg, jnp)
    mb = pick_tile(cfg.num_markets) if mb is None else mb
    bid, ask, last, pmid, pp, vp = kernel_fn(
        state.bid, state.ask, state.last_price, state.prev_mid,
        cfg=cfg, mb=mb, scan=scan, interpret=_auto_interpret(interpret),
    )
    return SimResult(bid=bid, ask=ask, last_price=last, prev_mid=pmid,
                     price_path=pp, volume_path=vp)


@engine.register("pallas-kinetic")
def simulate_kinetic(cfg: MarketConfig, mb=None, scan="cumsum",
                     interpret=None) -> SimResult:
    """The paper's engine: persistent, VMEM-resident, one kernel for S steps."""
    return _simulate_with(kinetic_clearing, cfg, mb=mb, scan=scan,
                          interpret=interpret)


@engine.register("pallas-naive")
def simulate_naive(cfg: MarketConfig, mb=None, scan="cumsum",
                   interpret=None) -> SimResult:
    """Ablation: per-step kernel launches, HBM-resident book."""
    return _simulate_with(naive_clearing, cfg, mb=mb, scan=scan,
                          interpret=interpret)
