"""Session runners + public wrappers for the Pallas engines.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as pure JAX ops — bit-exact semantics); on TPU the same
entry points lower via Mosaic. ``interpret=None`` auto-detects.

Both Pallas families are plumbed through the Session API: the chunk entries
(:func:`repro.kernels.kinetic_clearing.kinetic_clearing_chunk`,
:func:`repro.kernels.naive_clearing.naive_clearing_chunk`) take runtime
``(step0, n_valid)`` scalars over a static chunk length, so one trace serves
any requested step count; the runner jits them with donated state buffers.
``simulate_kinetic``/``simulate_naive`` remain one-session compatibility
wrappers registered behind ``engine.simulate``.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import session
from repro.core.config import MarketConfig
from repro.core.result import SimResult
from repro.core.step import MarketState
from repro.kernels.kinetic_clearing import kinetic_clearing_chunk, pick_tile
from repro.kernels.naive_clearing import naive_clearing_chunk


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


class PallasChunkRunner(session.ChunkRunner):
    """jit wrapper around a chunk-parametrized Pallas entry point."""

    xp = jnp

    def __init__(self, kernel_chunk_fn, cfg: MarketConfig, chunk: int,
                 mb: Optional[int], scan: str, interpret: Optional[bool]):
        super().__init__()
        self.cfg = cfg
        self.chunk = int(chunk)
        mb = pick_tile(cfg.num_markets) if mb is None else mb
        interpret = _auto_interpret(interpret)
        M, L = cfg.num_markets, cfg.num_levels
        self._zero_ext = (jnp.zeros((M, L), jnp.float32),
                          jnp.zeros((M, L), jnp.float32))

        def chunk_fn(state, step0, n_valid, ext_buy, ext_ask):
            self._trace_count += 1  # python side effect: trace-time only
            return kernel_chunk_fn(
                state.bid, state.ask, state.last_price, state.prev_mid,
                step0, n_valid, ext_buy, ext_ask,
                cfg=cfg, chunk=self.chunk, mb=mb, scan=scan,
                interpret=interpret,
            )

        self._chunk_fn = jax.jit(chunk_fn, donate_argnums=(0,))

    def run(self, state: MarketState, aux, step0: int, n: int,
            ext) -> Tuple[MarketState, Any, session.StepBatch]:
        eb, ea = self._zero_ext if ext is None else ext
        step0_arr = jnp.full((1, 1), step0, dtype=jnp.int32)
        nvalid_arr = jnp.full((1, 1), n, dtype=jnp.int32)
        bid, ask, last, pmid, pp, vp, mp = self._chunk_fn(
            state, step0_arr, nvalid_arr, eb, ea)
        new_state = MarketState(bid=bid, ask=ask, last_price=last,
                                prev_mid=pmid)
        return new_state, aux, session.StepBatch(
            price=pp[:, :n], volume=vp[:, :n], mid=mp[:, :n])


@session.register_backend("pallas-kinetic")
def open_kinetic_runner(cfg: MarketConfig, chunk: int, mb=None,
                        scan: str = "cumsum",
                        interpret: Optional[bool] = None) -> PallasChunkRunner:
    """The paper's engine: persistent, VMEM-resident, one launch per chunk."""
    return PallasChunkRunner(kinetic_clearing_chunk, cfg, chunk, mb=mb,
                             scan=scan, interpret=interpret)


@session.register_backend("pallas-naive")
def open_naive_runner(cfg: MarketConfig, chunk: int, mb=None,
                      scan: str = "cumsum",
                      interpret: Optional[bool] = None) -> PallasChunkRunner:
    """Ablation: per-step kernel launches, HBM-resident book."""
    return PallasChunkRunner(naive_clearing_chunk, cfg, chunk, mb=mb,
                             scan=scan, interpret=interpret)


def _simulate_with(factory, cfg: MarketConfig, **opts: Any) -> SimResult:
    runner = factory(cfg, min(session.DEFAULT_CHUNK, cfg.num_steps), **opts)
    return session.run_runner_to_result(runner, cfg)


def simulate_kinetic(cfg: MarketConfig, mb=None, scan: str = "cumsum",
                     interpret: Optional[bool] = None) -> SimResult:
    """Compatibility wrapper: one-session run of the persistent engine."""
    return _simulate_with(open_kinetic_runner, cfg, mb=mb, scan=scan,
                          interpret=interpret)


def simulate_naive(cfg: MarketConfig, mb=None, scan: str = "cumsum",
                   interpret: Optional[bool] = None) -> SimResult:
    """Compatibility wrapper: one-session run of the per-step ablation."""
    return _simulate_with(open_naive_runner, cfg, mb=mb, scan=scan,
                          interpret=interpret)
