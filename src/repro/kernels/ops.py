"""Session runners + public wrappers for the Pallas engines.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as pure JAX ops — bit-exact semantics); on TPU the same
entry points lower via Mosaic. ``interpret=None`` auto-detects.

Both Pallas families are plumbed through the Session API: the chunk entries
(:func:`repro.kernels.kinetic_clearing.kinetic_clearing_chunk`,
:func:`repro.kernels.naive_clearing.naive_clearing_chunk`) take runtime
``(step0, n_valid)`` scalars plus the per-market
:class:`repro.core.params.MarketParams` operands over a static chunk
length, so one trace serves any requested step count *and any scenario
mixture*; the runner jits them with donated state buffers (params are
never donated — a session's scenario operands persist device-resident).
``simulate_kinetic``/``simulate_naive`` remain one-session compatibility
wrappers registered behind ``engine.simulate``.

Scaling knobs (Engine backend_opts, all composable):

  * ``devices=N`` / ``mesh=`` — shard the market axis across a 1-D
    ``("markets",)`` device mesh with ``shard_map`` over the chunk kernel.
    Each shard receives its rows' true *global* market ids — and its rows
    of every parameter column — so a sharded heterogeneous ensemble is
    bitwise-identical to the single-device run; state stays
    device-resident and donated, sharded row-wise (uneven M is padded to a
    whole tile per shard and sliced back).
  * ``stats_only=True`` — replace the per-step path outputs with in-kernel
    running statistics (see :mod:`repro.core.stats`): the kernel's HBM
    output traffic drops from Θ(M·chunk) to Θ(M), independent of horizon.
  * ``mb=`` / ``agent_chunk=`` / ``autotune=`` — tile selection. By default
    the market axis is padded to sublane-aligned MB=8 tiles
    (:func:`repro.kernels.autotune.auto_tile`); ``autotune=True`` (or
    ``"auto"``, which sweeps only when lowering via Mosaic on real TPU)
    times (MB, agent-chunk) candidates on first compile and caches the
    winner per ``(device-kind, L, A, chunk)`` for every engine in the
    process.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import session
from repro.core import stats as stats_mod
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.result import SimResult
from repro.core.step import MarketState, StepOutput, initial_state
from repro.kernels import autotune as tune
from repro.kernels.kinetic_clearing import (_pad_rows, kinetic_clearing_chunk,
                                            pad_params, pick_tile)
from repro.kernels.naive_clearing import naive_clearing_chunk
from repro.launch.mesh import make_markets_mesh
from repro.launch.sharding import market_sharding, replicated_sharding


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _resolve_mesh(mesh, devices):
    if mesh is not None:
        return mesh
    if devices is not None:
        return make_markets_mesh(devices)
    return None


def _zero_params(num_markets: int) -> MarketParams:
    """Valid all-zero parameter columns (autotune timing operands)."""
    return MarketParams.zeros(num_markets, jnp)


class PallasChunkRunner(session.ChunkRunner):
    """jit wrapper around a chunk-parametrized Pallas entry point.

    Optionally shards the market axis over a ``("markets",)`` mesh and/or
    runs in ``stats_only`` mode; see the module docstring for the knobs.
    """

    xp = jnp
    compiled = True
    env_traceable = True
    env_runtime_seed = False  # the kernel trace bakes the RNG seed

    def __init__(self, kernel_chunk_fn, spec: EnsembleSpec, chunk: int,
                 mb: Optional[int], scan: str, interpret: Optional[bool],
                 stats_only: bool = False,
                 agent_chunk: Optional[int] = None,
                 devices: Optional[int] = None, mesh=None,
                 autotune="auto"):
        super().__init__()
        self.spec = spec
        self.chunk = int(chunk)
        self.stats_only = bool(stats_only)
        interpret = _auto_interpret(interpret)
        self._interpret = interpret
        self._scan = scan
        self._kernel_chunk_fn = kernel_chunk_fn
        self._mesh = _resolve_mesh(mesh, devices)
        M, L = spec.num_markets, spec.num_levels

        # Per-shard market count: tiles are chosen for (and padding applied
        # to) each shard's local slice.
        n_shards = self._mesh.devices.size if self._mesh is not None else 1
        self._n_shards = n_shards
        m_local = -(-M // n_shards)
        self.tile = self._resolve_tile(kernel_chunk_fn, spec, m_local, mb,
                                       agent_chunk, scan, interpret, autotune)

        self._zero_ext = (jnp.zeros((M, L), jnp.float32),
                          jnp.zeros((M, L), jnp.float32))

        pure_chunk = self._build_chunk_fn(self.chunk, self.stats_only)

        def chunk_fn(state, stats, params, step0, n_valid,
                     ext_buy, ext_ask):
            self._trace_count += 1  # python side effect: trace-time only
            return pure_chunk(state, stats, params, step0, n_valid,
                              ext_buy, ext_ask)

        if self._mesh is None:
            self._chunk_fn = jax.jit(chunk_fn, donate_argnums=(0, 1))
        else:
            row = self._row_sharding = market_sharding(self._mesh)
            rep = replicated_sharding(self._mesh)
            state_sh = MarketState(row, row, row, row)
            params_sh = MarketParams(*(row,) * len(MarketParams._fields))
            stats_sh = (stats_mod.MarketStats(*(row,) * 6)
                        if self.stats_only else None)
            out_sh = ((state_sh, stats_sh) if self.stats_only
                      else (state_sh, (row, row, row)))
            self._chunk_fn = jax.jit(
                chunk_fn, donate_argnums=(0, 1),
                in_shardings=(state_sh, stats_sh, params_sh, rep, rep,
                              row, row),
                out_shardings=out_sh)

    def _build_chunk_fn(self, chunk: int, stats_only: bool):
        """Pure ``(state, stats, params, step0, n_valid, ext_buy, ext_ask)
        -> (MarketState, payload)`` chunk executor around the kernel entry.

        The single construction site for both front doors: the Session
        wraps the runner-chunk instance in ``jax.jit`` with donated state
        buffers; the RL env (:meth:`env_step_fn`) embeds a ``chunk=1``
        instance inside its own jitted step/rollout graphs. Mesh-opened
        runners wrap the kernel in the same ``shard_map`` either way, so
        env rollouts compose with market-axis sharding unchanged.
        """
        spec = self.spec
        kernel_chunk_fn = self._kernel_chunk_fn
        M = spec.num_markets
        kernel_kwargs = dict(cfg=spec, chunk=chunk, mb=self.tile.mb,
                             scan=self._scan, interpret=self._interpret,
                             agent_chunk=self.tile.agent_chunk,
                             stats_only=stats_only)

        if self._mesh is None:
            def pure_chunk(state, stats, params, step0, n_valid,
                           ext_buy, ext_ask):
                return self._split(kernel_chunk_fn(
                    state.bid, state.ask, state.last_price, state.prev_mid,
                    step0, n_valid, ext_buy, ext_ask, params=params,
                    stats=stats, **kernel_kwargs), stats_only)

            return pure_chunk

        mesh_ = self._mesh
        n_shards = self._n_shards
        m_shard = tune.pad_to_multiple(-(-M // self._n_shards), self.tile.mb)
        m_padded = self._n_shards * m_shard
        ring = [(i, (i + 1) % n_shards) for i in range(n_shards)]

        def shard_body(step0, n_valid, mids, bid, ask, last, pmid,
                       ext_buy, ext_ask, params, stats):
            # Coupling halo exchange: a cross-market peer may live on
            # another shard, so the chunk-entry mids circulate the ring
            # once (`ppermute` all-gather) before the local gather. The
            # global padding sits at the END of the market axis, so a
            # real row's global id IS its row index in `full` — the
            # gathered column is bitwise what the single-device entry
            # computes, which is what makes coupled sharded runs
            # bitwise-identical (and `peer < 0` resolves to the row's own
            # global id, i.e. self-coupling).
            idx = jax.lax.axis_index("markets")
            full = jnp.zeros((m_padded, 1), pmid.dtype)
            cur = pmid
            for k in range(n_shards):
                src = (idx - k) % n_shards
                full = jax.lax.dynamic_update_slice(full, cur,
                                                    (src * m_shard, 0))
                if k + 1 < n_shards:
                    cur = jax.lax.ppermute(cur, "markets", ring)
            peer = jnp.reshape(
                jnp.asarray(params.coupling_peer, jnp.int32), (-1, 1))
            resolved = jnp.where(peer < jnp.int32(0), mids, peer)
            peer_mid = jnp.take_along_axis(full, resolved, axis=0)
            return kernel_chunk_fn(
                bid, ask, last, pmid, step0, n_valid, ext_buy, ext_ask,
                market_ids=mids, params=params, peer_mid=peer_mid,
                stats=stats, **kernel_kwargs)

        row_params = MarketParams(*(P("markets", None),)
                                  * len(MarketParams._fields))
        sharded_call = shard_map(
            shard_body, mesh=mesh_,
            in_specs=(P(), P(), P("markets", None), P("markets", None),
                      P("markets", None), P("markets", None),
                      P("markets", None), P("markets", None),
                      P("markets", None), row_params,
                      P("markets", None) if stats_only else None),
            out_specs=P("markets", None), check_rep=False)

        def pure_chunk(state, stats, params, step0, n_valid,
                       ext_buy, ext_ask):
            # Pad/slice every call rather than carrying padded state:
            # Θ(M·L) per chunk vs the kernel's Θ(chunk·A·L) work, and it
            # keeps session state — and therefore snapshots — in the
            # canonical [M, ...] layout on every device topology.
            padded = [_pad_rows(x, m_padded) for x in state]
            eb = _pad_rows(ext_buy, m_padded)
            ea = _pad_rows(ext_ask, m_padded)
            pp = pad_params(params, m_padded)
            # Global row coordinates: rows < M are real markets, pad rows
            # get distinct ids >= M whose streams are discarded.
            mids = jnp.arange(m_padded, dtype=jnp.int32)[:, None]
            st = None
            if stats_only:
                st = stats_mod.MarketStats(
                    *(_pad_rows(x, m_padded) for x in stats))
            out = sharded_call(step0, n_valid, mids, *padded, eb, ea,
                               pp, st)
            return self._split(
                tuple(x[:M] for x in jax.tree_util.tree_leaves(out)),
                stats_only)

        return pure_chunk

    def env_step_fn(self):
        """Traceable per-step core for :class:`repro.env.MarketEnv`: one
        ``chunk=1`` persistent-kernel call (sharded when the runner is),
        embeddable in the env's jitted ``lax.scan`` rollouts."""
        pure_step = self._build_chunk_fn(1, False)
        one = jnp.ones((1, 1), jnp.int32)

        def step_core(market, params, t, ext_buy, ext_ask, seed, aux):
            step0 = jnp.reshape(jnp.asarray(t, dtype=jnp.int32), (1, 1))
            state, payload = pure_step(market, None, params, step0, one,
                                       ext_buy, ext_ask)
            pp, vp, mp = payload
            return state, StepOutput(price=pp, volume=vp, mid=mp), aux

        return step_core

    # ---- tile selection ----
    def _resolve_tile(self, kernel_chunk_fn, spec, m_local, mb, agent_chunk,
                      scan, interpret, autotune) -> tune.TileChoice:
        if mb is not None:
            return tune.TileChoice(
                mb=mb, m_padded=tune.pad_to_multiple(m_local, mb),
                agent_chunk=(agent_chunk if agent_chunk is not None
                             else tune.default_agent_chunk(spec.num_agents)))
        sweep = autotune is True or (autotune == "auto" and not interpret)
        heuristic = tune.auto_tile(m_local, spec.num_agents)
        if agent_chunk is not None:
            heuristic = heuristic._replace(agent_chunk=agent_chunk)
        if not sweep:
            return heuristic

        def time_candidate(choice: tune.TileChoice) -> float:
            M, L = m_local, spec.num_levels
            m0 = jnp.float32(spec.mid0)
            bid = jnp.zeros((M, L), jnp.float32)
            scalars = jnp.ones((M, 1), jnp.float32) * m0
            step0 = jnp.zeros((1, 1), jnp.int32)
            nv = jnp.full((1, 1), self.chunk, jnp.int32)
            zp = _zero_params(M)
            st = (stats_mod.init_stats(M, jnp) if self.stats_only else None)

            @jax.jit
            def fn():
                return kernel_chunk_fn(
                    bid, bid, scalars, scalars, step0, nv, bid, bid,
                    cfg=spec, chunk=self.chunk, mb=choice.mb, scan=scan,
                    interpret=interpret, agent_chunk=choice.agent_chunk,
                    params=zp, stats=st, stats_only=self.stats_only)

            return tune.time_call(fn, jax.block_until_ready)

        # An explicitly pinned agent_chunk is never swept away, and distinct
        # kernel configurations (family / scan / stats mode) never share a
        # measured winner.
        key = tune.tune_key(
            spec.num_levels, spec.num_agents, self.chunk,
            kernel=kernel_chunk_fn.__name__, scan=scan,
            stats_only=self.stats_only, agent_chunk=agent_chunk)
        cands = tune.candidate_tiles(
            m_local, spec.num_agents,
            agent_chunk=agent_chunk if agent_chunk is not None else ...)
        return tune.autotune_tile(key, time_candidate, cands,
                                  fallback=heuristic, num_markets=m_local)

    # ---- placement hooks (sharded state stays sharded across snapshots) ----
    def init_state(self, spec: EnsembleSpec) -> MarketState:
        return self.to_device(initial_state(spec, np))

    def to_device(self, state: MarketState) -> MarketState:
        state = super().to_device(state)
        if self._mesh is None:
            return state
        return MarketState(*(jax.device_put(x, self._row_sharding)
                             for x in state))

    def params_to_device(self, params: MarketParams) -> MarketParams:
        params = super().params_to_device(params)
        if self._mesh is None:
            return params
        return MarketParams(*(jax.device_put(x, self._row_sharding)
                              for x in params))

    def init_stats(self, spec: EnsembleSpec):
        stats = super().init_stats(spec)
        if stats is None or self._mesh is None:
            return stats
        return self.stats_to_device(stats)

    def stats_to_device(self, stats):
        stats = super().stats_to_device(stats)
        if self._mesh is None:
            return stats
        return stats_mod.MarketStats(
            *(jax.device_put(x, self._row_sharding) for x in stats))

    # ---- execution ----
    def _split(self, out, stats_only: Optional[bool] = None):
        """Kernel output tuple -> (MarketState, payload)."""
        if stats_only is None:
            stats_only = self.stats_only
        state = MarketState(bid=out[0], ask=out[1], last_price=out[2],
                            prev_mid=out[3])
        if stats_only:
            rest = out[4]
            if not isinstance(rest, stats_mod.MarketStats):
                rest = stats_mod.MarketStats(*out[4:])
            return state, rest
        return state, tuple(out[4:])

    def run(self, state: MarketState, params: MarketParams, aux,
            step0: int, n: int, ext,
            stats=None) -> Tuple[MarketState, Any, session.StepBatch, Any]:
        eb, ea = self._zero_ext if ext is None else ext
        step0_arr = jnp.full((1, 1), step0, dtype=jnp.int32)
        nvalid_arr = jnp.full((1, 1), n, dtype=jnp.int32)
        new_state, payload = self._chunk_fn(
            state, stats if self.stats_only else None, params,
            step0_arr, nvalid_arr, jnp.asarray(eb), jnp.asarray(ea))
        if self.stats_only:
            empty = jnp.zeros((self.spec.num_markets, 0), jnp.float32)
            return (new_state, aux,
                    session.StepBatch(price=empty, volume=empty, mid=empty),
                    payload)
        pp, vp, mp = payload
        return new_state, aux, session.StepBatch(
            price=pp[:, :n], volume=vp[:, :n], mid=mp[:, :n]), None


@session.register_backend("pallas-kinetic")
def open_kinetic_runner(spec, chunk: int, mb=None,
                        scan: str = "cumsum",
                        interpret: Optional[bool] = None,
                        **opts: Any) -> PallasChunkRunner:
    """The paper's engine: persistent, VMEM-resident, one launch per chunk."""
    return PallasChunkRunner(kinetic_clearing_chunk, EnsembleSpec.coerce(spec),
                             chunk, mb=mb, scan=scan, interpret=interpret,
                             **opts)


@session.register_backend("pallas-naive")
def open_naive_runner(spec, chunk: int, mb=None,
                      scan: str = "cumsum",
                      interpret: Optional[bool] = None,
                      **opts: Any) -> PallasChunkRunner:
    """Ablation: per-step kernel launches, HBM-resident book."""
    return PallasChunkRunner(naive_clearing_chunk, EnsembleSpec.coerce(spec),
                             chunk, mb=mb, scan=scan, interpret=interpret,
                             **opts)


def _simulate_with(factory, cfg, **opts: Any) -> SimResult:
    spec = EnsembleSpec.coerce(cfg)
    runner = factory(spec, min(session.DEFAULT_CHUNK, spec.num_steps), **opts)
    return session.run_runner_to_result(runner, spec)


def simulate_kinetic(cfg, mb=None, scan: str = "cumsum",
                     interpret: Optional[bool] = None,
                     **opts: Any) -> SimResult:
    """Compatibility wrapper: one-session run of the persistent engine."""
    return _simulate_with(open_kinetic_runner, cfg, mb=mb, scan=scan,
                          interpret=interpret, **opts)


def simulate_naive(cfg, mb=None, scan: str = "cumsum",
                   interpret: Optional[bool] = None,
                   **opts: Any) -> SimResult:
    """Compatibility wrapper: one-session run of the per-step ablation."""
    return _simulate_with(open_naive_runner, cfg, mb=mb, scan=scan,
                          interpret=interpret, **opts)
