"""Naive per-step kernel — the paper's "Naive Custom CUDA" ablation on TPU.

Identical device-side semantics (same ``agents.decide``, same
``auction.clear``, same RNG), but the two central optimizations removed:

  * **No persistence**: one ``pallas_call`` per simulation step, driven by a
    host-level ``lax.scan``. The book round-trips HBM every step — the
    Θ(S·M·L) global-traffic regime of paper §III-F, plus Θ(S) kernel
    dispatches instead of one.

On TPU the GPU notion of a "one-thread serial scan" has no analogue (the VPU
is always SIMD over lanes), so this ablation isolates the *persistence* axis;
the scan-depth axis is exercised separately via the ``scan=`` mode flag
('hillis-steele' log-depth vs 'cumsum'). The performance gap between this and
:mod:`kinetic_clearing` is a clean attribution to state residency (§IV-I).

Scenario configs (archetype mixtures, flash-crash shocks, regimes) dispatch
branch-free inside the shared ``simulate_step``, so this ablation stays
bitwise comparable to the persistent kernel on every scenario — the basis of
the parity matrix in tests/test_parity_matrix.py.

The chunk entry mirrors :func:`kinetic_clearing_chunk`'s full contract —
padded sublane tiles, explicit global ``market_ids`` for sharded callers,
per-market :class:`repro.core.params.MarketParams` operands (``(mb, 1)``
columns fetched into each tile, so one compiled step kernel serves any
scenario mixture), and a ``stats_only`` mode (accumulated in the host scan
carry here, since per-step launches are this ablation's point) — so the
Session/shard layers treat both engines uniformly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import stats as stats_mod
from repro.core.config import MarketConfig
from repro.core.params import MarketParams
from repro.core.step import MarketState, resolve_peer_mids, simulate_step
from repro.kernels.autotune import pad_to_multiple
from repro.kernels.kinetic_clearing import (NUM_PARAM_OPERANDS, _pad_rows,
                                            pad_params, pick_tile,
                                            resolve_params)


def _step_kernel_body(
    step_ref,
    bid_ref, ask_ref, last_ref, pmid_ref,
    out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
    price_ref, volume_ref,
    *, cfg: MarketConfig, mb: int, scan: str,
):
    i = pl.program_id(0)
    s = step_ref[0, 0]
    market_ids = (i * mb + jnp.arange(mb, dtype=jnp.int32))[:, None]
    state = MarketState(
        bid=bid_ref[...], ask=ask_ref[...],
        last_price=last_ref[...], prev_mid=pmid_ref[...],
    )
    new_state, out = simulate_step(cfg, state, s, market_ids, jnp, scan=scan)
    out_bid_ref[...] = new_state.bid
    out_ask_ref[...] = new_state.ask
    out_last_ref[...] = new_state.last_price
    out_pmid_ref[...] = new_state.prev_mid
    price_ref[...] = out.price
    volume_ref[...] = out.volume


@functools.partial(jax.jit, static_argnames=("cfg", "mb", "scan", "interpret"))
def naive_clearing(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    *, cfg: MarketConfig, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """S launches of a single-step kernel; state resides in HBM between steps."""
    M, L = bid.shape
    S = cfg.num_steps
    if M % mb:
        raise ValueError(f"M={M} not divisible by tile mb={mb}")
    grid = (M // mb,)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    step_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, L), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
    )
    step_call = pl.pallas_call(
        functools.partial(_step_kernel_body, cfg=cfg, mb=mb, scan=scan),
        grid=grid,
        in_specs=[step_spec, book_spec, book_spec, scalar_spec, scalar_spec],
        out_specs=(book_spec, book_spec, scalar_spec, scalar_spec,
                   scalar_spec, scalar_spec),
        out_shape=out_shapes,
        interpret=interpret,
    )

    def host_step(carry, s):
        bid, ask, last, pmid = carry
        step_arr = jnp.full((1, 1), s, dtype=jnp.int32)
        bid, ask, last, pmid, price, volume = step_call(
            step_arr, bid, ask, last, pmid
        )
        return (bid, ask, last, pmid), (price[:, 0], volume[:, 0])

    steps = jnp.arange(S, dtype=jnp.int32)
    (bid, ask, last, pmid), (pp, vp) = jax.lax.scan(
        host_step, (bid, ask, last, pmid), steps
    )
    return bid, ask, last, pmid, pp.T, vp.T


def _chunk_step_kernel_body(
    step_ref, mids_ref,
    bid_ref, ask_ref, last_ref, pmid_ref, ext_buy_ref, ext_ask_ref,
    peer_ref,
    *refs,
    cfg, mb: int, scan: str, agent_chunk: Optional[int],
):
    """Per-step kernel with external-order inputs (Session API variant).

    ``mids_ref`` carries the per-row global market ids (see the kinetic
    chunk kernel) so padded/sharded callers keep exact RNG coordinates;
    ``peer_ref`` is the chunk-frozen coupling column (gathered once per
    chunk by the entry, NOT per launch — same freeze boundary as the
    persistent kernel); the next ``NUM_PARAM_OPERANDS`` refs are this
    tile's per-market :class:`MarketParams` columns.
    """
    s = step_ref[0, 0]
    market_ids = mids_ref[...]
    params = MarketParams(*(r[...] for r in refs[:NUM_PARAM_OPERANDS]))
    (out_bid_ref, out_ask_ref, out_last_ref, out_pmid_ref,
     price_ref, volume_ref, mid_ref) = refs[NUM_PARAM_OPERANDS:]
    state = MarketState(
        bid=bid_ref[...], ask=ask_ref[...],
        last_price=last_ref[...], prev_mid=pmid_ref[...],
    )
    new_state, out = simulate_step(
        cfg, state, s, market_ids, jnp, scan=scan,
        ext_buy=ext_buy_ref[...], ext_ask=ext_ask_ref[...],
        agent_chunk=agent_chunk, params=params, peer_mid=peer_ref[...],
    )
    out_bid_ref[...] = new_state.bid
    out_ask_ref[...] = new_state.ask
    out_last_ref[...] = new_state.last_price
    out_pmid_ref[...] = new_state.prev_mid
    price_ref[...] = out.price
    volume_ref[...] = out.volume
    mid_ref[...] = out.mid


def naive_clearing_chunk(
    bid: jax.Array, ask: jax.Array, last: jax.Array, pmid: jax.Array,
    step0: jax.Array, n_valid: jax.Array,
    ext_buy: jax.Array, ext_ask: jax.Array,
    *, cfg, chunk: int, mb: int = 8, scan: str = "cumsum",
    interpret: bool = False, market_ids: Optional[jax.Array] = None,
    agent_chunk: Optional[int] = None,
    params: Optional[MarketParams] = None,
    peer_mid: Optional[jax.Array] = None,
    stats: Optional[stats_mod.MarketStats] = None, stats_only: bool = False,
) -> Tuple[jax.Array, ...]:
    """Session entry for the launch-per-step regime: ``chunk`` kernel
    launches per call, state round-tripping HBM between launches.

    Mirrors :func:`kinetic_clearing_chunk`'s contract — ``step0``/``n_valid``
    int32[1, 1] runtime scalars, per-market ``params`` operands (one trace
    serves any scenario mixture), external orders injected at the first
    local step, gated state so a partial tail advances exactly ``n_valid``
    steps, padded sublane tiles with explicit global ``market_ids``, and a
    ``stats_only`` mode (accumulated in the scan carry between launches) —
    but keeps the Θ(chunk) dispatches and Θ(chunk·M·L) HBM traffic that this
    ablation exists to exhibit. Not jitted here; the session runner owns jit.
    """
    M, L = bid.shape
    m_padded = pad_to_multiple(M, mb)
    grid = (m_padded // mb,)

    if market_ids is None:
        market_ids = jnp.arange(M, dtype=jnp.int32)
    mids = jnp.reshape(jnp.asarray(market_ids, dtype=jnp.int32), (M, 1))
    if m_padded != M:
        pad_ids = jnp.arange(M, m_padded, dtype=jnp.int32)[:, None]
        mids = jnp.concatenate([mids, pad_ids], axis=0)
    params = resolve_params(cfg, M, params, jnp)
    if peer_mid is None:
        # Chunk-entry coupling freeze over local rows (single-device case);
        # sharded callers pass the halo-exchanged column explicitly.
        peer_mid = resolve_peer_mids(pmid, params.coupling_peer, jnp)
    bid, ask, last, pmid, ext_buy, ext_ask, peer_mid = (
        _pad_rows(x, m_padded) for x in (bid, ask, last, pmid, ext_buy,
                                         ext_ask, peer_mid))
    params = pad_params(params, m_padded)

    book_spec = pl.BlockSpec((mb, L), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((mb, 1), lambda i: (i, 0))
    step_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((m_padded, L), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, L), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
        jax.ShapeDtypeStruct((m_padded, 1), jnp.float32),
    )
    step_call = pl.pallas_call(
        functools.partial(_chunk_step_kernel_body, cfg=cfg, mb=mb, scan=scan,
                          agent_chunk=agent_chunk),
        grid=grid,
        in_specs=[step_spec, scalar_spec, book_spec, book_spec, scalar_spec,
                  scalar_spec, book_spec, book_spec, scalar_spec]
        + [scalar_spec] * NUM_PARAM_OPERANDS,
        out_specs=(book_spec, book_spec, scalar_spec, scalar_spec,
                   scalar_spec, scalar_spec, scalar_spec),
        out_shape=out_shapes,
        interpret=interpret,
    )

    step0_s = step0[0, 0]
    n_valid_s = n_valid[0, 0]
    zeros_ext = jnp.zeros_like(ext_buy)

    if stats_only and stats is None:
        raise ValueError("stats_only=True requires the carried `stats` "
                         "accumulators (see repro.core.stats.init_stats)")
    st0 = None
    if stats_only:
        st0 = stats_mod.MarketStats(
            *(_pad_rows(jnp.asarray(x, dtype=jnp.float32), m_padded)
              for x in stats))

    def host_step(carry, s):
        if stats_only:
            bid, ask, last, pmid, st = carry
        else:
            bid, ask, last, pmid = carry
        eb = jnp.where(s == jnp.int32(0), ext_buy, zeros_ext)
        ea = jnp.where(s == jnp.int32(0), ext_ask, zeros_ext)
        step_arr = jnp.full((1, 1), step0_s + s, dtype=jnp.int32)
        nbid, nask, nlast, npmid, price, volume, mid = step_call(
            step_arr, mids, bid, ask, last, pmid, eb, ea, peer_mid, *params
        )
        active = s < n_valid_s
        bid = jnp.where(active, nbid, bid)
        ask = jnp.where(active, nask, ask)
        last = jnp.where(active, nlast, last)
        pmid = jnp.where(active, npmid, pmid)
        if stats_only:
            st = stats_mod.accumulate(st, mid, volume, active, jnp)
            return (bid, ask, last, pmid, st), None
        return (bid, ask, last, pmid), (price[:, 0], volume[:, 0], mid[:, 0])

    steps = jnp.arange(chunk, dtype=jnp.int32)
    if stats_only:
        (bid, ask, last, pmid, st), _ = jax.lax.scan(
            host_step, (bid, ask, last, pmid, st0), steps
        )
        return (bid[:M], ask[:M], last[:M], pmid[:M],
                stats_mod.MarketStats(*(x[:M] for x in st)))
    (bid, ask, last, pmid), (pp, vp, mp) = jax.lax.scan(
        host_step, (bid, ask, last, pmid), steps
    )
    return (bid[:M], ask[:M], last[:M], pmid[:M],
            pp.T[:M], vp.T[:M], mp.T[:M])
