"""Fault-tolerant training driver: checkpoint/restart, straggler watch.

The driver owns the outer loop: data shard selection (stateless, from the
step counter), periodic async checkpoints, recovery-by-restart on failure,
and step-time telemetry. It is mesh-agnostic: pass any jitted train_step.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import make_batch
from repro.models.model import Model
from repro.models.model_config import ModelConfig
from repro.runtime.fault import (FaultInjector, SimulatedNodeFailure,
                                 StragglerWatch)

log = logging.getLogger("repro.driver")


@dataclasses.dataclass
class DriverConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 3
    seed: int = 0
    log_every: int = 10


class TrainDriver:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 train_step: Callable, opt_init: Callable,
                 driver_cfg: DriverConfig,
                 fault_injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.shape = shape
        self.train_step = train_step
        self.opt_init = opt_init
        self.dcfg = driver_cfg
        self.ckpt = CheckpointManager(driver_cfg.checkpoint_dir)
        self.straggler = StragglerWatch()
        self.fault = fault_injector or FaultInjector()
        self.metrics_log: list = []

    # ------------------------------------------------------------------
    def _init_state(self):
        model = Model(self.cfg)
        params = model.init(jax.random.PRNGKey(self.dcfg.seed))
        opt_state = self.opt_init(params)
        return params, opt_state, 0

    def _restore_or_init(self):
        restored = self.ckpt.restore()
        if restored is None:
            log.info("no checkpoint found; initializing from scratch")
            return self._init_state()
        step = int(np.asarray(restored["step"]))
        log.info("restored checkpoint at step %d", step)
        return restored["params"], restored["opt_state"], step

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        restarts = 0
        while True:
            try:
                return self._run_once()
            except SimulatedNodeFailure as e:
                restarts += 1
                log.warning("node failure (%s); restart %d/%d",
                            e, restarts, self.dcfg.max_restarts)
                if restarts > self.dcfg.max_restarts:
                    raise
                # recovery = reload from last durable checkpoint

    def _run_once(self) -> Dict[str, Any]:
        params, opt_state, step = self._restore_or_init()
        step_arr = np.int32(step)
        last_loss = None
        while step < self.dcfg.total_steps:
            batch = make_batch(self.cfg, self.shape, step,
                               seed=self.dcfg.seed)
            t0 = time.monotonic()
            params, opt_state, step_arr, metrics = self.train_step(
                params, opt_state, step_arr, batch)
            last_loss = float(np.asarray(metrics["loss"]))
            dt = time.monotonic() - t0
            if self.straggler.observe(step, dt):
                log.warning("straggler step %d: %.3fs", step, dt)
            step += 1
            self.metrics_log.append({"step": step, "loss": last_loss,
                                     "dt": dt})
            if step % self.dcfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, last_loss, dt)
            if step % self.dcfg.checkpoint_every == 0:
                self.ckpt.save(step, {
                    "step": np.int64(step),
                    "params": jax.device_get(params),
                    "opt_state": jax.device_get(opt_state),
                })
            self.fault.maybe_fail(step)
        self.ckpt.wait()
        return {"params": params, "opt_state": opt_state, "step": step,
                "loss": last_loss, "metrics": self.metrics_log,
                "straggler_flags": list(self.straggler.flagged)}
