from repro.runtime.driver import TrainDriver, DriverConfig  # noqa: F401
from repro.runtime.fault import (  # noqa: F401
    FaultInjector, HeartbeatMonitor, StragglerWatch,
)
