"""Fault-tolerance primitives: heartbeats, straggler detection, injection.

On a real cluster the heartbeat transport is the coordination service
(jax.distributed / etcd); here the same logic runs over an in-process clock
so the recovery paths are exercised by tests on one CPU host.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional


class HeartbeatMonitor:
    """Tracks per-worker liveness; a worker is dead after ``timeout_s``."""

    def __init__(self, workers: List[int], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen: Dict[int, float] = {w: clock() for w in workers}

    def beat(self, worker: int) -> None:
        self.last_seen[worker] = self.clock()

    def dead_workers(self) -> List[int]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


class StragglerWatch:
    """EMA step-time tracker; flags steps > ``k`` sigma above the mean.

    The mitigation hook is pluggable: at scale it triggers data-shard
    rebalancing or hot-spare swap-in; the default logs and counts.
    """

    def __init__(self, window: int = 50, k_sigma: float = 3.0,
                 min_samples: int = 10):
        self.times: deque = deque(maxlen=window)
        self.k = k_sigma
        self.min_samples = min_samples
        self.flagged: List[tuple] = []

    def observe(self, step: int, dt: float) -> bool:
        import numpy as np

        is_straggler = False
        if len(self.times) >= self.min_samples:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if dt > mu + self.k * sd:
                is_straggler = True
                self.flagged.append((step, dt, mu, sd))
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure injection for integration tests.

    ``fail_at_steps`` raises ``SimulatedNodeFailure`` just *after* the
    optimizer update of those steps, emulating a node loss between steps.
    """

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedNodeFailure(f"injected failure at step {step}")


class SimulatedNodeFailure(RuntimeError):
    pass
