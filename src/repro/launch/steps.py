"""Train / prefill / serve step builders (the functions the dry-run lowers).

These are the production entry points: mixed-precision forward, chunked CE,
optional gradient accumulation, optimizer update, and (for serving) KV-cache
decode. Sharding comes from in_shardings/out_shardings + the logical
constraints inside the model (launch/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.model_config import ModelConfig
from repro.optim import make_optimizer
from repro.optim.schedule import cosine_schedule


def pick_optimizer_name(cfg: ModelConfig) -> str:
    total, _ = cfg.param_counts()
    return "adafactor" if total > 60e9 else "adamw"


def make_train_step(cfg: ModelConfig, optimizer_name: Optional[str] = None,
                    micro_steps: int = 1, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000):
    """Returns (train_step, opt.init). train_step(params, opt_state, step,
    batch) -> (params, opt_state, step+1, metrics)."""
    model = Model(cfg)
    opt = make_optimizer(optimizer_name or pick_optimizer_name(cfg))

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, step, batch):
        if micro_steps > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((micro_steps, x.shape[0] // micro_steps)
                                    + x.shape[1:]), batch)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / micro_steps, grads)
            loss = loss / micro_steps
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        lr = cosine_schedule(step, warmup, total_steps, peak_lr)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        out = {"loss": loss, "lr": lr, "step": step}
        out.update(metrics)
        return params, opt_state, step + 1, out

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    model = Model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig, greedy: bool = True):
    """One decode iteration: logits for the current token -> next token."""
    model = Model(cfg)

    def serve_step(params, cache, batch):
        tokens, pos = batch["tokens"], batch["pos"]
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return cache, next_tok[:, None], pos + 1

    return serve_step
