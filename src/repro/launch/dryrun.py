"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a module entry point (``python -m repro.launch.dryrun``):
the XLA device-count override below has to run before jax initializes.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHITECTURES, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, long_context_skip_reason  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    make_prefill_step, make_serve_step, make_train_step, pick_optimizer_name,
)
from repro.models.model import Model  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_]+\[[^\]]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[\d+,\d+\])")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str):
    """Per-device wire-byte estimate per collective family.

    Shapes in SPMD-partitioned HLO are per-device. Ring-model costs:
    all-reduce 2(n-1)/n * bytes; all-gather (n-1)/n * result bytes;
    reduce-scatter (n-1) * result bytes; all-to-all (n-1)/n; permute 1x.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        n = 0
        if gm:
            g = gm.group(1)
            if g.startswith("[") :
                n = int(g.strip("[]").split(",")[1])
            else:
                n = g.count(",") - g.count("},{") * 0 + 1
                first = g[2:g.index("}")]
                n = len(first.split(","))
        n = max(n, 2)
        if op == "all-reduce":
            out[op] += 2 * (n - 1) / n * size
        elif op == "all-gather":
            out[op] += (n - 1) / n * size
        elif op == "reduce-scatter":
            out[op] += (n - 1) * size
        elif op == "all-to-all":
            out[op] += (n - 1) / n * size
        else:
            out[op] += size
        counts[op] += 1
    return out, counts


def _mesh_tag(multi_pod: bool) -> str:
    return "multipod_2x16x16" if multi_pod else "pod_16x16"


def build_cell(arch: str, shape_name: str, mesh, fsdp_override=None):
    """Returns (jitted_fn, abstract_args) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    specs = specs_mod.input_specs(cfg, shape)
    batch_sh = specs_mod.batch_shardings(mesh, cfg, specs)
    total_params, _ = cfg.param_counts()
    fsdp = (total_params > 20e9
            and (shape.phase == "train" or cfg.family == "moe")
            if fsdp_override is None else fsdp_override)
    layout = cfg.parallelism

    aparams = model.abstract_params()
    if shape.phase != "train":
        # Serving reads a compute-dtype checkpoint (EXPERIMENTS §Perf
        # deepseek decode: f32 master weights double inference weight
        # traffic for no benefit).
        from repro.models.model import cast_params
        aparams = jax.eval_shape(lambda p: cast_params(p, cfg), aparams)
    param_sh = shd.param_shardings(mesh, aparams, fsdp=fsdp, layout=layout)
    repl = NamedSharding(mesh, P())

    if shape.phase == "train":
        train_step, opt = make_train_step(cfg)
        aopt = jax.eval_shape(opt.init, aparams)
        opt_sh = shd.param_shardings(mesh, aopt, fsdp=fsdp, layout=layout)
        astep = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(params, opt_state, step, batch):
            with shd.activate(mesh, layout):
                return train_step(params, opt_state, step, batch)

        jf = jax.jit(
            fn,
            in_shardings=(param_sh, opt_sh, repl, batch_sh),
            out_shardings=(param_sh, opt_sh, repl, None),
            donate_argnums=(0, 1),
        )
        args = (aparams, aopt, astep, specs)
    elif shape.phase == "prefill":
        prefill_step = make_prefill_step(cfg)

        def fn(params, batch):
            with shd.activate(mesh, layout):
                return prefill_step(params, batch)

        jf = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                     out_shardings=None)
        args = (aparams, specs)
    else:  # decode
        serve_step = make_serve_step(cfg)
        acache = specs_mod.abstract_cache(cfg, shape.global_batch,
                                          shape.seq_len)
        cache_sh = specs_mod.cache_shardings(mesh, cfg, acache)

        def fn(params, cache, batch):
            with shd.activate(mesh, layout):
                return serve_step(params, cache, batch)

        jf = jax.jit(
            fn,
            in_shardings=(param_sh, cache_sh, batch_sh),
            out_shardings=(cache_sh, repl, repl),
            donate_argnums=(1,),
        )
        args = (aparams, acache, specs)
    return cfg, shape, jf, args


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = (long_context_skip_reason(cfg) if shape_name == "long_500k"
            else None)
    record = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "phase": shape.phase,
    }
    if skip:
        record["status"] = "SKIP"
        record["reason"] = skip
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    cfg, shape, jf, args = build_cell(arch, shape_name, mesh)
    lowered = jf.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware static analysis (XLA's cost_analysis counts while bodies
    # once; see launch/hlo_analysis.py)
    h = hlo_analysis.summarize(hlo)
    coll = h["collective_breakdown"]
    coll_counts = h["collective_counts"]
    wire = h["collective_wire_bytes"]

    flops_dev = float(h["flops"])
    bytes_dev = float(h["hbm_bytes"])
    t_comp = flops_dev / HW["peak_flops_bf16"]
    t_mem = bytes_dev / HW["hbm_bw"]
    t_coll = wire / (HW["ici_links_per_axis"] * HW["ici_link_bw"])

    total_p, active_p = cfg.param_counts()
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * active_p * tokens
    elif shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * active_p * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * active_p * tokens

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    record.update({
        "status": "OK",
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "flops": flops_dev,
            "bytes_accessed": bytes_dev,
            "xla_flops_loopbody_once": float(ca.get("flops", 0.0)),
            "xla_bytes_loopbody_once": float(ca.get("bytes accessed", 0.0)),
            "collective_wire_bytes": wire,
            "collective_breakdown": coll,
            "collective_counts": coll_counts,
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "roofline": {
            "compute_s": t_comp,
            "memory_s": t_mem,
            "collective_s": t_coll,
            "dominant": dominant,
            "step_time_bound_s": bound,
        },
        "model": {
            "total_params": total_p,
            "active_params": active_p,
            "tokens_per_step": tokens,
            "model_flops": model_flops,
            "useful_fraction": (model_flops / (flops_dev * n_dev)
                                if flops_dev else 0.0),
            "optimizer": (pick_optimizer_name(cfg)
                          if shape.phase == "train" else None),
        },
        "hbm_fits_16g": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                         - ma.alias_size_in_bytes) < HW["hbm_per_chip"],
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {record['mesh']}] "
              f"compile={t_compile:.1f}s flops/dev={flops_dev:.3e} "
              f"bytes/dev={bytes_dev:.3e} wire/dev={wire:.3e} "
              f"dominant={dominant} bound={bound*1e3:.2f}ms "
              f"useful={record['model']['useful_fraction']:.3f}")
        print("  memory_analysis:", ma)
    return record


def cell_path(arch, shape_name, multi_pod):
    return RESULTS_DIR / f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell (subprocess per cell, cached)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        archs = [a for a in ARCHITECTURES if a != "kineticsim"]
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = 0
        for arch in archs:
            for shape_name in SHAPES:
                for mp in meshes:
                    out = cell_path(arch, shape_name, mp)
                    if out.exists() and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures += 1
                        out.write_text(json.dumps({
                            "arch": arch, "shape": shape_name,
                            "mesh": _mesh_tag(mp), "status": "ERROR",
                            "returncode": r.returncode}))
        print(f"dry-run sweep done, {failures} failures")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    try:
        record = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception:
        traceback.print_exc()
        record = {"arch": args.arch, "shape": args.shape,
                  "mesh": _mesh_tag(args.multi_pod), "status": "ERROR",
                  "error": traceback.format_exc()[-2000:]}
        cell_path(args.arch, args.shape, args.multi_pod).write_text(
            json.dumps(record, indent=2))
        sys.exit(1)
    cell_path(args.arch, args.shape, args.multi_pod).write_text(
        json.dumps(record, indent=2))
    print("OK")


if __name__ == "__main__":
    main()
