"""Production mesh construction (multi-pod dry-run §0-1).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# TPU v5e hardware model used by the roofline analysis (EXPERIMENTS.md §Roofline)
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_link_bw": 50e9,         # bytes/s per link per direction
    "ici_links_per_axis": 2,     # 2D torus: 2 links per mesh axis
    "hbm_per_chip": 16e9,
}
