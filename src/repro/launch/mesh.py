"""Device mesh construction for ensemble sharding.

Meshes are built by functions (never module constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(num_axes: int) -> dict:
    """``axis_types=`` kwargs for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` only exists from jax 0.5; on 0.4.x meshes are
    implicitly Auto, so passing nothing is semantically equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_mesh_compat(shape, axes, **kwargs):
    """``jax.make_mesh`` with explicit-Auto axis types on jax >= 0.5."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)), **kwargs)


def make_markets_mesh(devices=None, skip=()):
    """1-D mesh over the market (ensemble) axis for sharded simulation runs.

    ``devices`` selects how many local devices to span (default: all). The
    simulator's market axis is embarrassingly parallel — independent markets,
    no collectives — so a plain 1-D ``("markets",)`` mesh is the whole
    topology. Works identically on real TPU slices and on CPU runners forced
    to N host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count``.

    ``skip`` excludes local device *indices* before selection — the elastic
    rebuild path after a device loss: ``make_markets_mesh(skip=(2,))``
    spans every surviving device, and a snapshot restored onto the new mesh
    resumes the stream bitwise (snapshots are layout-portable).
    """
    skip = frozenset(int(i) for i in skip)
    avail = [d for i, d in enumerate(jax.devices()) if i not in skip]
    if not avail:
        raise ValueError(f"skip={sorted(skip)} excludes every local device")
    if devices is None:
        devices = len(avail)
    n = int(devices)
    if not (1 <= n <= len(avail)):
        raise ValueError(
            f"requested {n} devices; have {len(avail)} "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "forces N host devices on CPU)")
    return make_mesh_compat((n,), ("markets",), devices=avail[:n])


# TPU v5e hardware model used by the roofline analysis (EXPERIMENTS.md §Roofline)
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_link_bw": 50e9,         # bytes/s per link per direction
    "ici_links_per_axis": 2,     # 2D torus: 2 links per mesh axis
    "hbm_per_chip": 16e9,
}
