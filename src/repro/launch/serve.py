"""Serving launcher: batched prefill + greedy decode loop.

``python -m repro.launch.serve --arch qwen2.5-3b --smoke --tokens 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_config
from repro.data.pipeline import make_batch
from repro.configs.shapes import ShapeSpec
from repro.launch.steps import make_serve_step
from repro.models.model import Model


def serve(cfg, *, batch_size=2, prompt_len=16, gen_tokens=16, max_len=None,
          seed=0, params=None):
    model = Model(cfg)
    params = params if params is not None else model.init(
        jax.random.PRNGKey(seed))
    max_len = max_len or (prompt_len + gen_tokens + 1)

    shape = ShapeSpec("serve", prompt_len, batch_size, "prefill")
    batch = make_batch(cfg, shape, 0, seed=seed)
    batch.pop("labels", None)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    # Prefill: fill an Smax-slot cache by stepping positions 0..prompt_len-1
    # through the decode path (exercises exactly the decode_32k lowering).
    cache = model.init_cache(batch_size, max_len)
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    pos = jnp.zeros((batch_size,), jnp.int32)
    tok = batch["tokens"][:, :1]
    generated = []
    t0 = time.monotonic()
    for i in range(prompt_len + gen_tokens - 1):
        cache, next_tok, pos = serve_step(params, cache,
                                          {"tokens": tok, "pos": pos})
        if i + 1 < prompt_len:
            tok = batch["tokens"][:, i + 1:i + 2]  # teacher-forced prompt
        else:
            tok = next_tok
            generated.append(np.asarray(next_tok)[:, 0])
    dt = time.monotonic() - t0
    gen = np.stack(generated, axis=1) if generated else np.zeros((batch_size, 0))
    return gen, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=[a for a in ARCHITECTURES if a != "kineticsim"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch, smoke=args.smoke)
    gen, dt = serve(cfg, batch_size=args.batch, prompt_len=args.prompt,
                    gen_tokens=args.tokens)
    tps = gen.size / dt if dt else 0
    print(f"arch={cfg.name} generated {gen.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s)\nfirst row: {gen[0][:16]}")


if __name__ == "__main__":
    main()
