"""Logical-axis sharding: mesh context + activation constraints + param rules.

Model code annotates activations with *logical* axes ("dp", "tp", "sp",
"dp_sp") via :func:`constrain`; outside a mesh context these are no-ops, so
the same model runs unsharded on one CPU device for smoke tests and fully
sharded under the production mesh for the dry-run.

Logical -> physical mapping:
  dp     -> ("pod", "data") when the pod axis exists, else ("data",)
  tp     -> ("model",)                        tensor/expert parallel
  sp     -> ("model",)                        sequence parallel (norm regions)
  dp_sp  -> dp + tp combined (MoE group dispatch spans every chip)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axes(mesh: Mesh, logical: Optional[str], layout: str = "tp"):
    names = mesh.axis_names
    dp = tuple(n for n in ("pod", "data") if n in names)
    model = ("model",) if "model" in names else ()
    # "ep" layout (MoE archs): batch spans every axis, attention/dense
    # params are replicated+FSDP, only expert weights use the model axis.
    table = {
        None: None,
        "dp": dp + model if layout == "ep" else dp,
        "dp_data": dp,            # data axes only, regardless of layout
        "vocab": (model or None) if layout == "tp" else None,
        "tp": model or None,
        "sp": model or None,
        "dp_sp": dp + model,
    }
    if logical not in table:
        raise KeyError(f"unknown logical axis {logical!r}")
    ax = table[logical]
    if ax == ():
        return None
    return ax


# ---------------------------------------------------------------------------
# Market-axis sharding (simulation ensembles; see repro.launch.mesh
# .make_markets_mesh). Per-market arrays are [M, ...] row-major, so one
# NamedSharding over the leading axis covers books, scalars and statistics.
# ---------------------------------------------------------------------------
def market_sharding(mesh: Mesh) -> NamedSharding:
    """Row-sharding for [M, ...] per-market arrays on a ``markets`` mesh."""
    if "markets" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh} has no 'markets' axis")
    return NamedSharding(mesh, P("markets"))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement (runtime scalars like step0/n_valid)."""
    return NamedSharding(mesh, P())


@contextlib.contextmanager
def activate(mesh: Mesh, layout: str = "tp"):
    """Enable activation constraints for model code traced inside."""
    prev = getattr(_state, "mesh", None), getattr(_state, "layout", "tp")
    _state.mesh = mesh
    _state.layout = layout
    try:
        yield
    finally:
        _state.mesh, _state.layout = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    layout = getattr(_state, "layout", "tp")
    axes = [_axes(mesh, a, layout) for a in logical_axes]
    # drop axes whose product doesn't divide the dim
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    clean = []
    for dim, ax in enumerate(axes):
        if ax is None:
            clean.append(None)
            continue
        n = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n *= sizes[a]
        clean.append(ax if x.shape[dim] % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))


def constrain_spec(x, spec_axes):
    """Like :func:`constrain` but with an explicit per-dim tuple."""
    return constrain(x, *spec_axes)


def spec(mesh: Mesh, *logical_axes, layout: str = "tp") -> NamedSharding:
    return NamedSharding(mesh, P(*(_axes(mesh, a, layout)
                                   for a in logical_axes)))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------
def _rule_for(path: str, arr_ndim: int, fsdp: bool, layout: str = "tp"):
    """Map a parameter tree path to logical axes per dimension.

    Conventions (see DESIGN.md §6): the contraction between "tp"-column and
    "tp"-row weights is Megatron-style; expert dim is EP; embeddings are
    vocab-parallel; norms/biases replicated (FSDP shards them on dim 0 when
    large enough — biases stay replicated for simplicity).
    """
    leaf = path.split("/")[-1]
    fs = "dp" if fsdp else None
    if layout == "ep" and not leaf.startswith("we_"):
        # replicate + (optional) FSDP for everything except expert weights
        if leaf in ("table", "wq", "wk", "wv", "wo", "w_gate", "w_up",
                    "w_out", "router", "in_proj", "out_proj", "x_proj",
                    "dt_proj", "bc_proj", "dt_in"):
            return (fs,) + (None,) * (arr_ndim - 1)

    if leaf == "table":                       # embedding [V, D]
        return ("tp", fs)
    if leaf in ("wq", "wk", "wv"):            # [D, H*hd]
        return (fs, "tp")
    if leaf == "wo":                          # [H*hd, D]
        return ("tp", fs)
    if leaf in ("w_gate", "w_up"):            # MLP [D, F]
        return (fs, "tp")
    if leaf == "w_out":                       # MLP [F, D]
        return ("tp", fs)
    if leaf in ("we_gate", "we_up"):          # MoE experts [E, D, F]
        return ("tp", fs, None)
    if leaf == "we_out":                      # MoE [E, F, D]
        return ("tp", fs, None)
    if leaf == "router":                      # [D, E]
        return (fs, None)
    # --- SSM (mamba) ---
    if leaf == "in_proj":                     # [D, 2*d_inner(+...)]
        return (fs, "tp")
    if leaf == "out_proj":                    # [d_inner, D]
        return ("tp", fs)
    if leaf in ("conv_w",):                   # [K, d_inner]
        return (None, "tp")
    if leaf in ("A_log", "D_skip", "dt_bias", "conv_b"):
        return ("tp",) + (None,) * (arr_ndim - 1)
    if leaf == "x_proj":                      # [d_inner, R+2N]
        return ("tp", None)
    if leaf == "dt_proj":                     # [R, d_inner]
        return (None, "tp")
    if leaf in ("bc_proj", "dt_in"):          # mamba2 [D, *]
        return (fs, None)
    # norms, biases, small vectors: replicated
    return (None,) * arr_ndim


def _tree_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k_, v in sorted(tree.items()):
            out.extend(_tree_paths(v, f"{prefix}/{k_}" if prefix else str(k_)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_tree_paths(v, f"{prefix}/{i}"))
    else:
        out.append((prefix, tree))
    return out


def param_shardings(mesh: Mesh, abstract_params, fsdp: bool = False,
                    layout: str = "tp"):
    """NamedSharding pytree for a parameter pytree of ShapeDtypeStructs.

    Layer-stacked parameters (leading scan dim) are detected by ndim vs the
    rule arity and the stacked dim is left unsharded.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _finalize(full, leaf):
        # drop shardings on dims smaller than the axis size
        clean = []
        for dim, ax in enumerate(full):
            phys = _axes(mesh, ax)
            if phys is None:
                clean.append(None)
                continue
            n = 1
            for a in (phys if isinstance(phys, tuple) else (phys,)):
                n *= sizes[a]
            # jit in_shardings require exact divisibility; drop the axis
            # otherwise (the param stays replicated — visible in roofline).
            if leaf.shape[dim] % n != 0:
                clean.append(None)
            else:
                clean.append(phys)
        return NamedSharding(mesh, P(*clean))

    def one(path, leaf):
        ndim = leaf.ndim
        # Adafactor factored stats live one level below the param name:
        # ".../wq/vr". Derive their rule from the parent's.
        parts = path.split("/")
        if parts[-1] in ("vr", "vc", "v") and len(parts) >= 2:
            parent = "/".join(parts[:-1])
            if parts[-1] == "v":
                return one(parent, leaf)
            for stacked in (0, 1):
                prule = _rule_for(parent, ndim + 1 - stacked, fsdp, layout)
                if len(prule) == ndim + 1 - stacked:
                    rule = (prule[:-1] if parts[-1] == "vr"
                            else prule[:-2] + prule[-1:])
                    return _finalize((None,) * stacked + rule, leaf)
            return NamedSharding(mesh, P())
        # try rule at both ndim and ndim-1 (scan-stacked)
        for stacked in (0, 1):
            rule = _rule_for(path, ndim - stacked, fsdp, layout)
            if len(rule) == ndim - stacked:
                return _finalize((None,) * stacked + rule, leaf)
        return NamedSharding(mesh, P())

    paths = _tree_paths(abstract_params)
    flat, treedef = jax.tree_util.tree_flatten(abstract_params)
    assert len(paths) == len(flat)
    shardings = [one(p, l) for (p, _), l in zip(paths, flat)]
    return jax.tree_util.tree_unflatten(treedef, shardings)
