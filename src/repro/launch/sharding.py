"""Market-axis sharding rules for simulation ensembles.

The simulator's market axis is embarrassingly parallel — independent
markets, no collectives — and every per-market array (books ``[M, L]``,
scalars/statistics ``[M, 1]``, parameter columns ``[M, 1]``) is row-major
over it, so one :class:`NamedSharding` over the leading axis covers the
whole session state. See :func:`repro.launch.mesh.make_markets_mesh` for
the 1-D ``("markets",)`` topology and ``repro.kernels.ops`` for the
``shard_map`` plumbing over the persistent chunk kernels.
"""
from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def market_sharding(mesh: Mesh) -> NamedSharding:
    """Row-sharding for [M, ...] per-market arrays on a ``markets`` mesh."""
    if "markets" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh} has no 'markets' axis")
    return NamedSharding(mesh, P("markets"))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement (runtime scalars like step0/n_valid)."""
    return NamedSharding(mesh, P())


def replicate_tree(tree, mesh: Mesh):
    """Place every leaf of a pytree fully replicated on ``mesh``.

    Policy/optimizer parameter trees in ``repro.train`` ride through the
    sharded rollout path replicated — only the market axis shards — so
    the trainer pins them here once at init instead of re-placing them
    every update.
    """
    import jax

    sharding = replicated_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), tree)
