"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container run with ``--smoke`` (reduced config, real training);
on a TPU cluster the same entry point drives the production mesh (the mesh
axes come from ``make_production_mesh`` and shardings from
``launch/sharding.py`` — exactly what the dry-run validates).
"""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np

from repro.configs import ARCHITECTURES, get_config
from repro.configs.shapes import SMOKE_SHAPES, SHAPES, ShapeSpec
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.runtime.driver import DriverConfig, TrainDriver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    choices=[a for a in ARCHITECTURES if a != "kineticsim"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    train_step, opt = make_train_step(cfg)

    def wrapped(params, opt_state, step, batch):
        with shd.activate(mesh):
            return train_step(params, opt_state, step, batch)

    jstep = jax.jit(wrapped, donate_argnums=(0, 1))
    driver = TrainDriver(
        cfg, shape, jstep, opt.init,
        DriverConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                     checkpoint_dir=args.ckpt_dir))
    out = driver.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"arch={cfg.name} steps={out['step']} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return out


if __name__ == "__main__":
    main()
