"""Abstract input specs (ShapeDtypeStruct) + shardings for every cell.

``input_specs(cfg, shape)`` builds the batch stand-ins (weak-type-correct,
shardable, no allocation); ``batch_shardings`` / ``cache_shardings`` map them
onto the mesh.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.model import Model
from repro.models.model_config import ModelConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _extras(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    ex: Dict[str, Any] = {}
    if cfg.family == "encdec":
        ex["frames"] = _sds((B, cfg.source_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        ex["vision_embeds"] = _sds((B, cfg.num_vision_tokens, cfg.d_model),
                                   jnp.float32)
        ex["mrope_positions"] = _sds((B, 3, S), jnp.int32)
    return ex


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.phase == "train":
        return {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32), **_extras(cfg, B, S)}
    if shape.phase == "prefill":
        return {"tokens": _sds((B, S), jnp.int32), **_extras(cfg, B, S)}
    if shape.phase == "decode":
        return {"tokens": _sds((B, 1), jnp.int32),
                "pos": _sds((B,), jnp.int32)}
    raise ValueError(shape.phase)


def abstract_cache(cfg: ModelConfig, B: int, max_len: int):
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init_cache(B, max_len))


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------
def _dp(mesh):
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def _batch_axes(mesh, batch: int):
    dp = _dp(mesh)
    return dp if batch % _axis_size(mesh, dp) == 0 else None


def batch_shardings(mesh, cfg: ModelConfig, specs: Dict[str, Any]):
    out = {}
    full = tuple(n for n in ("pod", "data", "model") if n in mesh.axis_names)
    for name, s in specs.items():
        if (cfg.parallelism == "ep"
                and s.shape[0] % _axis_size(mesh, full) == 0):
            b_ax = full
        else:
            b_ax = _batch_axes(mesh, s.shape[0])
        spec = (b_ax,) + (None,) * (len(s.shape) - 1)
        out[name] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(mesh, cfg: ModelConfig, cache_abstract):
    """Decode-cache shardings: batch over dp; kv-heads over model when
    divisible, else sequence over model (flash-decode style); SSM inner dim
    over model."""
    tp = _axis_size(mesh, ("model",)) if "model" in mesh.axis_names else 1

    def leaf_spec(path, leaf):
        shape = leaf.shape
        kind = path[-1]
        if kind in ("kv", "attn", "cross_kv"):
            # (..., B, Smax, KV, hd)
            nb = len(shape) - 4
            B, Smax, KV = shape[-4], shape[-3], shape[-2]
            b_ax = _batch_axes(mesh, B)
            if KV % tp == 0:
                spec = (None,) * nb + (b_ax, None, ("model",), None)
            elif Smax % tp == 0:
                spec = (None,) * nb + (b_ax, ("model",), None, None)
            else:
                spec = (None,) * nb + (b_ax, None, None, None)
        elif kind == "conv":
            # (..., B, K-1, d_inner)
            nb = len(shape) - 3
            b_ax = _batch_axes(mesh, shape[-3])
            d_in = shape[-1]
            spec = (None,) * nb + (b_ax, None,
                                   ("model",) if d_in % tp == 0 else None)
        elif kind == "h":
            # mamba1 (..., B, d_inner, N); mamba2 (..., B, nh, hd, N)
            if cfg.mamba_version == 1:
                nb = len(shape) - 3
                b_ax = _batch_axes(mesh, shape[-3])
                d_in = shape[-2]
                spec = (None,) * nb + (b_ax,
                                       ("model",) if d_in % tp == 0 else None,
                                       None)
            else:
                nb = len(shape) - 4
                b_ax = _batch_axes(mesh, shape[-4])
                nh = shape[-3]
                spec = (None,) * nb + (b_ax,
                                       ("model",) if nh % tp == 0 else None,
                                       None, None)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, P(*spec))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            t = type(tree)
            return t(walk(v, path) for v in tree)
        return leaf_spec(path, tree)

    return walk(cache_abstract, ())
