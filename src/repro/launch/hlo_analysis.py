"""Static roofline analyzer over compiled (SPMD-partitioned) HLO text.

XLA's built-in ``cost_analysis()`` counts ``while`` bodies exactly once,
which silently under-reports every scanned construct (layer stacks, flash
KV loops, CE chunk loops) by its trip count. This analyzer re-derives the
three roofline inputs from the HLO itself with proper loop accounting:

  * **flops**: exact 2·M·N·K for every ``dot`` (contracting/batch dims parsed
    from the op), 1 flop/element for other materializing ops; fusion bodies
    are traversed for dots only.
  * **hbm bytes**: every top-level op reads its operands and writes its
    result, with TPU-aware exceptions: fusion internals, reshapes,
    broadcasts, converts and iotas are free (they fuse); dynamic-slice /
    gather / slice count only the *sliced* bytes (not the full operand —
    critical for scan-over-layers, where the stacked parameter tensor is an
    operand of every per-layer slice); dynamic-update-slice / scatter count
    2x the update region. Still an upper bound on real traffic.
  * **collective wire bytes**: ring-model per-device bytes for all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute.

Shapes in partitioned HLO are per-device, so all outputs are per-device.
``while`` multipliers come from ``backend_config.known_trip_count`` (always
emitted for jax.lax.scan/fori_loop).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_ATOM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-z][a-z0-9\-]*)\(")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:body|calls|to_apply|true_computation|"
                     r"false_computation|branch_computations)=\{?%?([\w.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DOT_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops that don't materialize / move data.
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "opt-barrier",
             "custom-call", "reshape", "broadcast", "iota", "convert",
             "copy-start", "copy-done", "rng-bit-generator"}

# ops where only the sliced/updated region moves, not the whole operand
_SLICE_OPS = {"dynamic-slice", "gather", "slice", "pad"}
_UPDATE_OPS = {"dynamic-update-slice", "scatter"}


def _atoms(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_ATOM.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _atoms(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _atoms(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


class Op:
    __slots__ = ("name", "result", "opcode", "line")

    def __init__(self, name, result, opcode, line):
        self.name, self.result, self.opcode, self.line = (
            name, result, opcode, line)


def _split_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
                continue
            m = _OP_LINE.match(line)
            if m:
                comps[cur].append(Op(m.group(1), m.group(2), m.group(3), line))
    return comps


_ARGS_RE = re.compile(r"[a-z0-9\-]+\(([^)]*)\)")


def _operand_names(line: str) -> List[str]:
    """Names of the operands of an op line (bare %name references)."""
    m = _ARGS_RE.search(line)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def _dot_flops(op: Op, lookup) -> int:
    names = _operand_names(op.line)
    if not names:
        return 0
    lhs_shape = lookup(names[0])
    if lhs_shape is None:
        return 0
    lhs = _atoms(lhs_shape)
    if not lhs:
        return 0
    _, lhs_dims = lhs[0]
    m = _DOT_DIMS.search(op.line)
    contract = [int(i) for i in m.group(1).split(",") if i] if m else []
    k = 1
    for i in contract:
        if i < len(lhs_dims):
            k *= lhs_dims[i]
    return 2 * _shape_elems(op.result) * k


def _collective_wire(op: Op) -> float:
    size = _shape_bytes(op.result)
    n = 2
    g = _GROUPS_IOTA.search(op.line)
    if g:
        n = int(g.group(2))
    else:
        g = _GROUPS_LIST.search(op.line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
    n = max(n, 2)
    kind = op.opcode.replace("-start", "")
    if kind == "all-reduce":
        return 2 * (n - 1) / n * size
    if kind == "all-gather":
        return (n - 1) / n * size
    if kind == "reduce-scatter":
        return (n - 1) * size
    if kind == "all-to-all":
        return (n - 1) / n * size
    return float(size)  # collective-permute



def _shape_elems_only(shape_str: str) -> int:
    return _shape_elems(shape_str)


_TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "broadcast",
                "transpose"}


def _fusion_bytes(op: Op, comps, table, lookup) -> float:
    """HBM bytes for a fusion call site, slice-aware.

    * An operand whose transitive consumers (through convert/bitcast/copy/
      reshape) inside the fused computation are all dynamic-slice/gather ops
      is charged at the sliced size — this is how scan-over-layers reads one
      layer's weights from the stacked parameter tensor.
    * A fusion rooted (modulo converts) in dynamic-update-slice writes only
      the update region: charge ~2x the update (read-modify-write) and do
      not charge the aliased full buffer operand or result.
    """
    mf = re.search(r"calls=%?([\w.\-]+)", op.line)
    sub_ops = comps.get(mf.group(1), []) if mf else []
    by_name = {so.name: so for so in sub_ops}

    def resolve_producer(name):
        seen = set()
        while name in by_name and by_name[name].opcode in _TRANSPARENT:
            if name in seen:
                break
            seen.add(name)
            prods = _operand_names(by_name[name].line)
            if not prods:
                break
            name = prods[0]
        return name

    params = {}
    for so in sub_ops:
        if so.opcode == "parameter":
            mnum = re.search(r"parameter\((\d+)\)", so.line)
            if mnum:
                params[so.name] = int(mnum.group(1))

    # transitive consumers (through transparent ops) per op name
    direct_consumers = {}
    for so in sub_ops:
        for nm in _operand_names(so.line):
            direct_consumers.setdefault(nm, []).append(so)

    def sink_consumers(name, depth=0):
        out = []
        for c in direct_consumers.get(name, []):
            if c.opcode in _TRANSPARENT and depth < 6:
                out.extend(sink_consumers(c.name, depth + 1))
            else:
                out.append(c)
        return out

    # identify DUS/scatter aliasing (both update a region of a buffer that
    # the fusion result aliases)
    dus_ops = [so for so in sub_ops
               if so.opcode in ("dynamic-update-slice", "scatter")]
    aliased_params = set()
    dus_rooted = False
    update_bytes = 0.0
    result_elems = _shape_elems(op.result)
    for so in dus_ops:
        if _shape_elems(so.result) != result_elems:
            continue
        dus_rooted = True
        names = _operand_names(so.line)
        if names:
            buf = resolve_producer(names[0])
            if buf in params:
                aliased_params.add(buf)
        upd_idx = 2 if so.opcode == "scatter" else 1
        if len(names) > upd_idx:
            upd = resolve_producer(names[upd_idx])
            upd_shape = (by_name[upd].result if upd in by_name
                         else lookup(upd))
            if upd_shape:
                update_bytes += 2 * _shape_bytes(upd_shape)

    operand_names = _operand_names(op.line)
    result_bytes = _shape_bytes(op.result)
    total = 0.0
    for pname, pnum in params.items():
        if pname in aliased_params:
            continue
        sinks = [c for c in sink_consumers(pname)]
        if sinks and all(c.opcode in ("dynamic-slice", "gather", "slice")
                         for c in sinks):
            total += sum(_shape_bytes(c.result) for c in sinks)
        elif not sinks:
            # pure transparent chain to ROOT (convert/bitcast-only fusion):
            # the read is bounded by what the fusion emits
            if pnum < len(operand_names):
                shp = lookup(operand_names[pnum])
                if shp:
                    total += min(_shape_bytes(shp), result_bytes)
        else:
            if pnum < len(operand_names):
                shp = lookup(operand_names[pnum])
                if shp:
                    total += _shape_bytes(shp)
    if dus_rooted:
        total += update_bytes
    else:
        total += _shape_bytes(op.result)
    return total


def analyze(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)

    # symbol table: op name -> result shape string (global; names are unique
    # in optimized HLO output)
    table: Dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            table[op.name] = op.result

    def lookup(name: str) -> Optional[str]:
        return table.get(name)

    def operand_bytes(op: Op) -> int:
        total = 0
        for name in _operand_names(op.line):
            shp = lookup(name)
            if shp is not None:
                total += _shape_bytes(shp)
        return total

    # entry = computation named on the ENTRY line, else "main"-like
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            entry = m.group(1) if m else None
            break
    if entry is None or entry not in comps:
        candidates = [c for c in comps if c.startswith("main")]
        entry = candidates[0] if candidates else next(iter(comps))

    memo: Dict[Tuple[str, bool], Dict[str, float]] = {}

    def walk(comp: str, fused: bool) -> Dict[str, float]:
        key = (comp, fused)
        if key in memo:
            return memo[key]
        totals = {"flops": 0.0, "bytes": 0.0, "wire": 0.0}
        coll: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
        counts: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
        memo[key] = {**totals}  # cycle guard
        for op in comps.get(comp, []):
            oc = op.opcode
            base = oc.replace("-start", "").replace("-done", "")
            if oc.endswith("-done"):
                continue
            if oc == "while":
                t = _TRIP.search(op.line)
                mult = float(t.group(1)) if t else 1.0
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                if mb and mb.group(1) in comps:
                    sub = walk(mb.group(1), fused)
                    for k in totals:
                        totals[k] += mult * sub[k]
                    for c in COLLECTIVES:
                        coll[c] += mult * sub.get("coll_" + c, 0.0)
                        counts[c] += mult * sub.get("cnt_" + c, 0.0)
                # NOTE: loop-carry traffic is captured by the ops inside the
                # body (dynamic-slice reads of xs, the ops producing the new
                # carry); counting the while tuple itself would multiply the
                # whole stacked parameter tensor by the trip count.
                continue
            if oc == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", op.line)
                if mf and mf.group(1) in comps:
                    sub = walk(mf.group(1), True)  # dots-only inside fusions
                    totals["flops"] += sub["flops"]
                    totals["wire"] += sub["wire"]
                    for c in COLLECTIVES:
                        coll[c] += sub.get("coll_" + c, 0.0)
                        counts[c] += sub.get("cnt_" + c, 0.0)
                if not fused:
                    totals["bytes"] += _fusion_bytes(op, comps, table, lookup)
                continue
            if oc in ("call", "conditional", "async-start"):
                for sub_name in _CALLED.findall(op.line):
                    if sub_name in comps:
                        sub = walk(sub_name, fused)
                        for k in totals:
                            totals[k] += sub[k]
                        for c in COLLECTIVES:
                            coll[c] += sub.get("coll_" + c, 0.0)
                            counts[c] += sub.get("cnt_" + c, 0.0)
                continue
            if base in COLLECTIVES:
                wire = _collective_wire(op)
                totals["wire"] += wire
                coll[base] += wire
                counts[base] += 1
                if not fused:
                    totals["bytes"] += _shape_bytes(op.result)
                continue
            if oc in ("dot", "convolution"):
                totals["flops"] += _dot_flops(op, lookup)
                if not fused:
                    totals["bytes"] += _shape_bytes(op.result) + operand_bytes(op)
                continue
            if oc in _SLICE_OPS:
                if not fused:
                    totals["bytes"] += _shape_bytes(op.result)
                continue
            if oc in _UPDATE_OPS:
                if not fused:
                    names = _operand_names(op.line)
                    upd = (lookup(names[1]) if len(names) > 1 else None)
                    if oc == "scatter" and len(names) > 2:
                        upd = lookup(names[2])
                    totals["bytes"] += (2 * _shape_bytes(upd) if upd
                                        else _shape_bytes(op.result))
                continue
            if oc in _FREE_OPS:
                if oc == "custom-call" and not fused:
                    totals["bytes"] += _shape_bytes(op.result) + operand_bytes(op)
                continue
            # generic elementwise / reduce / gather / scatter / dus ops
            totals["flops"] += _shape_elems(op.result)
            if not fused:
                totals["bytes"] += _shape_bytes(op.result) + operand_bytes(op)
        result = dict(totals)
        for c in COLLECTIVES:
            result["coll_" + c] = coll[c]
            result["cnt_" + c] = counts[c]
        memo[key] = result
        return result

    return walk(entry, False)


def summarize(hlo: str) -> Dict[str, object]:
    r = analyze(hlo)
    return {
        "flops": r["flops"],
        "hbm_bytes": r["bytes"],
        "collective_wire_bytes": r["wire"],
        "collective_breakdown": {c: r["coll_" + c] for c in COLLECTIVES},
        "collective_counts": {c: r["cnt_" + c] for c in COLLECTIVES},
    }


def top_contributors(hlo: str, key: str = "bytes", n: int = 25):
    """Largest per-op contributors (with loop multipliers) — §Perf debugging."""
    comps = _split_computations(hlo)
    table = {}
    for ops in comps.values():
        for op in ops:
            table[op.name] = op.result
    lookup = table.get

    def operand_bytes(op):
        return sum(_shape_bytes(lookup(nm)) for nm in _operand_names(op.line)
                   if lookup(nm) is not None)

    # compute multiplier per computation via while nesting
    mults = {c: 0.0 for c in comps}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line)
            entry = m.group(1) if m else None
            break
    if entry is None or entry not in comps:
        entry = next(iter(comps))

    import collections
    queue = collections.deque([(entry, 1.0, False)])
    seen = set()
    items = []
    while queue:
        comp, mult, fused = queue.popleft()
        if (comp, mult, fused) in seen:
            continue
        seen.add((comp, mult, fused))
        for op in comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                t = _TRIP.search(op.line)
                m2 = float(t.group(1)) if t else 1.0
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                if mb and mb.group(1) in comps:
                    queue.append((mb.group(1), mult * m2, fused))
                continue
            if oc == "fusion":
                mf = re.search(r"calls=%?([\w.\-]+)", op.line)
                if mf and mf.group(1) in comps:
                    queue.append((mf.group(1), mult, True))
                if not fused:
                    b = _fusion_bytes(op, comps, table, lookup)
                    items.append((mult * b, mult, op.opcode, op.line[:160]))
                continue
            if oc in ("call", "conditional"):
                for sub in _CALLED.findall(op.line):
                    if sub in comps:
                        queue.append((sub, mult, fused))
                continue
            if fused:
                if oc in ("dot", "convolution"):
                    items.append((mult * _dot_flops(op, lookup), mult,
                                  "FLOPS:" + oc, op.line[:160]))
                continue
            if oc in _FREE_OPS or oc.endswith("-done"):
                continue
            if oc in _SLICE_OPS:
                b = _shape_bytes(op.result)
            elif oc in _UPDATE_OPS:
                names = _operand_names(op.line)
                upd = lookup(names[1]) if len(names) > 1 else None
                b = 2 * _shape_bytes(upd) if upd else _shape_bytes(op.result)
            else:
                b = _shape_bytes(op.result) + operand_bytes(op)
            items.append((mult * b, mult, op.opcode, op.line[:160]))
    items.sort(reverse=True)
    return items[:n]
