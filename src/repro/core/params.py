"""Per-market scenario parameters as device operands — the ensemble front door.

The seed API baked every scenario knob (shock step, flow intensities, agent
mixture) into the compiled trace as Python scalars, so a 72-config parity
sweep cost 72 compiles. This module makes the scenario axis *data*:

  * :class:`MarketParams` — a pytree of per-market ``[M, 1]`` arrays, one
    leaf per scenario-varying :class:`~repro.core.config.MarketConfig`
    field. Every backend (NumPy host loop, both JAX regimes, both Pallas
    kernels) takes it as an explicit runtime operand, so one warm trace
    serves *any* parameter values — and any per-market mixture of them.
  * :class:`EnsembleSpec` — the builder API. ``EnsembleSpec.homogeneous(cfg)``
    broadcasts one config over its markets (``Engine.open(cfg)`` wraps this
    and stays bitwise-identical to the scalar-config path);
    ``EnsembleSpec.from_scenarios([...])`` concatenates scenario blocks into
    one heterogeneous ensemble; ``EnsembleSpec.product(base, sweep=...)``
    expands a cartesian parameter sweep into one launch.

Because markets are row-independent and the RNG is a pure function of
(seed, global market id, step, channel), market ``m`` of a heterogeneous
ensemble is bitwise-identical to market ``m`` of the homogeneous ensemble
built from its scenario alone — the property the mixed-preset parity tests
in ``tests/test_ensemble.py`` assert on every backend.

Static vs dynamic split: array shapes (``M``, ``A``, ``L``) and the RNG
``seed`` fix the trace and form :meth:`EnsembleSpec.static_key`, the
engine's executable cache key; *everything else* rides in
:class:`MarketParams`, so parameter changes never retrace. The horizon
``num_steps`` is also Python-static (blocks of one ensemble must agree on
it, and scenario events are validated against it) but no trace depends on
it — specs differing only in horizon share one warm executable.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterable, NamedTuple, Sequence, Tuple, Union

import numpy as np

from repro.core.config import (
    MarketConfig,
    assign_agent_types,
    scenario_config,
    seed_books,
)


class MarketParams(NamedTuple):
    """Scenario-varying parameters, one ``[M, 1]`` column per market.

    Float leaves are float32, count/step leaves int32 — the dtypes the
    kernels consume directly (per-market rows are fetched into each grid
    tile alongside the global market-id operand). ``fundamental`` is the
    *resolved* fundamentalist target (the config's negative-means-midpoint
    convention is applied at build time, since ``L`` is static).
    """

    shock_step: Any           # int32[M, 1] flash-crash step (< 0 → disabled)
    shock_intensity: Any      # f32[M, 1] P(agent panic-sells at the shock)
    shock_cancel: Any         # f32[M, 1] fraction of resting bids withdrawn
    p_marketable: Any         # f32[M, 1] P(order is marketable)
    q_max: Any                # f32[M, 1] max order quantity (integer-valued)
    noise_delta: Any          # f32[M, 1] noise-trader price offset half-width
    maker_half_spread: Any    # f32[M, 1] maker quote half-spread
    fundamental: Any          # f32[M, 1] resolved fundamentalist target
    fundamentalist_kappa: Any # f32[M, 1] mean-reversion strength
    num_makers: Any           # int32[M, 1] leading agents assigned MAKER
    num_momentum: Any         # int32[M, 1] next block assigned MOMENTUM
    num_fundamentalists: Any  # int32[M, 1] next block assigned FUNDAMENTALIST
    num_whales: Any           # int32[M, 1] next block assigned WHALE
    num_hft: Any              # int32[M, 1] next block assigned HFT
    num_informed: Any         # int32[M, 1] next block assigned INFORMED
    num_arbitrageurs: Any     # int32[M, 1] next block assigned ARBITRAGEUR
    whale_size: Any           # f32[M, 1] lots per whale sweep (integer-valued)
    whale_period: Any         # int32[M, 1] steps between whale sweeps (>= 1)
    hft_threshold: Any        # f32[M, 1] |book imbalance| HFT trigger
    informed_horizon: Any     # int32[M, 1] steps of early shock knowledge
    arb_kappa: Any            # f32[M, 1] arbitrageur gap-chasing strength
    coupling_peer: Any        # int32[M, 1] peer market feeding arbs (<0: self)

    def to_numpy(self) -> "MarketParams":
        return MarketParams(*(np.asarray(x) for x in self))

    @property
    def num_markets(self) -> int:
        return int(np.shape(self.shock_step)[0])

    @staticmethod
    def field_dtype(field: str):
        return np.int32 if field in _INT_FIELDS else np.float32

    def asarray(self, xp) -> "MarketParams":
        """Dtype-preserving placement into array module ``xp`` — the single
        live copy of the per-field dtype coercion, shared by the session
        placement hook, the kernels' spec fallback, and the autotuner."""
        return MarketParams(*(
            xp.asarray(np.asarray(leaf), dtype=MarketParams.field_dtype(f))
            for f, leaf in zip(MarketParams._fields, self)))

    @classmethod
    def zeros(cls, num_markets: int, xp) -> "MarketParams":
        """Valid all-zero parameter columns (timing/padding operands)."""
        return cls(*(xp.zeros((num_markets, 1), cls.field_dtype(f))
                     for f in cls._fields))


#: MarketParams leaves carried as int32 (counts and step/index coordinates).
_INT_FIELDS = ("shock_step", "num_makers", "num_momentum",
               "num_fundamentalists", "num_whales", "num_hft",
               "num_informed", "num_arbitrageurs", "whale_period",
               "informed_horizon", "coupling_peer")

#: Inert per-field values: the value each leaf takes when its archetype is
#: absent (counts 0, self-coupling) — the back-compat fill for snapshots
#: and journals recorded before a field existed, and the parked-slot rows.
#: ``fundamental`` is shape-dependent (grid midpoint) and handled by
#: callers explicitly.
INERT_PARAM_VALUES: Dict[str, float] = {
    "shock_step": -1, "shock_intensity": 0.0, "shock_cancel": 0.0,
    "p_marketable": 0.0, "q_max": 1.0, "noise_delta": 0.0,
    "maker_half_spread": 0.0, "fundamentalist_kappa": 0.0,
    "num_makers": 0, "num_momentum": 0, "num_fundamentalists": 0,
    "num_whales": 0, "num_hft": 0, "num_informed": 0,
    "num_arbitrageurs": 0, "whale_size": 1.0, "whale_period": 1,
    "hft_threshold": 0.0, "informed_horizon": 0, "arb_kappa": 0.0,
    "coupling_peer": -1,
}


def params_from_dict(values: Dict[str, Any], num_markets: int,
                     num_levels: int) -> MarketParams:
    """Rebuild host params from a ``{field: array}`` mapping (snapshot /
    journal payloads), default-filling fields the payload predates.

    Older payloads are valid ensembles whose missing leaves were
    definitionally inert (the archetype/coupling did not exist when they
    were written), so the fill is value-invisible by construction.
    """
    M = int(num_markets)
    leaves = []
    for f in MarketParams._fields:
        if f in values:
            leaves.append(np.asarray(values[f],
                                     dtype=MarketParams.field_dtype(f)))
        else:
            fill = (float(num_levels // 2) if f == "fundamental"
                    else INERT_PARAM_VALUES[f])
            leaves.append(np.full((M, 1), fill, MarketParams.field_dtype(f)))
    return MarketParams(*leaves)


def replace_rows(params: MarketParams, slots, rows: MarketParams,
                 ) -> MarketParams:
    """Host-side row splice: ``params`` with markets ``slots`` replaced by
    the rows of ``rows`` (a ``len(slots)``-market params pytree).

    The serving gateway's slot mutation primitive: attaching/detaching a
    client's market into a running ensemble is a pure value update — the
    result has identical shapes/dtypes, so re-placing it on device reuses
    the warm executable (shape-semantic cache keys) and every *other* row
    is carried over bitwise-untouched.
    """
    idx = np.asarray(slots, dtype=np.int64).reshape(-1)
    M = params.num_markets
    if idx.size != rows.num_markets:
        raise ValueError(
            f"replace_rows got {idx.size} slots but {rows.num_markets} "
            "replacement rows")
    if idx.size != np.unique(idx).size:
        raise ValueError(f"slots must be unique, got {idx.tolist()}")
    if ((idx < 0) | (idx >= M)).any():
        raise ValueError(f"slots {idx.tolist()} out of range [0, {M})")
    out = []
    for f, leaf, src in zip(MarketParams._fields, params, rows):
        leaf = np.array(np.asarray(leaf), dtype=MarketParams.field_dtype(f))
        leaf[idx] = np.asarray(src, dtype=leaf.dtype)
        out.append(leaf)
    return MarketParams(*out)


def _config_values(cfg: MarketConfig) -> Dict[str, float]:
    """One config's scenario-varying values, keyed by MarketParams field."""
    return {
        "shock_step": cfg.shock_step,
        "shock_intensity": cfg.shock_intensity,
        "shock_cancel": cfg.shock_cancel,
        "p_marketable": cfg.p_marketable,
        "q_max": cfg.q_max,
        "noise_delta": cfg.noise_delta,
        "maker_half_spread": cfg.maker_half_spread,
        "fundamental": cfg.fundamental,
        "fundamentalist_kappa": cfg.fundamentalist_kappa,
        "num_makers": cfg.num_makers,
        "num_momentum": cfg.num_momentum,
        "num_fundamentalists": cfg.num_fundamentalists,
        "num_whales": cfg.num_whales,
        "num_hft": cfg.num_hft,
        "num_informed": cfg.num_informed,
        "num_arbitrageurs": cfg.num_arbitrageurs,
        "whale_size": cfg.whale_size,
        "whale_period": cfg.whale_period,
        "hft_threshold": cfg.hft_threshold,
        "informed_horizon": cfg.informed_horizon,
        "arb_kappa": cfg.arb_kappa,
        # Peer wiring is an ensemble-level concern (repro.scenario
        # .CouplingSpec); a plain config always self-couples.
        "coupling_peer": -1,
    }


def params_from_config(cfg: MarketConfig, num_markets: int = None,
                       xp=np) -> MarketParams:
    """Homogeneous per-market params: broadcast one config over M rows."""
    M = cfg.num_markets if num_markets is None else int(num_markets)
    vals = _config_values(cfg)
    return MarketParams(**{
        f: xp.full((M, 1), vals[f], dtype=MarketParams.field_dtype(f))
        for f in MarketParams._fields
    })


def scalar_params(cfg: MarketConfig, xp) -> MarketParams:
    """Broadcastable ``[1, 1]`` constant params for legacy scalar-config
    entry points (the one-shot kernels, the jitted reference oracle): inside
    a trace these fold to the exact constants the pre-ensemble code used, so
    the scalar path stays bitwise-identical to the seed engine."""
    return params_from_config(cfg, num_markets=1, xp=xp)


def agent_types(params: MarketParams, num_agents: int, xp):
    """Per-market strategy-class lattice: int32 broadcastable to [M, A].

    The single shared assignment rule
    (:func:`repro.core.config.assign_agent_types`) driven by the per-market
    count operands, so each ensemble row carries its own population mix —
    and the scalar path can never drift from it.
    """
    return assign_agent_types(xp, num_agents, params.num_makers,
                              params.num_momentum,
                              params.num_fundamentalists,
                              params.num_whales, params.num_hft,
                              params.num_informed, params.num_arbitrageurs)


# ---------------------------------------------------------------------------
# EnsembleSpec: the builder front door
# ---------------------------------------------------------------------------

#: Fields every block of a heterogeneous ensemble must agree on: they are
#: Python-static (they fix array shapes / the RNG key / the horizon).
_STATIC_FIELDS = ("num_agents", "num_levels", "num_steps", "seed")


@dataclasses.dataclass(frozen=True, eq=False)
class EnsembleSpec:
    """A heterogeneous market ensemble: static shape + per-market params.

    The engine-facing twin of :class:`MarketConfig`. ``Engine.open`` accepts
    either; a config is coerced through :meth:`homogeneous`, which is
    bitwise-identical to the historical scalar-config path. Specs compare by
    identity (they hold arrays) — the executable cache keys on
    :meth:`static_key`, never on parameter values.
    """

    num_markets: int
    num_agents: int
    num_levels: int
    num_steps: int
    seed: int
    params: MarketParams               # host numpy [M, 1] leaves
    initial_quote_qty: np.ndarray      # f32[M] opening book depth
    initial_spread: np.ndarray         # int32[M] opening spread (ticks)
    scenarios: Tuple[str, ...] = ()    # per-market preset labels (metadata)

    # ---- constructors ----
    @classmethod
    def homogeneous(cls, cfg: MarketConfig) -> "EnsembleSpec":
        """Broadcast one config over its ``num_markets`` markets."""
        M = cfg.num_markets
        return cls(
            num_markets=M, num_agents=cfg.num_agents,
            num_levels=cfg.num_levels, num_steps=cfg.num_steps,
            seed=cfg.seed, params=params_from_config(cfg),
            initial_quote_qty=np.full(M, cfg.initial_quote_qty, np.float32),
            initial_spread=np.full(M, cfg.initial_spread, np.int32),
            scenarios=(cfg.scenario,) * M,
        )

    @classmethod
    def from_scenarios(cls, blocks: Sequence[Union[MarketConfig, str]],
                       **common: Any) -> "EnsembleSpec":
        """Concatenate scenario blocks into one heterogeneous ensemble.

        Each element is a :class:`MarketConfig` (contributing its
        ``num_markets`` rows) or a preset name (resolved through
        :func:`repro.core.config.scenario_config`). The ``common``
        overrides (e.g. ``num_markets=8, num_agents=64``) apply to *every*
        block — names and configs alike, the latter via
        ``dataclasses.replace`` — so one call site pins the shared shape.
        Blocks must agree on the static fields (A, L, S, seed); a mismatch
        is a loud error — per-market *seeds* are not supported because the
        stateful PCG64 reference RNG has a single stream.

        Market ``m`` of the result is bitwise-identical, on every backend,
        to market ``m`` of ``homogeneous(block)`` for the block covering
        row ``m`` (padded to the full ensemble width) — block boundaries are
        invisible to the per-market streams.
        """
        cfgs = [scenario_config(b, **common) if isinstance(b, str)
                else (dataclasses.replace(b, **common) if common else b)
                for b in blocks]
        if not cfgs:
            raise ValueError("from_scenarios needs at least one block")
        first = cfgs[0]
        for i, c in enumerate(cfgs[1:], start=1):
            for f in _STATIC_FIELDS:
                if getattr(c, f) != getattr(first, f):
                    raise ValueError(
                        f"ensemble blocks must agree on static field {f!r}: "
                        f"block 0 has {getattr(first, f)}, block {i} "
                        f"({c.scenario}) has {getattr(c, f)}")
        specs = [cls.homogeneous(c) for c in cfgs]
        return cls.concatenate(specs)

    @classmethod
    def product(cls, base: MarketConfig, sweep: Dict[str, Iterable[Any]],
                markets_per_config: int = None) -> "EnsembleSpec":
        """Cartesian parameter sweep as one ensemble.

        ``sweep`` maps :class:`MarketConfig` field names to value lists;
        every combination contributes ``markets_per_config`` (default
        ``base.num_markets``) rows built via ``dataclasses.replace``. The
        whole sweep then runs in one compile and one launch per chunk —
        the regime ``benchmarks/scenario_sweep.py`` measures against the
        per-config loop.
        """
        if not sweep:
            raise ValueError("product() needs a non-empty sweep")
        M = base.num_markets if markets_per_config is None \
            else int(markets_per_config)
        names = list(sweep)
        cfgs = [
            dataclasses.replace(base, num_markets=M,
                                **dict(zip(names, combo)))
            for combo in itertools.product(*(sweep[n] for n in names))
        ]
        return cls.from_scenarios(cfgs)

    @classmethod
    def concatenate(cls, specs: Sequence["EnsembleSpec"]) -> "EnsembleSpec":
        """Stack already-built specs along the market axis."""
        if not specs:
            raise ValueError("concatenate needs at least one spec")
        first = specs[0]
        for s in specs[1:]:
            for f in _STATIC_FIELDS:
                if getattr(s, f) != getattr(first, f):
                    raise ValueError(
                        f"ensemble blocks must agree on static field {f!r}")
        return cls(
            num_markets=sum(s.num_markets for s in specs),
            num_agents=first.num_agents, num_levels=first.num_levels,
            num_steps=first.num_steps, seed=first.seed,
            params=MarketParams(*(
                np.concatenate([np.asarray(getattr(s.params, f))
                                for s in specs], axis=0)
                for f in MarketParams._fields)),
            initial_quote_qty=np.concatenate(
                [s.initial_quote_qty for s in specs]),
            initial_spread=np.concatenate([s.initial_spread for s in specs]),
            scenarios=tuple(itertools.chain.from_iterable(
                s.scenarios for s in specs)),
        )

    @classmethod
    def coerce(cls, obj: Union["EnsembleSpec", MarketConfig]) -> "EnsembleSpec":
        """The front-door normalizer: configs become homogeneous specs."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, MarketConfig):
            return cls.homogeneous(obj)
        raise TypeError(
            f"expected MarketConfig or EnsembleSpec, got {type(obj).__name__}")

    def __post_init__(self):
        self.validate()

    # ---- derived API mirroring MarketConfig (duck-typed by the runners) ----
    @property
    def mid0(self) -> float:
        return float(self.num_levels // 2)

    def events(self) -> int:
        """Total agent events M*A*S (paper's throughput denominator)."""
        return self.num_markets * self.num_agents * self.num_steps

    def initial_books(self, xp) -> Tuple[Any, Any]:
        """(bid, ask) float32[M, L] per-market opening books.

        Delegates to the single shared seeding rule
        (:func:`repro.core.config.seed_books`) with this spec's per-market
        depth/spread — a homogeneous spec produces bitwise the books the
        scalar path does, by construction.
        """
        return seed_books(
            xp, self.num_levels,
            xp.asarray(np.asarray(self.initial_quote_qty, np.float32)),
            xp.asarray(np.asarray(self.initial_spread, np.int32)))

    def static_key(self) -> Tuple[Any, ...]:
        """Executable cache key: shape/structure-semantic only.

        Everything that fixes the *trace* — array shapes and the RNG seed
        baked into the counter hash — and nothing that is merely a value:
        two specs with equal keys share one compiled executable, whatever
        their scenario mixture.
        """
        return (self.num_markets, self.num_agents, self.num_levels, self.seed)

    # ---- builders for parameter updates (no retrace: same static key) ----
    def replace_markets(self, slots, sub: "EnsembleSpec") -> "EnsembleSpec":
        """New spec with markets ``slots`` replaced by the rows of ``sub``.

        The spec-level twin of :func:`replace_rows`, carrying the scenario
        labels and per-market opening-book fields along with the params —
        the serving gateway's attach/detach bookkeeping. ``sub`` must agree
        with this spec on every static field (shapes/seed/horizon), so the
        result keeps this spec's :meth:`static_key` and therefore its warm
        executable.
        """
        for f in _STATIC_FIELDS:
            if getattr(sub, f) != getattr(self, f):
                raise ValueError(
                    f"replace_markets rows must agree on static field {f!r}:"
                    f" this spec has {getattr(self, f)}, the replacement has"
                    f" {getattr(sub, f)}")
        idx = np.asarray(slots, dtype=np.int64).reshape(-1)
        scenarios = list(self.scenarios or ("?",) * self.num_markets)
        quote = np.array(self.initial_quote_qty, np.float32)
        spread = np.array(self.initial_spread, np.int32)
        params = replace_rows(self.params, idx, sub.params)  # validates idx
        quote[idx] = np.asarray(sub.initial_quote_qty, np.float32)
        spread[idx] = np.asarray(sub.initial_spread, np.int32)
        for k, slot in enumerate(idx):
            scenarios[slot] = (sub.scenarios[k] if k < len(sub.scenarios)
                               else "?")
        return dataclasses.replace(
            self, params=params, initial_quote_qty=quote,
            initial_spread=spread, scenarios=tuple(scenarios))

    def with_values(self, **fields: Any) -> "EnsembleSpec":
        """New spec with some :class:`MarketParams` leaves replaced.

        Values broadcast over the market axis (scalars or ``[M]``/``[M, 1]``
        arrays). Shapes stay fixed, so sessions opened on the result reuse
        the warm executable of this spec's engine; the per-market scenario
        labels gain a trailing ``*`` to mark them customized (metadata
        honesty in repr and snapshots). Note ``fundamental`` is
        the *resolved* target price — unlike ``MarketConfig
        .fundamental_price`` there is no negative-means-midpoint sentinel
        here (pass ``num_levels // 2`` for the grid midpoint); validation
        rejects negative values.
        """
        unknown = set(fields) - set(MarketParams._fields)
        if unknown:
            raise KeyError(f"unknown MarketParams fields: {sorted(unknown)}")
        leaves = {}
        for f in MarketParams._fields:
            if f in fields:
                v = np.asarray(fields[f], MarketParams.field_dtype(f))
                if v.ndim:
                    v = v.reshape(-1, 1)
                leaves[f] = np.ascontiguousarray(
                    np.broadcast_to(v, (self.num_markets, 1)))
            else:
                leaves[f] = np.asarray(getattr(self.params, f))
        # A trailing '*' marks customized presets, so repr and snapshot/
        # checkpoint metadata never claim an unmodified preset mixture for
        # params the preset did not produce.
        labels = tuple(n if n.endswith("*") else n + "*"
                       for n in self.scenarios)
        return dataclasses.replace(self, params=MarketParams(**leaves),
                                   scenarios=labels)

    # ---- validation (the scalar path's __post_init__, per market) ----
    def validate(self) -> None:
        M, A, L = self.num_markets, self.num_agents, self.num_levels
        if L < 4 or (L & (L - 1)) != 0:
            raise ValueError(f"num_levels must be a power of two >= 4, got {L}")
        if L > 1024:
            raise ValueError("num_levels > 1024 requires tiling (paper §V)")
        p = self.params.to_numpy()
        for f in MarketParams._fields:
            arr = np.asarray(getattr(p, f))
            if arr.shape != (M, 1):
                raise ValueError(
                    f"params.{f} must have shape ({M}, 1), got {arr.shape}")
            # Eager finiteness gate: NaN/inf must never reach a kernel —
            # NaN in particular sails through every range check below
            # (all comparisons are False) and would silently poison the
            # whole trajectory. Name the offending field and markets.
            bad = ~np.isfinite(arr.astype(np.float64))
            if bad.any():
                rows = np.where(bad[:, 0])[0]
                raise ValueError(
                    f"params.{f} contains non-finite values "
                    f"(nan/inf) in markets {rows[:8].tolist()}; "
                    "parameter operands must be finite")
        for name in ("initial_quote_qty", "initial_spread"):
            arr = np.asarray(getattr(self, name))
            if arr.shape != (M,):
                raise ValueError(
                    f"{name} must have shape ({M},), got {arr.shape}")
        spread = np.asarray(self.initial_spread)
        half = spread // 2 + spread % 2
        off_grid = (spread < 0) | (half > L // 2 - 1)
        if off_grid.any():
            bad = np.where(off_grid)[0]
            raise ValueError(
                f"initial_spread must place both opening quotes on the "
                f"grid (0 <= spread, ceil(spread/2) <= {L // 2 - 1} for "
                f"num_levels={L}); markets {bad[:8].tolist()} violate it")
        if (np.asarray(self.initial_quote_qty) < 0).any():
            raise ValueError("initial_quote_qty must be >= 0")
        for name in ("shock_intensity", "shock_cancel", "p_marketable"):
            arr = getattr(p, name)
            if ((arr < 0.0) | (arr > 1.0)).any():
                bad = np.where((arr < 0.0) | (arr > 1.0))[0]
                raise ValueError(
                    f"{name} must be in [0, 1]; markets {bad[:8].tolist()} "
                    "violate it")
        if (p.q_max < 1.0).any():
            bad = np.where((p.q_max < 1.0)[:, 0])[0]
            raise ValueError(
                f"q_max must be >= 1 (qty = 1 + floor(u * q_max) would go "
                f"non-positive); markets {bad[:8].tolist()} violate it")
        if (p.fundamental < 0.0).any():
            bad = np.where((p.fundamental < 0.0)[:, 0])[0]
            raise ValueError(
                f"fundamental must be a resolved price >= 0 (the config's "
                f"negative-means-midpoint sentinel is applied at build time; "
                f"use num_levels // 2 = {L // 2} for the grid midpoint); "
                f"markets {bad[:8].tolist()} violate it")
        assigned = (p.num_makers + p.num_momentum + p.num_fundamentalists
                    + p.num_whales + p.num_hft + p.num_informed
                    + p.num_arbitrageurs)
        if (assigned > A).any():
            bad = np.where((assigned > A)[:, 0])[0]
            raise ValueError(
                f"agent mixture assigns more than num_agents={A} agents in "
                f"markets {bad[:8].tolist()}")
        if ((p.num_makers < 0) | (p.num_momentum < 0)
                | (p.num_fundamentalists < 0) | (p.num_whales < 0)
                | (p.num_hft < 0) | (p.num_informed < 0)
                | (p.num_arbitrageurs < 0)).any():
            raise ValueError("archetype counts must be >= 0")
        if ((p.whale_size < 1.0)
                | (p.whale_size != np.floor(p.whale_size))).any():
            bad = np.where(((p.whale_size < 1.0)
                            | (p.whale_size != np.floor(p.whale_size)))[:, 0])[0]
            raise ValueError(
                f"whale_size must be an integer-valued lot count >= 1 "
                f"(exact in f32); markets {bad[:8].tolist()} violate it")
        if (p.whale_period < 1).any():
            bad = np.where((p.whale_period < 1)[:, 0])[0]
            raise ValueError(
                f"whale_period must be >= 1; markets {bad[:8].tolist()} "
                "violate it")
        if ((p.hft_threshold < 0.0) | (p.hft_threshold > 1.0)).any():
            bad = np.where(((p.hft_threshold < 0.0)
                            | (p.hft_threshold > 1.0))[:, 0])[0]
            raise ValueError(
                f"hft_threshold must be in [0, 1] (book imbalance is "
                f"normalized); markets {bad[:8].tolist()} violate it")
        if (p.informed_horizon < 0).any():
            raise ValueError("informed_horizon must be >= 0")
        if (p.arb_kappa < 0.0).any():
            raise ValueError("arb_kappa must be >= 0")
        # Coupling peers index the *global* market axis; -1 self-couples.
        if ((p.coupling_peer < -1) | (p.coupling_peer >= M)).any():
            bad = np.where(((p.coupling_peer < -1)
                            | (p.coupling_peer >= M))[:, 0])[0]
            raise ValueError(
                f"coupling_peer must be -1 (self) or a market index in "
                f"[0, {M}); markets {bad[:8].tolist()} violate it")
        # Horizon semantics (see Session.stream): every scenario event must
        # lie inside [0, num_steps) — a shock placed at or past the horizon
        # would silently never fire in a default-length run.
        beyond = p.shock_step >= self.num_steps
        if beyond.any():
            bad = np.where(beyond[:, 0])[0]
            raise ValueError(
                f"shock_step must be < num_steps={self.num_steps} (the "
                f"session horizon); markets {bad[:8].tolist()} place the "
                "shock at or past it and a default-length run would "
                "silently never fire it")

    @classmethod
    def parked(cls, like: "EnsembleSpec", num_markets: int = None,
               ) -> "EnsembleSpec":
        """A minimal-activity ensemble agreeing with ``like`` on every
        static field — the serving gateway's *parked slot* rows.

        A detached slot keeps simulating (the step loop is branch-free and
        shape-static; removing a row would retrace), so parked rows are
        built to make that dead work as inert as possible: no scenario
        events (``shock_step=-1``), all agents quoting passively at the mid
        with zero offset and unit size (``p_marketable=0``,
        ``noise_delta=0``, ``q_max=1``, no maker/momentum/fundamentalist
        blocks), and empty opening books. The slot still costs its share of
        the ensemble's fixed per-chunk work — what it never costs is an
        extra trace, host sync, or any effect on other rows.
        """
        M = like.num_markets if num_markets is None else int(num_markets)
        values = dict(INERT_PARAM_VALUES,
                      fundamental=float(like.num_levels // 2))
        return cls(
            num_markets=M, num_agents=like.num_agents,
            num_levels=like.num_levels, num_steps=like.num_steps,
            seed=like.seed,
            params=MarketParams(**{
                f: np.full((M, 1), values[f], MarketParams.field_dtype(f))
                for f in MarketParams._fields}),
            initial_quote_qty=np.zeros(M, np.float32),
            initial_spread=np.zeros(M, np.int32),
            scenarios=("parked",) * M,
        )

    def __repr__(self) -> str:  # arrays make the dataclass repr unreadable
        kinds = [f"{name}×{len(list(group))}"
                 for name, group in itertools.groupby(self.scenarios)]
        return (f"EnsembleSpec(M={self.num_markets}, A={self.num_agents}, "
                f"L={self.num_levels}, S={self.num_steps}, seed={self.seed}, "
                f"scenarios=[{', '.join(kinds) or '?'}])")
