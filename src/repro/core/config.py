"""Configuration for the KineticSim market engine."""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Agent strategy classes (paper §III-C)
NOISE = 0
MOMENTUM = 1
MAKER = 2

# RNG channels
CH_SIDE = 0
CH_PRICE = 1
CH_MKT = 2
CH_QTY = 3


@dataclasses.dataclass(frozen=True)
class MarketConfig:
    """Parameters of the uniform-price call-auction ensemble (paper §III).

    Defaults follow the paper's benchmarked configuration: L=128 price ticks,
    S=500 steps, population mix 15% makers / 15% momentum / 70% noise.
    """

    num_markets: int = 64          # M — independent markets
    num_agents: int = 256          # A — agents per market
    num_levels: int = 128          # L — price grid ticks (power of two)
    num_steps: int = 500           # S — simulation steps
    seed: int = 0

    # Agent behaviour (paper §III-C)
    q_max: int = 8                 # max order quantity
    p_marketable: float = 0.1      # P_mkt — probability of a marketable order
    noise_delta: float = 8.0       # Δ_noise — uniform price offset half-width
    maker_half_spread: float = 2.0 # Δ_maker_half_spread

    # Population mix (paper §IV-J: α_maker fixed at 0.15, α_mom swept)
    alpha_maker: float = 0.15
    alpha_momentum: float = 0.15

    # Opening book seeding (paper Alg.1 line 3); quotes straddle L/2.
    initial_quote_qty: float = 10.0
    initial_spread: int = 2        # opening bid at L/2 - spread/2 ... ask at +

    def __post_init__(self):
        L = self.num_levels
        if L < 4 or (L & (L - 1)) != 0:
            raise ValueError(f"num_levels must be a power of two >= 4, got {L}")
        if L > 1024:
            raise ValueError("num_levels > 1024 requires tiling (paper §V)")
        if not (0.0 <= self.alpha_maker + self.alpha_momentum <= 1.0):
            raise ValueError("agent fractions must sum to <= 1")

    # ---- derived population counts (deterministic by agent index) ----
    @property
    def num_makers(self) -> int:
        return int(round(self.num_agents * self.alpha_maker))

    @property
    def num_momentum(self) -> int:
        return int(round(self.num_agents * self.alpha_momentum))

    @property
    def mid0(self) -> float:
        return float(self.num_levels // 2)

    def agent_types(self, xp) -> "xp.ndarray":
        """int32[A] strategy class per agent index: makers, momentum, noise."""
        a = xp.arange(self.num_agents, dtype=xp.int32)
        nm, nmo = self.num_makers, self.num_momentum
        return xp.where(
            a < nm,
            xp.int32(MAKER),
            xp.where(a < nm + nmo, xp.int32(MOMENTUM), xp.int32(NOISE)),
        )

    def initial_books(self, xp) -> Tuple["xp.ndarray", "xp.ndarray"]:
        """(bid, ask) float32[M, L] opening books."""
        M, L = self.num_markets, self.num_levels
        bid = xp.zeros((M, L), dtype=xp.float32)
        ask = xp.zeros((M, L), dtype=xp.float32)
        half = self.initial_spread // 2 + self.initial_spread % 2
        pb = L // 2 - half
        pa = L // 2 + half
        q = xp.float32(self.initial_quote_qty)
        onehot_b = (xp.arange(L, dtype=xp.int32) == pb).astype(xp.float32) * q
        onehot_a = (xp.arange(L, dtype=xp.int32) == pa).astype(xp.float32) * q
        bid = bid + onehot_b[None, :]
        ask = ask + onehot_a[None, :]
        return bid, ask

    def events(self) -> int:
        """Total agent events M*A*S (paper's throughput denominator)."""
        return self.num_markets * self.num_agents * self.num_steps
