"""Configuration for the KineticSim market engine.

Besides the raw simulation shape (M, A, L, S) this module owns the two axes
of the scenario engine:

  * the **archetype mixture** — static per-config fractions of the agent
    population assigned to each strategy class (paper §III-C plus the
    fundamentalist/mean-reversion class), resolved to a deterministic
    ``int32[A]`` type vector by agent index so every backend sees the exact
    same population; and
  * the **scenario** — named presets (baseline, flash-crash, high/low
    volatility regimes, wide/thin opening books) expressed purely as config
    fields, so scenario dispatch compiles to branch-free ``where`` selects
    inside the fused step and never breaks the persistent kernel.

A ``MarketConfig`` is the *scalar* surface: one value per field, uniform
over the ensemble. The engine-facing generalization is
:class:`repro.core.params.EnsembleSpec`, which stacks per-market values of
every scenario-varying field into device operands — ``Engine.open(cfg)``
coerces a config through ``EnsembleSpec.homogeneous`` bitwise-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

# Agent strategy classes (paper §III-C + fundamentalist extension + the
# coupled-scenario classes: whale / HFT / informed / cross-market arb)
NOISE = 0
MOMENTUM = 1
MAKER = 2
FUNDAMENTALIST = 3
WHALE = 4
HFT = 5
INFORMED = 6
ARBITRAGEUR = 7

# RNG channels
CH_SIDE = 0
CH_PRICE = 1
CH_MKT = 2
CH_QTY = 3
CH_SHOCK = 4


@dataclasses.dataclass(frozen=True)
class MarketConfig:
    """Parameters of the uniform-price call-auction ensemble (paper §III).

    Defaults follow the paper's benchmarked configuration: L=128 price ticks,
    S=500 steps, population mix 15% makers / 15% momentum / 70% noise.
    """

    num_markets: int = 64          # M — independent markets
    num_agents: int = 256          # A — agents per market
    num_levels: int = 128          # L — price grid ticks (power of two)
    num_steps: int = 500           # S — simulation steps
    seed: int = 0

    # Agent behaviour (paper §III-C)
    q_max: int = 8                 # max order quantity
    p_marketable: float = 0.1      # P_mkt — probability of a marketable order
    noise_delta: float = 8.0       # Δ_noise — uniform price offset half-width
    maker_half_spread: float = 2.0 # Δ_maker_half_spread

    # Population mix (paper §IV-J: α_maker fixed at 0.15, α_mom swept).
    # Static weights: agents [0, A·α_maker) are makers, the next A·α_mom are
    # momentum, the next A·α_fund fundamentalists, the remainder noise.
    alpha_maker: float = 0.15
    alpha_momentum: float = 0.15
    alpha_fundamentalist: float = 0.0

    # Fundamentalist behaviour: mean reversion toward ``fundamental_price``
    # (defaults to the grid midpoint when negative) at strength kappa.
    fundamental_price: float = -1.0
    fundamentalist_kappa: float = 0.5

    # Coupled-scenario archetypes (repro.scenario): whales sweep the book
    # with large marketable orders every ``whale_period`` steps; HFTs react
    # to resting-book imbalance beyond ``hft_threshold``; informed traders
    # see the fundamental shock ``informed_horizon`` steps early and
    # front-run it; arbitrageurs trade the gap to a coupled peer market's
    # previous-chunk mid (peer wiring lives on EnsembleSpec via
    # ``repro.scenario.CouplingSpec`` — a plain config always self-couples).
    alpha_whale: float = 0.0
    alpha_hft: float = 0.0
    alpha_informed: float = 0.0
    alpha_arbitrageur: float = 0.0
    whale_size: float = 32.0       # lots per whale sweep (integer-valued)
    whale_period: int = 16         # steps between sweeps (>= 1)
    hft_threshold: float = 0.2     # |imbalance| trigger, in [0, 1]
    informed_horizon: int = 8      # steps of early shock knowledge (>= 0)
    arb_kappa: float = 0.5         # gap-chasing strength (>= 0)

    # Scenario (presets below; "baseline" leaves every knob at its default).
    scenario: str = "baseline"
    shock_step: int = -1           # flash-crash step (< 0 → disabled)
    shock_intensity: float = 0.0   # P(agent panic-sells marketably at shock)
    shock_cancel: float = 0.0      # fraction of resting bids withdrawn at shock

    # Opening book seeding (paper Alg.1 line 3); quotes straddle L/2.
    initial_quote_qty: float = 10.0
    initial_spread: int = 2        # opening bid at L/2 - spread/2 ... ask at +

    def __post_init__(self):
        L = self.num_levels
        if L < 4 or (L & (L - 1)) != 0:
            raise ValueError(f"num_levels must be a power of two >= 4, got {L}")
        if L > 1024:
            raise ValueError("num_levels > 1024 requires tiling (paper §V)")
        mix_total = (self.alpha_maker + self.alpha_momentum
                     + self.alpha_fundamentalist + self.alpha_whale
                     + self.alpha_hft + self.alpha_informed
                     + self.alpha_arbitrageur)
        if not (0.0 <= mix_total <= 1.0):
            raise ValueError("agent fractions must sum to <= 1")
        assigned = (self.num_makers + self.num_momentum
                    + self.num_fundamentalists + self.num_whales
                    + self.num_hft + self.num_informed
                    + self.num_arbitrageurs)
        if assigned > self.num_agents:
            raise ValueError(
                f"per-class rounding assigns {assigned} agents > "
                f"num_agents={self.num_agents}; adjust alphas or num_agents")
        if not (0.0 <= self.shock_intensity <= 1.0):
            raise ValueError("shock_intensity must be in [0, 1]")
        if not (0.0 <= self.shock_cancel <= 1.0):
            raise ValueError("shock_cancel must be in [0, 1]")
        if self.shock_step >= self.num_steps:
            raise ValueError("shock_step must be < num_steps")
        if self.whale_size < 1 or self.whale_size != int(self.whale_size):
            raise ValueError("whale_size must be an integer-valued lot "
                             "count >= 1 (exact in f32)")
        if self.whale_period < 1:
            raise ValueError("whale_period must be >= 1")
        if not (0.0 <= self.hft_threshold <= 1.0):
            raise ValueError("hft_threshold must be in [0, 1] (book "
                             "imbalance is normalized)")
        if self.informed_horizon < 0:
            raise ValueError("informed_horizon must be >= 0")
        if self.arb_kappa < 0:
            raise ValueError("arb_kappa must be >= 0")

    # ---- derived population counts (deterministic by agent index) ----
    @property
    def num_makers(self) -> int:
        return int(round(self.num_agents * self.alpha_maker))

    @property
    def num_momentum(self) -> int:
        return int(round(self.num_agents * self.alpha_momentum))

    @property
    def num_fundamentalists(self) -> int:
        return int(round(self.num_agents * self.alpha_fundamentalist))

    @property
    def num_whales(self) -> int:
        return int(round(self.num_agents * self.alpha_whale))

    @property
    def num_hft(self) -> int:
        return int(round(self.num_agents * self.alpha_hft))

    @property
    def num_informed(self) -> int:
        return int(round(self.num_agents * self.alpha_informed))

    @property
    def num_arbitrageurs(self) -> int:
        return int(round(self.num_agents * self.alpha_arbitrageur))

    @property
    def mid0(self) -> float:
        return float(self.num_levels // 2)

    @property
    def fundamental(self) -> float:
        """Resolved fundamental price (grid midpoint unless overridden)."""
        return self.mid0 if self.fundamental_price < 0 else self.fundamental_price

    def mixture(self) -> Dict[int, float]:
        """Static archetype weights {type_id: fraction}, summing to 1."""
        noise = 1.0 - (self.alpha_maker + self.alpha_momentum
                       + self.alpha_fundamentalist + self.alpha_whale
                       + self.alpha_hft + self.alpha_informed
                       + self.alpha_arbitrageur)
        return {
            NOISE: noise,
            MOMENTUM: self.alpha_momentum,
            MAKER: self.alpha_maker,
            FUNDAMENTALIST: self.alpha_fundamentalist,
            WHALE: self.alpha_whale,
            HFT: self.alpha_hft,
            INFORMED: self.alpha_informed,
            ARBITRAGEUR: self.alpha_arbitrageur,
        }

    def archetype_counts(self) -> Dict[int, int]:
        """Resolved population {type_id: agent count} (sums to num_agents)."""
        nm, nmo, nf = self.num_makers, self.num_momentum, self.num_fundamentalists
        nw, nh, ni, na = (self.num_whales, self.num_hft, self.num_informed,
                          self.num_arbitrageurs)
        return {
            NOISE: self.num_agents - (nm + nmo + nf + nw + nh + ni + na),
            MOMENTUM: nmo,
            MAKER: nm,
            FUNDAMENTALIST: nf,
            WHALE: nw,
            HFT: nh,
            INFORMED: ni,
            ARBITRAGEUR: na,
        }

    def agent_types(self, xp) -> "xp.ndarray":
        """int32[A] strategy class per agent index.

        Delegates to the single shared assignment rule
        (:func:`assign_agent_types`) with this config's scalar counts, so
        the scalar path and the per-market ensemble path
        (``repro.core.params.agent_types``) can never drift apart.
        """
        return assign_agent_types(
            xp, self.num_agents, self.num_makers, self.num_momentum,
            self.num_fundamentalists, self.num_whales, self.num_hft,
            self.num_informed, self.num_arbitrageurs)[0]

    def initial_books(self, xp) -> Tuple["xp.ndarray", "xp.ndarray"]:
        """(bid, ask) float32[M, L] opening books."""
        M = self.num_markets
        return seed_books(
            xp, self.num_levels,
            xp.full((M,), self.initial_quote_qty, dtype=xp.float32),
            xp.full((M,), self.initial_spread, dtype=xp.int32))

    def events(self) -> int:
        """Total agent events M*A*S (paper's throughput denominator)."""
        return self.num_markets * self.num_agents * self.num_steps


def assign_agent_types(xp, num_agents: int, num_makers, num_momentum,
                       num_fundamentalists, num_whales=0, num_hft=0,
                       num_informed=0, num_arbitrageurs=0):
    """int32 strategy-class lattice broadcastable to [M, A].

    The single live copy of the deterministic assignment rule — makers
    first, then momentum, then fundamentalists, then whales, HFTs,
    informed traders, arbitrageurs, then noise, by agent index — shared by
    the scalar :meth:`MarketConfig.agent_types` (scalar counts → one row)
    and the per-market ``repro.core.params.agent_types`` (``[M, 1]`` count
    columns → ``[M, A]``), so every backend derives the identical
    population without any device-side state. With the new class counts at
    zero the block boundaries are unchanged, so legacy populations are
    bitwise-identical to the four-class rule.
    """
    a = xp.arange(num_agents, dtype=xp.int32)[None, :]
    blocks = (
        (MAKER, num_makers),
        (MOMENTUM, num_momentum),
        (FUNDAMENTALIST, num_fundamentalists),
        (WHALE, num_whales),
        (HFT, num_hft),
        (INFORMED, num_informed),
        (ARBITRAGEUR, num_arbitrageurs),
    )
    # Cumulative upper bounds per block; fold highest-threshold first so
    # each earlier (smaller) block overrides the later ones.
    uppers = []
    cum = xp.asarray(0, dtype=xp.int32)
    for tid, count in blocks:
        cum = cum + xp.asarray(count, dtype=xp.int32)
        uppers.append((tid, cum))
    out = xp.full_like(a, xp.int32(NOISE))
    for tid, upper in reversed(uppers):
        out = xp.where(a < upper, xp.int32(tid), out)
    return out


def seed_books(xp, num_levels: int, quote_qty, spread) -> Tuple:
    """(bid, ask) float32[M, L] opening books (paper Alg.1 line 3).

    The single live copy of the book-seeding rule, vectorized over
    per-market ``quote_qty`` (f32[M]) and ``spread`` (int32[M]) — quotes
    straddle L/2 at ``ceil(spread / 2)`` ticks. Shared by the scalar
    :meth:`MarketConfig.initial_books` and the per-market
    ``EnsembleSpec.initial_books`` so the homogeneous path stays
    bitwise-identical by construction.
    """
    L = num_levels
    half = spread // 2 + spread % 2                      # int32[M]
    pb = (xp.int32(L // 2) - half)[:, None]              # int32[M, 1]
    pa = (xp.int32(L // 2) + half)[:, None]
    q = xp.asarray(quote_qty, dtype=xp.float32)[:, None] # f32[M, 1]
    levels = xp.arange(L, dtype=xp.int32)[None, :]
    bid = (levels == pb).astype(xp.float32) * q
    ask = (levels == pa).astype(xp.float32) * q
    return bid, ask


# ---------------------------------------------------------------------------
# Scenario presets. Each preset is a function (num_steps) -> field overrides;
# taking num_steps lets flash-crash place its shock mid-run by default.
# ---------------------------------------------------------------------------
SCENARIO_PRESETS: Dict[str, Callable[[int], dict]] = {}


def register_scenario(name: str):
    def deco(fn):
        SCENARIO_PRESETS[name] = fn
        return fn
    return deco


@register_scenario("baseline")
def _baseline(num_steps: int) -> dict:
    return {}


@register_scenario("flash-crash")
def _flash_crash(num_steps: int) -> dict:
    # Mid-run shock: 60% of non-maker agents dump marketably while half the
    # resting bid support is withdrawn at the same step.
    return {
        "shock_step": num_steps // 2,
        "shock_intensity": 0.6,
        "shock_cancel": 0.5,
    }


@register_scenario("high-vol")
def _high_vol(num_steps: int) -> dict:
    return {"noise_delta": 16.0, "p_marketable": 0.25}


@register_scenario("low-vol")
def _low_vol(num_steps: int) -> dict:
    return {"noise_delta": 2.0, "p_marketable": 0.05}


@register_scenario("whale")
def _whale(num_steps: int) -> dict:
    # A small population of large infrequent sweepers over a momentum-rich
    # high-vol base: each whale crosses the spread with `whale_size` lots
    # every `whale_period` steps and sits out in between.
    return {"noise_delta": 16.0, "p_marketable": 0.25, "alpha_maker": 0.15,
            "alpha_momentum": 0.40, "alpha_whale": 0.05,
            "whale_size": 32.0, "whale_period": 16}


@register_scenario("hft")
def _hft(num_steps: int) -> dict:
    # Book-imbalance reactive traders: join the heavy side one tick inside
    # the mid whenever |imbalance| clears the threshold. The population is
    # small and the trigger strict — larger/looser HFT crowds amplify
    # one-sided books so hard that volume decouples from volatility and
    # the stylized-facts gate (repro.scenario.validate) fails.
    return {"noise_delta": 16.0, "p_marketable": 0.25, "alpha_maker": 0.15,
            "alpha_momentum": 0.35, "alpha_hft": 0.03,
            "hft_threshold": 0.5}


@register_scenario("informed")
def _informed(num_steps: int) -> dict:
    # Informed traders see the flash-crash shock `informed_horizon` steps
    # early and sell marketably through the pre-shock window. Kept to 5% of
    # the crowd: a larger informed cohort drags the volume/volatility
    # correlation negative (see repro.scenario.validate).
    return {"noise_delta": 16.0, "p_marketable": 0.25, "alpha_maker": 0.15,
            "alpha_momentum": 0.40, "alpha_informed": 0.05,
            "shock_step": num_steps // 2, "shock_intensity": 0.3,
            "informed_horizon": 8}


@register_scenario("wide-book")
def _wide_book(num_steps: int) -> dict:
    return {"initial_quote_qty": 64.0, "initial_spread": 8}


@register_scenario("thin-book")
def _thin_book(num_steps: int) -> dict:
    return {"initial_quote_qty": 1.0, "initial_spread": 2}


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIO_PRESETS))


def scenario_config(name: str, **overrides) -> MarketConfig:
    """Preset constructor: build a MarketConfig for a named scenario.

    Explicit ``overrides`` win over preset fields, so e.g. the flash-crash
    shock step stays configurable: ``scenario_config("flash-crash",
    shock_step=7, num_steps=20)``.
    """
    if name not in SCENARIO_PRESETS:
        raise KeyError(
            f"unknown scenario {name!r}; have {scenario_names()}")
    if overrides.get("scenario", name) != name:
        raise ValueError(
            f"scenario={overrides['scenario']!r} override conflicts with "
            f"preset name {name!r}")
    num_steps = overrides.get(
        "num_steps", MarketConfig.__dataclass_fields__["num_steps"].default)
    fields = dict(SCENARIO_PRESETS[name](num_steps))
    fields.update(overrides)
    fields["scenario"] = name
    return MarketConfig(**fields)
