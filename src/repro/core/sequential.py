"""Sequential-clearing reference mechanism (Steinbacher et al.).

The production engine clears each step as one uniform-price call auction
over the *aggregate* order flow (:mod:`repro.core.auction`) — the
mechanism that makes the step embarrassingly parallel over agents. The
classical ABM literature instead matches orders **one agent at a time**
against the resting book (continuous-double-auction style), and
Steinbacher et al. show the choice of mechanism itself changes the
emergent dynamics. This module is the sequential reference the repo uses
to *quantify* that gap:

  * identical agent decisions — the same :func:`repro.core.agents.decide`
    draws on the same fixed five-channel schedule, so any trajectory
    difference is attributable to the clearing mechanism alone;
  * order-by-order immediate matching in agent-index order, vectorized
    over the market axis: a buy at limit ``p`` fills against resting asks
    at levels ``<= p`` (lowest first), the residual rests at ``p``; sells
    are symmetric against resting bids (highest first);
  * exact-integer f32 arithmetic throughout (cumsum/min/clip of integer
    masses), so the NumPy host loop and the jitted ``lax.scan`` reference
    (:func:`repro.kernels.ref.simulate_reference_sequential`) are
    **bitwise identical** — the same reproducibility bar the parallel
    engine clears.

Exposed as ``Engine("numpy", clearing="sequential")`` through the session
layer and re-exported by :mod:`repro.scenario` for the mechanism-gap
reports.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import agents, auction
from repro.core import params as params_mod
from repro.core.params import MarketParams
from repro.core.step import (
    MarketState,
    StepOutput,
    apply_scenario_shock,
)


def match_order(bid, ask, exec_price, side_buy, price, qty, xp):
    """Match ONE order per market against the resting books, immediately.

    All operands are per-market columns: ``side_buy`` bool[M, 1], ``price``
    int32[M, 1] (limit level), ``qty`` f32[M, 1] (integer-valued lots);
    ``bid``/``ask`` are the resting f32[M, L] books. Returns
    ``(bid, ask, fill, exec_price)`` where ``fill`` is the executed
    quantity and ``exec_price`` carries the marginal executed level (the
    previous value where nothing traded).

    Both sides are evaluated branch-free and selected by the side mask, so
    the jitted ``lax.fori_loop`` driver and the NumPy agent loop run the
    identical op sequence. Every quantity is an exact integer in f32
    (cumsums of book masses stay far below 2^24), so fills, residuals and
    book updates are bitwise reproducible across backends.
    """
    f32 = xp.float32
    L = bid.shape[-1]
    levels = xp.arange(L, dtype=xp.int32)[None, :]
    onehot = (levels == price).astype(f32)            # [M, L] at the limit

    # Buy: sweep asks at levels <= p, lowest first.
    s_cum = xp.cumsum(ask, axis=-1)                   # prefix supply
    elig_b = xp.take_along_axis(s_cum, price, axis=-1)
    fill_b = xp.minimum(qty, elig_b)
    below = s_cum - ask                               # supply strictly below l
    traded_a = xp.clip(fill_b - below, f32(0.0), ask)
    bid_buy = bid + onehot * (qty - fill_b)           # residual rests at p
    ask_buy = ask - traded_a
    lvl_b = xp.max(xp.where(traded_a > f32(0.0), levels, xp.int32(-1)),
                   axis=-1, keepdims=True)            # marginal (highest) level

    # Sell: sweep bids at levels >= p, highest first.
    d_cum = xp.flip(xp.cumsum(xp.flip(bid, -1), axis=-1), -1)  # suffix demand
    elig_s = xp.take_along_axis(d_cum, price, axis=-1)
    fill_s = xp.minimum(qty, elig_s)
    above = d_cum - bid                               # demand strictly above l
    traded_b = xp.clip(fill_s - above, f32(0.0), bid)
    bid_sell = bid - traded_b
    ask_sell = ask + onehot * (qty - fill_s)
    lvl_s = xp.min(xp.where(traded_b > f32(0.0), levels, xp.int32(L)),
                   axis=-1, keepdims=True)            # marginal (lowest) level

    new_bid = xp.where(side_buy, bid_buy, bid_sell)
    new_ask = xp.where(side_buy, ask_buy, ask_sell)
    fill = xp.where(side_buy, fill_b, fill_s)
    lvl = xp.where(side_buy, lvl_b, lvl_s)
    exec_price = xp.where(fill > f32(0.0), lvl.astype(f32), exec_price)
    return new_bid, new_ask, fill, exec_price


def simulate_step_sequential(
    cfg,
    state: MarketState,
    step_idx,
    market_ids,
    xp,
    uniform_fn=None,
    params: Optional[MarketParams] = None,
    atype=None,
    seed=None,
    peer_mid=None,
):
    """Advance all markets one step under sequential clearing.

    Mirrors :func:`repro.core.step.simulate_step` phase for phase — shock
    overlay, mid estimation, the *identical* ``decide`` call — and then
    replaces the call auction with the agent-ordered matching loop.
    Returns ``(MarketState, StepOutput)`` with the same shapes, so the
    session layer drives it unchanged. The step's reported price is the
    marginal level of the last executing order (the sequential analogue of
    the auction's ``p_star``), falling back to the previous last price on
    no-trade steps.
    """
    if params is None:
        params = params_mod.scalar_params(cfg, xp)
    f32 = xp.float32
    A = cfg.num_agents

    resting_bid = apply_scenario_shock(params, state.bid, step_idx, xp)
    _, _, mid = auction.best_quotes(resting_bid, state.ask,
                                    state.last_price, xp)

    sum_bid = xp.sum(resting_bid, axis=-1, keepdims=True)
    sum_ask = xp.sum(state.ask, axis=-1, keepdims=True)
    depth = sum_bid + sum_ask
    safe_depth = xp.where(depth > f32(0.0), depth, f32(1.0))
    imbalance = xp.where(depth > f32(0.0), (sum_bid - sum_ask) / safe_depth,
                         xp.zeros_like(depth))

    agent_ids = xp.arange(A, dtype=xp.int32)
    side_buy, price, qty = agents.decide(
        cfg, params, mid, state.prev_mid, step_idx, market_ids, agent_ids, xp,
        uniform_fn=uniform_fn, atype=atype, seed=seed,
        imbalance=imbalance, peer_mid=peer_mid,
    )

    bid, ask = resting_bid, state.ask
    volume = xp.zeros_like(mid)
    exec_price = xp.asarray(state.last_price, dtype=f32) + xp.zeros_like(mid)

    if xp is np:
        for a in range(A):
            bid, ask, fill, exec_price = match_order(
                bid, ask, exec_price,
                side_buy[:, a:a + 1], price[:, a:a + 1], qty[:, a:a + 1], xp)
            volume = volume + fill
    else:
        import jax

        def body(a, carry):
            bid, ask, volume, exec_price = carry
            sb = jax.lax.dynamic_slice_in_dim(side_buy, a, 1, axis=1)
            pr = jax.lax.dynamic_slice_in_dim(price, a, 1, axis=1)
            qt = jax.lax.dynamic_slice_in_dim(qty, a, 1, axis=1)
            bid, ask, fill, exec_price = match_order(
                bid, ask, exec_price, sb, pr, qt, xp)
            return bid, ask, volume + fill, exec_price

        bid, ask, volume, exec_price = jax.lax.fori_loop(
            0, A, body, (bid, ask, volume, exec_price))

    executed = volume > f32(0.0)
    new_last = xp.where(executed, exec_price, state.last_price)
    new_state = MarketState(bid=bid, ask=ask, last_price=new_last,
                            prev_mid=mid)
    out = StepOutput(price=new_last, volume=volume, mid=mid)
    return new_state, out
