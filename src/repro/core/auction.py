"""Uniform-price call-auction clearing (paper §II-A, §IV-C), xp-polymorphic.

Pure clearing math shared verbatim by every backend. The allocation rule is
the closed form of the paper's priority-based allocation (§IV-C): orders with
limits strictly better than the clearing price fill first; the marginal level
p* is rationed. Verified against the paper's analytical L=5 ground truth in
tests/test_auction.py.
"""
from __future__ import annotations


def prefix_sum(x, xp):
    """Inclusive prefix sum over the last axis (cumulative supply)."""
    return xp.cumsum(x, axis=-1, dtype=x.dtype)


def suffix_sum(x, xp):
    """Inclusive suffix sum over the last axis (cumulative demand)."""
    return xp.flip(xp.cumsum(xp.flip(x, axis=-1), axis=-1, dtype=x.dtype), axis=-1)


def hillis_steele_prefix(x, xp):
    """Θ(log L)-depth Hillis–Steele inclusive prefix scan (paper §III-D).

    Faithful transcription of the kernel's strided shared-memory scan: at each
    stride ``off`` every lane accumulates the value ``off`` lanes behind it.
    Exact-integer float adds make this bitwise-identical to ``cumsum``.
    """
    L = x.shape[-1]
    off = 1
    while off < L:
        shifted = xp.concatenate(
            [xp.zeros(x.shape[:-1] + (off,), dtype=x.dtype), x[..., :-off]],
            axis=-1,
        )
        x = x + shifted
        off *= 2
    return x


def hillis_steele_suffix(x, xp):
    """Θ(log L)-depth suffix scan (reads ``off`` lanes ahead)."""
    L = x.shape[-1]
    off = 1
    while off < L:
        shifted = xp.concatenate(
            [x[..., off:], xp.zeros(x.shape[:-1] + (off,), dtype=x.dtype)],
            axis=-1,
        )
        x = x + shifted
        off *= 2
    return x


def best_quotes(bid, ask, last_price, xp):
    """Best bid/ask and mid price (paper Eq. 3).

    Returns (bb int32[M,1], ba int32[M,1], mid float32[M,1]); bb = -1 when no
    bids, ba = L when no asks; mid falls back to last_price.
    """
    L = bid.shape[-1]
    levels = xp.arange(L, dtype=xp.int32)
    has_bid = bid > xp.float32(0.0)
    has_ask = ask > xp.float32(0.0)
    bb = xp.max(xp.where(has_bid, levels, xp.int32(-1)), axis=-1, keepdims=True)
    ba = xp.min(xp.where(has_ask, levels, xp.int32(L)), axis=-1, keepdims=True)
    ok = (bb >= xp.int32(0)) & (ba < xp.int32(L))
    mid = xp.where(
        ok,
        (bb + ba).astype(xp.float32) * xp.float32(0.5),
        xp.asarray(last_price, dtype=xp.float32),
    )
    return bb, ba, mid


def clear(total_buy, total_ask, xp, scan="cumsum"):
    """Clear one step of the uniform-price call auction.

    Args:
      total_buy / total_ask: float32[..., L] aggregate resting+incoming books.
      scan: 'cumsum' (XLA native) or 'hillis-steele' (paper-faithful log-depth
        strided scan) — bitwise-identical results for exact-integer books.

    Returns dict with p_star int32[...,1], volume float32[...,1],
    new_bid/new_ask float32[...,L], traded_buy/traded_sell float32[...,L].
    """
    f32 = xp.float32
    if scan == "hillis-steele":
        d_cum = hillis_steele_suffix(total_buy, xp)
        s_cum = hillis_steele_prefix(total_ask, xp)
    else:
        d_cum = suffix_sum(total_buy, xp)
        s_cum = prefix_sum(total_ask, xp)

    match = xp.minimum(d_cum, s_cum)  # executable volume V(p)
    # argmax returns the first (lowest-price) maximizer in both NumPy & JAX,
    # matching the paper's tournament tie-break toward lower ticks.
    p_star = xp.argmax(match, axis=-1).astype(xp.int32)[..., None]
    volume = xp.take_along_axis(match, p_star, axis=-1)

    # Priority allocation (closed form of paper §IV-C):
    #   demand strictly above p: d_cum[p] - total_buy[p]
    #   traded_buy[p] = min(total_buy[p], max(0, V - demand_above_p))
    zero = f32(0.0)
    demand_above = d_cum - total_buy
    traded_buy = xp.minimum(total_buy, xp.maximum(zero, volume - demand_above))
    supply_below = s_cum - total_ask
    traded_sell = xp.minimum(total_ask, xp.maximum(zero, volume - supply_below))

    new_bid = total_buy - traded_buy
    new_ask = total_ask - traded_sell
    return {
        "p_star": p_star,
        "volume": volume,
        "new_bid": new_bid,
        "new_ask": new_ask,
        "traded_buy": traded_buy,
        "traded_sell": traded_sell,
    }
