"""Public engine API.

The stateful front door lives in :mod:`repro.core.session`:
``Engine(backend, **backend_opts)`` caches compiled chunk executables and
``engine.open(spec) -> Session`` holds a live device-resident market
ensemble. ``spec`` is an :class:`repro.core.params.EnsembleSpec` — the
ensemble-first surface, heterogeneous per-market scenario parameters as
device operands — or a plain :class:`MarketConfig`, which coerces to a
homogeneous spec bitwise-identically. ``engine.env(spec)`` is the RL front
door (a pure-functional environment whose rollouts compile to one
``lax.scan``) and ``engine.trainer(spec, PPOConfig())`` the training one —
an on-device PPO span (:mod:`repro.train`) over that env, sharing the same
engine-wide warm-trace cache. This module keeps the historical
one-shot surface — ``simulate(cfg, backend=...)`` and
``simulate_scenario(name, backend=...)`` — as thin compatibility wrappers
over a one-session run, sharing a module-level engine cache so repeated
calls reuse warm executables.

Backends (paper §IV's five engines):
  * ``numpy``             — CPU (NumPy) reference, kinetic RNG (bitwise-comparable)
  * ``numpy-splitmix64``  — CPU reference with the paper's SplitMix64 stream
  * ``numpy-pcg64``       — CPU reference with NumPy's PCG64 (paper's literal CPU RNG)
  * ``jax-per-step``      — launch-per-step framework regime
  * ``jax-scan``          — fused lax.scan framework baseline
  * ``pallas-naive``      — per-step Pallas kernel, HBM-resident book (naive CUDA analogue)
  * ``pallas-kinetic``    — THE paper's engine: persistent, VMEM-resident clearing
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.core.config import MarketConfig, scenario_config, scenario_names
from repro.core.params import (  # noqa: F401 (re-exported API)
    EnsembleSpec,
    MarketParams,
)
from repro.core.result import SimResult
from repro.core.session import (  # noqa: F401 (re-exported API)
    Engine,
    ExternalOrders,
    Session,
    StepBatch,
    backend_available,
    register_backend,
)
from repro.core.stats import MarketStats  # noqa: F401 (re-exported API)
from repro.core import session as _session

# Warm engines shared by the compatibility wrappers, keyed by
# (backend, sorted backend_opts) — repeated simulate() calls with the same
# options reuse the same compiled executables.
_COMPAT_ENGINES: Dict[Tuple[Any, ...], Engine] = {}


def _ensure_builtin() -> None:
    _session._ensure_builtin()


def backends() -> List[str]:
    return _session.backends()


def clear_compat_cache() -> None:
    """Release the wrappers' warm engines and their compiled executables
    (for long-lived processes sweeping many distinct configurations)."""
    _COMPAT_ENGINES.clear()


def _compat_engine(backend: str, opts: Dict[str, Any]) -> Engine:
    key = (backend,) + tuple(sorted(opts.items()))
    eng = _COMPAT_ENGINES.get(key)
    if eng is None:
        eng = Engine(backend, **opts)
        _COMPAT_ENGINES[key] = eng
    return eng


def simulate(cfg, backend: str = "jax-scan",
             **kwargs: Any) -> SimResult:
    """One-shot compatibility wrapper: open a session, run ``num_steps``
    steps, return the terminal :class:`SimResult`.

    ``cfg`` may be a :class:`MarketConfig` or an :class:`EnsembleSpec`.
    Raises ``KeyError`` for unknown backends; if a backend failed to
    register (e.g. the Pallas kernels' import failed), the error carries the
    recorded reason — see :func:`backend_available`.
    """
    with _compat_engine(backend, kwargs).open(cfg) as sess:
        return sess.run_to_result(cfg.num_steps)


def scenarios() -> Tuple[str, ...]:
    """Registered scenario preset names (see repro.core.config)."""
    return scenario_names()


def simulate_scenario(name: str, backend: str = "jax-scan",
                      config_overrides: Optional[Dict[str, Any]] = None,
                      **kwargs: Any) -> SimResult:
    """Build a scenario preset config and simulate it on ``backend``."""
    cfg = scenario_config(name, **(config_overrides or {}))
    return simulate(cfg, backend=backend, **kwargs)


def open_scenario(name: str, backend: str = "jax-scan",
                  config_overrides: Optional[Dict[str, Any]] = None,
                  **kwargs: Any) -> Session:
    """Session-API scenario front door: open a warm session on a preset."""
    cfg = scenario_config(name, **(config_overrides or {}))
    return _compat_engine(backend, kwargs).open(cfg)
