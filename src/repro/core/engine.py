"""Public engine API: ``simulate(cfg, backend=...)`` with a backend registry,
plus the scenario front door ``simulate_scenario(name, backend=...)``.

Backends (paper §IV's five engines):
  * ``numpy``             — CPU (NumPy) reference, kinetic RNG (bitwise-comparable)
  * ``numpy-splitmix64``  — CPU reference with the paper's SplitMix64 stream
  * ``numpy-pcg64``       — CPU reference with NumPy's PCG64 (paper's literal CPU RNG)
  * ``jax-per-step``      — launch-per-step framework regime
  * ``jax-scan``          — fused lax.scan framework baseline
  * ``pallas-naive``      — per-step Pallas kernel, HBM-resident book (naive CUDA analogue)
  * ``pallas-kinetic``    — THE paper's engine: persistent, VMEM-resident clearing
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.config import MarketConfig, scenario_config, scenario_names
from repro.core.result import SimResult

_REGISTRY: Dict[str, Callable[..., SimResult]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def backends():
    _ensure_builtin()
    return sorted(_REGISTRY)


def _ensure_builtin():
    if "numpy" in _REGISTRY:
        return
    from repro.core import jax_backend, numpy_backend

    _REGISTRY["numpy"] = lambda cfg, **kw: numpy_backend.simulate(
        cfg, rng_mode="kinetic", **kw)
    _REGISTRY["numpy-splitmix64"] = lambda cfg, **kw: numpy_backend.simulate(
        cfg, rng_mode="splitmix64", **kw)
    _REGISTRY["numpy-pcg64"] = lambda cfg, **kw: numpy_backend.simulate(
        cfg, rng_mode="pcg64", **kw)
    _REGISTRY["jax-scan"] = lambda cfg, **kw: jax_backend.simulate(
        cfg, mode="scan", **kw)
    _REGISTRY["jax-per-step"] = lambda cfg, **kw: jax_backend.simulate(
        cfg, mode="per-step", **kw)
    try:
        from repro.kernels import ops as _kernel_ops  # registers pallas backends
    except ImportError:
        pass


def simulate(cfg: MarketConfig, backend: str = "jax-scan", **kwargs) -> SimResult:
    _ensure_builtin()
    if backend not in _REGISTRY:
        raise KeyError(f"unknown backend {backend!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[backend](cfg, **kwargs)


def scenarios():
    """Registered scenario preset names (see repro.core.config)."""
    return scenario_names()


def simulate_scenario(name: str, backend: str = "jax-scan",
                      config_overrides: Dict = None, **kwargs) -> SimResult:
    """Build a scenario preset config and simulate it on ``backend``."""
    cfg = scenario_config(name, **(config_overrides or {}))
    return simulate(cfg, backend=backend, **kwargs)
