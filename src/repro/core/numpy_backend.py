"""CPU (NumPy) reference backend — the paper's baseline 1 (§IV, §IV-E).

Drives the shared ``simulate_step`` semantics with ``np.add.at`` scatter
binning (exactly the paper's described implementation), sequential over
steps on the host — so scenario overlays and archetype dispatch can never
drift from the device engines.

Two RNG modes:
  * ``kinetic``   — the production counter RNG: bitwise-comparable to every
                    other backend (paper's bitwise-identity experiment).
  * ``splitmix64``— the paper's 64-bit generator (different stream): only
                    statistically comparable, mirroring the paper's
                    CPU-vs-CUDA <0.1% equivalence experiment.
  * ``pcg64``     — NumPy's own PRNG, the paper's literal CPU reference.
"""
from __future__ import annotations

import numpy as np

from repro.core import rng
from repro.core.config import MarketConfig
from repro.core.step import initial_state, simulate_step
from repro.core.result import SimResult


def _bin_orders_scatter(side_buy, price, qty, M, L):
    buy = np.zeros((M, L), dtype=np.float32)
    sell = np.zeros((M, L), dtype=np.float32)
    m_idx = np.broadcast_to(np.arange(M)[:, None], price.shape)
    qb = (qty * side_buy.astype(np.float32)).astype(np.float32)
    qs = (qty * (~side_buy).astype(np.float32)).astype(np.float32)
    np.add.at(buy, (m_idx, price), qb)
    np.add.at(sell, (m_idx, price), qs)
    return buy, sell


def simulate(cfg: MarketConfig, rng_mode: str = "kinetic",
             scan: str = "cumsum") -> SimResult:
    M, L, S = cfg.num_markets, cfg.num_levels, cfg.num_steps
    state = initial_state(cfg, np)
    market_ids = np.arange(M, dtype=np.int32)[:, None]

    if rng_mode == "kinetic":
        uniform_fn = None
    elif rng_mode == "splitmix64":
        def uniform_fn(gid, step, channel):
            return rng.splitmix64_uniform(cfg.seed, gid, step, channel)
    elif rng_mode == "pcg64":
        gen = np.random.Generator(np.random.PCG64(cfg.seed))

        def uniform_fn(gid, step, channel):
            return gen.random(size=gid.shape, dtype=np.float32)
    else:
        raise ValueError(f"unknown rng_mode {rng_mode!r}")

    price_path = np.zeros((M, S), dtype=np.float32)
    volume_path = np.zeros((M, S), dtype=np.float32)

    bin_orders = lambda sb, p, q: _bin_orders_scatter(sb, p, q, M, L)
    for s in range(S):
        state, out = simulate_step(
            cfg, state, np.int32(s), market_ids, np,
            bin_orders=bin_orders, scan=scan, uniform_fn=uniform_fn,
        )
        price_path[:, s] = out.price[:, 0]
        volume_path[:, s] = out.volume[:, 0]

    return SimResult(
        bid=state.bid, ask=state.ask,
        last_price=state.last_price, prev_mid=state.prev_mid,
        price_path=price_path, volume_path=volume_path,
    )
