"""CPU (NumPy) reference backend — the paper's baseline 1 (§IV, §IV-E).

Drives the shared ``simulate_step`` semantics with ``np.add.at`` scatter
binning (exactly the paper's described implementation), sequential over
steps on the host — so scenario overlays and archetype dispatch can never
drift from the device engines.

Three RNG modes:
  * ``kinetic``   — the production counter RNG: bitwise-comparable to every
                    other backend (paper's bitwise-identity experiment).
  * ``splitmix64``— the paper's 64-bit generator (different stream): only
                    statistically comparable, mirroring the paper's
                    CPU-vs-CUDA <0.1% equivalence experiment.
  * ``pcg64``     — NumPy's own PRNG, the paper's literal CPU reference.

The session entry point is :func:`open_chunk_runner`; :func:`simulate` is a
compatibility wrapper over a one-session run. Because the kinetic and
SplitMix64 streams are pure functions of the absolute step coordinate —
and the PCG64 generator persists inside the session — chunked execution is
bitwise-identical to one-shot in every mode.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

from repro.core import params as params_mod
from repro.core import rng, session
from repro.core import stats as stats_mod
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.sequential import simulate_step_sequential
from repro.core.step import MarketState, resolve_peer_mids, simulate_step
from repro.core.result import SimResult


def _bin_orders_scatter(side_buy, price, qty, M, L):
    buy = np.zeros((M, L), dtype=np.float32)
    sell = np.zeros((M, L), dtype=np.float32)
    m_idx = np.broadcast_to(np.arange(M)[:, None], price.shape)
    qb = (qty * side_buy.astype(np.float32)).astype(np.float32)
    qs = (qty * (~side_buy).astype(np.float32)).astype(np.float32)
    np.add.at(buy, (m_idx, price), qb)
    np.add.at(sell, (m_idx, price), qs)
    return buy, sell


class NumpyChunkRunner(session.ChunkRunner):
    """Host-loop chunk executor (no compilation; ``trace_count`` stays 0)."""

    xp = np

    def __init__(self, spec: EnsembleSpec, chunk: int, rng_mode: str,
                 scan: str, stats_only: bool = False,
                 clearing: str = "parallel"):
        super().__init__()
        if rng_mode not in ("kinetic", "splitmix64", "pcg64"):
            raise ValueError(f"unknown rng_mode {rng_mode!r}")
        if clearing not in ("parallel", "sequential"):
            raise ValueError(f"unknown clearing mode {clearing!r}")
        self.spec = spec
        self.chunk = int(chunk)
        self.rng_mode = rng_mode
        self.scan = scan
        self.stats_only = bool(stats_only)
        # "sequential" replaces the uniform-price call auction with the
        # order-by-order immediate-matching reference (repro.core
        # .sequential) — same decisions, different mechanism — used to
        # quantify the parallel-vs-sequential clearing gap.
        self.clearing = clearing
        # Runtime seed overrides rebuild the counter/SplitMix64 stream per
        # step; the sequential PCG64 stream is fixed at init.
        self.env_runtime_seed = rng_mode != "pcg64"
        M, L = spec.num_markets, spec.num_levels
        self._market_ids = np.arange(M, dtype=np.int32)[:, None]
        self._bin = lambda sb, p, q: _bin_orders_scatter(sb, p, q, M, L)

    def env_step_fn(self):
        """Host-loop per-step core for :class:`repro.env.MarketEnv` (not
        traceable — the env's rollout falls back to a python loop)."""
        if self.clearing == "sequential":
            return None  # reference mechanism: Session/simulate surface only
        spec = self.spec
        # The type lattice is step-invariant and EnvState threads the same
        # params object through every step of a rollout: a one-slot
        # identity-keyed memo gives the host loop the same atype hoist the
        # chunked `run` path performs (value-identical either way).
        atype_memo = []

        def step_core(market, params, t, ext_buy, ext_ask, seed, aux):
            if not (atype_memo and atype_memo[0] is params):
                atype_memo[:] = [params, params_mod.agent_types(
                    params, spec.num_agents, np)]
            new_state, out = simulate_step(
                spec, market, np.int32(t), self._market_ids, np,
                bin_orders=self._bin, scan=self.scan,
                uniform_fn=self._uniform_fn(aux, seed=seed),
                ext_buy=ext_buy, ext_ask=ext_ask, params=params, seed=seed,
                atype=atype_memo[1],
                peer_mid=resolve_peer_mids(market.prev_mid,
                                           params.coupling_peer, np),
            )
            return new_state, out, aux

        return step_core

    # ---- stateful RNG (PCG64 only) ----
    def init_aux(self, spec: EnsembleSpec) -> Optional[np.random.Generator]:
        if self.rng_mode == "pcg64":
            return np.random.Generator(np.random.PCG64(spec.seed))
        return None

    def aux_state(self, aux) -> Optional[dict]:
        return None if aux is None else aux.bit_generator.state

    def restore_aux(self, payload) -> Optional[np.random.Generator]:
        if self.rng_mode != "pcg64":
            return None
        gen = np.random.Generator(np.random.PCG64(self.spec.seed))
        gen.bit_generator.state = payload
        return gen

    def _uniform_fn(self, aux, seed=None):
        if self.rng_mode == "kinetic":
            return None  # decide() defaults to the counter stream (`seed`
            #              is forwarded separately through simulate_step)
        if self.rng_mode == "splitmix64":
            seed = self.spec.seed if seed is None else seed

            def uniform_fn(gid, step, channel):
                return rng.splitmix64_uniform(seed, gid, step, channel)
            return uniform_fn

        def uniform_fn(gid, step, channel):
            return aux.random(size=gid.shape, dtype=np.float32)
        return uniform_fn

    def run(self, state: MarketState, params: MarketParams, aux,
            step0: int, n: int, ext,
            stats=None) -> Tuple[MarketState, Any, session.StepBatch, Any]:
        spec = self.spec
        M = spec.num_markets
        uniform_fn = self._uniform_fn(aux)
        # The type lattice is step-invariant: build it once per chunk, not
        # once per step of the host loop.
        atype = params_mod.agent_types(params, spec.num_agents, np)
        # Coupling freeze: arbitrageurs see the peer's mid as of the chunk
        # boundary (same freeze points as every compiled backend).
        peer_mid = resolve_peer_mids(state.prev_mid, params.coupling_peer, np)
        width = 0 if self.stats_only else n
        pp = np.zeros((M, width), dtype=np.float32)
        vp = np.zeros((M, width), dtype=np.float32)
        mp = np.zeros((M, width), dtype=np.float32)
        for k in range(n):
            eb, ea = ext if (k == 0 and ext is not None) else (None, None)
            if self.clearing == "sequential":
                if eb is not None or ea is not None:
                    raise ValueError(
                        "sequential clearing is a reference mechanism "
                        "without external-order injection; use the "
                        "parallel-clearing backends for session stepping")
                state, out = simulate_step_sequential(
                    spec, state, np.int32(step0 + k), self._market_ids, np,
                    uniform_fn=uniform_fn, params=params, atype=atype,
                    peer_mid=peer_mid,
                )
            else:
                state, out = simulate_step(
                    spec, state, np.int32(step0 + k), self._market_ids, np,
                    bin_orders=self._bin, scan=self.scan,
                    uniform_fn=uniform_fn,
                    ext_buy=eb, ext_ask=ea, params=params, atype=atype,
                    peer_mid=peer_mid,
                )
            if self.stats_only:
                stats = stats_mod.accumulate(stats, out.mid, out.volume,
                                             True, np)
            else:
                pp[:, k] = out.price[:, 0]
                vp[:, k] = out.volume[:, 0]
                mp[:, k] = out.mid[:, 0]
        return (state, aux, session.StepBatch(price=pp, volume=vp, mid=mp),
                stats)


def open_chunk_runner(spec, chunk: int,
                      rng_mode: str = "kinetic",
                      scan: str = "cumsum",
                      stats_only: bool = False,
                      clearing: str = "parallel") -> NumpyChunkRunner:
    """Session factory for the CPU reference backend."""
    return NumpyChunkRunner(EnsembleSpec.coerce(spec), chunk,
                            rng_mode=rng_mode, scan=scan,
                            stats_only=stats_only, clearing=clearing)


def simulate(cfg, rng_mode: str = "kinetic",
             scan: str = "cumsum") -> SimResult:
    """Compatibility wrapper: one-session run over ``num_steps``."""
    spec = EnsembleSpec.coerce(cfg)
    runner = open_chunk_runner(spec,
                               min(session.DEFAULT_CHUNK, spec.num_steps),
                               rng_mode=rng_mode, scan=scan)
    return session.run_runner_to_result(runner, spec)
