"""In-stream ensemble statistics — the Θ(M) output-traffic regime.

The paper's traffic model (HBM bytes independent of step count) holds for
the *books*, but the per-step path outputs (``price_path``/``volume_path``)
still leak Θ(M·S) HBM + host traffic. ``stats_only`` mode replaces them with
per-market running aggregates accumulated *inside* the step loop — in the
persistent kernel's ``fori_loop`` for ``pallas-kinetic`` — so a session's
output traffic is Θ(M) regardless of horizon:

  * running moments of the pre-clearing mid (count, sum, sum of squares),
  * extremes of the mid (min / max), and
  * total cleared volume.

Every backend accumulates through :func:`accumulate` with the same f32 op
sequence, so the statistics inherit the engine-parity and chunk-invariance
guarantees of the paths themselves: any chunking of S steps produces the
bitwise-identical :class:`MarketStats` as one S-step call, because the
accumulators are *carried through* each chunk call rather than merged
after the fact.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np


class MarketStats(NamedTuple):
    """Per-market running aggregates; every field is float32[M, 1].

    ``count`` is an exact integer in f32 (steps accumulated so far);
    ``min_mid``/``max_mid`` start at ±inf so the first step always wins.
    """

    count: Any      # f32[M, 1] number of steps accumulated
    sum_mid: Any    # f32[M, 1] Σ mid
    sumsq_mid: Any  # f32[M, 1] Σ mid²
    min_mid: Any    # f32[M, 1]
    max_mid: Any    # f32[M, 1]
    sum_volume: Any # f32[M, 1] total cleared volume

    def to_numpy(self) -> "MarketStats":
        return MarketStats(*(np.asarray(x) for x in self))

    # ---- derived moments (host-side; f64 division for the read-out) ----
    def mean_mid(self) -> np.ndarray:
        s = self.to_numpy()
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.asarray(s.sum_mid, np.float64) / s.count

    def var_mid(self) -> np.ndarray:
        """Population variance of the mid (clamped at 0 against f32 noise)."""
        s = self.to_numpy()
        mean = self.mean_mid()
        with np.errstate(invalid="ignore", divide="ignore"):
            raw = np.asarray(s.sumsq_mid, np.float64) / s.count - mean ** 2
        return np.maximum(raw, 0.0)


def init_stats(num_markets: int, xp) -> MarketStats:
    """Fresh accumulators for ``num_markets`` markets in module ``xp``.

    Each field is a *distinct* buffer (never aliased) so runners can donate
    the whole accumulator tuple back to their chunk executable.
    """
    def zeros():
        return xp.zeros((num_markets, 1), dtype=xp.float32)

    return MarketStats(count=zeros(), sum_mid=zeros(), sumsq_mid=zeros(),
                       min_mid=zeros() + xp.float32(np.inf),
                       max_mid=zeros() - xp.float32(np.inf),
                       sum_volume=zeros())


def accumulate(stats: MarketStats, mid, volume, active, xp) -> MarketStats:
    """One masked, branch-free accumulation step (shared by all backends).

    ``active`` is a boolean (scalar or broadcastable) gating the update —
    inactive steps (the padded tail of a partial chunk) leave every
    accumulator bitwise untouched, mirroring the gated state carry.
    """
    f32 = xp.float32
    act = xp.asarray(active)
    one = xp.where(act, f32(1.0), f32(0.0))
    mid = xp.asarray(mid, dtype=xp.float32)
    vol = xp.asarray(volume, dtype=xp.float32)
    return MarketStats(
        count=stats.count + one,
        sum_mid=stats.sum_mid + xp.where(act, mid, f32(0.0)),
        sumsq_mid=stats.sumsq_mid + xp.where(act, mid * mid, f32(0.0)),
        min_mid=xp.where(act, xp.minimum(stats.min_mid, mid), stats.min_mid),
        max_mid=xp.where(act, xp.maximum(stats.max_mid, mid), stats.max_mid),
        sum_volume=stats.sum_volume + xp.where(act, vol, f32(0.0)),
    )
