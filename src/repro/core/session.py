"""Stateful session API: open/step/close engine lifecycle with compile-once
reuse, chunked streaming, and an RL stepping hook.

The paper's headline regime — 22.1µs warm per-step latency, HBM traffic
independent of step count — is about *persistent state across step
boundaries*. This module is the front door to that regime:

    eng = Engine("pallas-kinetic")
    with eng.open(cfg) as sess:           # device-resident MarketState
        for batch in sess.stream(10_000): # chunked StepBatch slices
            consume(batch)
        obs = sess.step(actions)          # gym-style RL hook

Design:

  * :class:`Engine` caches compiled chunk executables per (config-semantics,
    chunk-length) key, shared by every session it opens — opening a second
    session with the same shape triggers **zero** retraces.
  * Each backend supplies a :class:`ChunkRunner`: a fixed ``chunk``-length
    compiled entry taking runtime ``(step0, n_valid)`` scalars, so one trace
    serves any requested step count; partial tails are gated branch-free.
  * State buffers are **donated** back to the executable on every chunk
    (``jax.jit(..., donate_argnums=(0,))``), so a warm session updates its
    books in place with no per-call re-init.
  * Chunked execution is bitwise-identical to one-shot: the RNG is a pure
    function of the absolute step coordinate and the scenario overlay keys
    on the absolute step, so chunk boundaries are invisible to the stream.
  * :meth:`Session.step` injects external orders through a reserved slot in
    the incoming flow (``simulate_step``'s ``ext_buy``/``ext_ask``) — the
    gym-style hook for future RL workloads; ``actions=None`` is a bitwise
    no-op relative to :meth:`Session.run`.
  * :meth:`Session.snapshot` / :meth:`Session.restore` round-trip the full
    session state (books, step cursor, stateful RNG, and any ``stats_only``
    accumulators) exactly, and wire into
    :class:`repro.checkpoint.manager.CheckpointManager` via
    :meth:`Session.save_checkpoint` / :meth:`Session.restore_checkpoint`.
  * Sessions are device-layout transparent: a runner may shard the market
    axis over a ``("markets",)`` mesh (``Engine(backend, devices=N)``) and
    every advancement/snapshot API behaves identically — bitwise — to the
    single-device session. In ``stats_only`` mode the per-step paths are
    replaced by carried per-market aggregates (:attr:`Session.stats`),
    making session output traffic Θ(M) independent of horizon.

``engine.simulate()`` / ``engine.simulate_scenario()`` remain as thin
compatibility wrappers over a one-session run.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Tuple, Union

import dataclasses

import numpy as np

from repro.core.config import MarketConfig
from repro.core.result import SimResult
from repro.core.stats import MarketStats, init_stats
from repro.core.step import MarketState, initial_state

#: Default compiled chunk length (steps per device call) for streaming runs.
DEFAULT_CHUNK = 64

# backend name -> factory(cfg, chunk, **backend_opts) -> ChunkRunner
_FACTORIES: Dict[str, Callable[..., "ChunkRunner"]] = {}
# backend name -> reason string for backends whose registration failed
_FAILED: Dict[str, str] = {}


class StepBatch(NamedTuple):
    """A contiguous slice of per-step outputs streamed from a session."""

    price: Any   # float32[M, n] clearing price (last price when no cross)
    volume: Any  # float32[M, n] transacted volume
    mid: Any     # float32[M, n] pre-clearing mid used for agent decisions

    @property
    def num_steps(self) -> int:
        return int(self.price.shape[-1])

    def to_numpy(self) -> "StepBatch":
        return StepBatch(*(np.asarray(x) for x in self))

    @staticmethod
    def concatenate(batches: "list[StepBatch]", xp=np) -> "StepBatch":
        if len(batches) == 1:
            return batches[0]
        return StepBatch(*(xp.concatenate(parts, axis=-1)
                           for parts in zip(*batches)))


class ExternalOrders(NamedTuple):
    """One external limit order per market for :meth:`Session.step`.

    Each field is broadcastable to ``[M]``: ``side_buy`` bool, ``price``
    int tick index (clipped to the grid), ``qty`` float lots.
    """

    side_buy: Any
    price: Any
    qty: Any


class ChunkRunner:
    """Backend adapter: a compiled (or host-loop) fixed-chunk executor.

    Subclasses set ``chunk`` and ``xp`` and implement :meth:`run`; stateful
    RNG backends additionally override the ``aux`` hooks. A runner is
    immutable and shared by every session opened with the same semantics —
    all per-session mutable state lives in :class:`Session`.
    """

    chunk: int = 1
    xp: Any = np
    #: Runners opened with ``stats_only=True`` replace per-step path outputs
    #: with carried :class:`repro.core.stats.MarketStats` accumulators.
    stats_only: bool = False

    def __init__(self) -> None:
        self._trace_count = 0

    @property
    def trace_count(self) -> int:
        """Times the underlying executable was (re)traced; 0 for host loops."""
        return self._trace_count

    def init_state(self, cfg: MarketConfig) -> MarketState:
        return initial_state(cfg, self.xp)

    def to_device(self, state: MarketState) -> MarketState:
        return MarketState(*(self.xp.asarray(np.asarray(x), dtype=self.xp.float32)
                             for x in state))

    # ---- stats_only accumulators (None unless the runner enables them) ----
    def init_stats(self, cfg: MarketConfig) -> Optional[MarketStats]:
        if not self.stats_only:
            return None
        return init_stats(cfg.num_markets, self.xp)

    def stats_to_device(self, stats: MarketStats) -> MarketStats:
        return MarketStats(*(self.xp.asarray(np.asarray(x),
                                             dtype=self.xp.float32)
                             for x in stats))

    # ---- stateful-RNG hooks (identity for counter-based backends) ----
    def init_aux(self, cfg: MarketConfig) -> Any:
        return None

    def aux_state(self, aux: Any) -> Any:
        """JSON-serializable payload capturing ``aux``, or None."""
        return None

    def restore_aux(self, payload: Any) -> Any:
        return None

    def run(self, state: MarketState, aux: Any, step0: int, n: int,
            ext: Optional[Tuple[Any, Any]],
            stats: Optional[MarketStats] = None,
            ) -> Tuple[MarketState, Any, StepBatch, Optional[MarketStats]]:
        """Advance ``n <= self.chunk`` steps from absolute step ``step0``.

        ``ext`` is an optional ``(ext_buy, ext_ask)`` float32[M, L] pair
        injected at the first step of the chunk. Returns the new state, new
        aux, a :class:`StepBatch` whose paths have exactly ``n`` columns,
        and the updated stats accumulators. In ``stats_only`` mode the
        carried ``stats`` must be threaded through every call (the batch
        comes back with zero-width paths); otherwise ``stats`` is ignored
        and returned as ``None``.
        """
        raise NotImplementedError


def register_backend(name: str):
    """Register a session factory ``f(cfg, chunk, **opts) -> ChunkRunner``."""
    def deco(fn):
        _FACTORIES[name] = fn
        _FAILED.pop(name, None)
        return fn
    return deco


def _ensure_builtin() -> None:
    if "numpy" in _FACTORIES:
        return
    from repro.core import jax_backend, numpy_backend  # noqa: F401 (register)

    for mode in ("kinetic", "splitmix64", "pcg64"):
        name = "numpy" if mode == "kinetic" else f"numpy-{mode}"
        _FACTORIES[name] = _numpy_factory(mode)
    _FACTORIES["jax-scan"] = _jax_factory("scan")
    _FACTORIES["jax-per-step"] = _jax_factory("per-step")
    try:
        from repro.kernels import ops as _kernel_ops  # noqa: F401 (register)
    except ImportError as exc:
        # Record the reason instead of swallowing it: surfaced by
        # backend_available() and by Engine/simulate KeyErrors.
        reason = f"{type(exc).__name__}: {exc}"
        for name in ("pallas-naive", "pallas-kinetic"):
            _FAILED.setdefault(name, reason)


def _numpy_factory(rng_mode: str):
    def factory(cfg, chunk, **opts):
        from repro.core import numpy_backend

        return numpy_backend.open_chunk_runner(cfg, chunk, rng_mode=rng_mode,
                                               **opts)
    return factory


def _jax_factory(mode: str):
    def factory(cfg, chunk, **opts):
        from repro.core import jax_backend

        return jax_backend.open_chunk_runner(cfg, chunk, mode=mode, **opts)
    return factory


def backends() -> "list[str]":
    _ensure_builtin()
    return sorted(_FACTORIES)


def backend_available(name: str) -> Union[bool, str]:
    """True if ``name`` is registered, the recorded failure-reason string if
    its registration failed (e.g. a Pallas ImportError), False if unknown."""
    _ensure_builtin()
    if name in _FACTORIES:
        return True
    if name in _FAILED:
        return _FAILED[name]
    return False


def _unknown_backend_error(name: str) -> KeyError:
    if name in _FAILED:
        return KeyError(
            f"backend {name!r} failed to register: {_FAILED[name]}")
    return KeyError(f"unknown backend {name!r}; have {sorted(_FACTORIES)}")


def _semantic_key(cfg: MarketConfig) -> Tuple[Any, ...]:
    """Executable cache key: every config field except ``num_steps``.

    ``num_steps`` never enters the per-step semantics — chunk runners are
    parametrized by their static chunk length instead — so configs differing
    only in total step count share one compiled executable.
    """
    return tuple(getattr(cfg, f.name) for f in dataclasses.fields(cfg)
                 if f.name != "num_steps")


def run_runner_to_result(runner: ChunkRunner, cfg: MarketConfig) -> SimResult:
    """One-session run over ``cfg.num_steps`` on a bare runner — the shared
    body of every backend's ``simulate()`` compatibility wrapper."""
    if runner.stats_only:
        # A SimResult has nowhere to carry the accumulators — returning
        # zero-width paths would silently lose every output.
        raise ValueError(
            "stats_only is a Session-API mode: open a session and read "
            "Session.stats instead of using the one-shot simulate() wrappers")
    state = runner.init_state(cfg)
    aux = runner.init_aux(cfg)
    stats = runner.init_stats(cfg)
    batches, t = [], 0
    while t < cfg.num_steps:
        n = min(runner.chunk, cfg.num_steps - t)
        state, aux, batch, stats = runner.run(state, aux, t, n, None, stats)
        batches.append(batch)
        t += n
    if batches:
        batch = StepBatch.concatenate(batches, xp=runner.xp)
    else:
        empty = runner.xp.zeros((cfg.num_markets, 0), runner.xp.float32)
        batch = StepBatch(empty, empty, empty)
    return SimResult(bid=state.bid, ask=state.ask,
                     last_price=state.last_price, prev_mid=state.prev_mid,
                     price_path=batch.price, volume_path=batch.volume)


class Engine:
    """Compiled-executable cache + session factory for one backend.

    ``backend_opts`` are backend-specific knobs (``scan=``, ``mb=``,
    ``interpret=``, ``binning=``, and for the Pallas engines the scaling
    knobs ``devices=``/``mesh=`` market-axis sharding, ``stats_only=``
    in-kernel statistics, ``autotune=``/``agent_chunk=`` tile selection —
    see ``repro.kernels.ops``) folded into every runner this engine
    builds. Executables are cached per (config-semantics, chunk-length) and
    shared across sessions: re-opening the same shape never recompiles.
    ``cfg.num_steps`` itself is not part of the key, but it does cap the
    *default* chunk length at ``min(DEFAULT_CHUNK, num_steps)`` — pass an
    explicit ``chunk_size`` to share one executable across configs whose
    ``num_steps`` differ below ``DEFAULT_CHUNK``.
    """

    def __init__(self, backend: str = "jax-scan", *,
                 chunk_size: Optional[int] = None, **backend_opts: Any):
        _ensure_builtin()
        if backend not in _FACTORIES:
            raise _unknown_backend_error(backend)
        self.backend = backend
        self.chunk_size = chunk_size
        self.backend_opts = dict(backend_opts)
        self._runners: Dict[Tuple[Any, ...], ChunkRunner] = {}

    @property
    def trace_count(self) -> int:
        """Total traces across all cached executables (retrace detector)."""
        return sum(r.trace_count for r in self._runners.values())

    def clear_cache(self) -> None:
        """Drop all cached executables (long-lived config-sweep processes)."""
        self._runners.clear()

    def _runner(self, cfg: MarketConfig, chunk: int) -> ChunkRunner:
        key = _semantic_key(cfg) + (chunk,)
        runner = self._runners.get(key)
        if runner is None:
            runner = _FACTORIES[self.backend](cfg, chunk, **self.backend_opts)
            self._runners[key] = runner
        return runner

    def open(self, cfg: MarketConfig, *,
             chunk_size: Optional[int] = None) -> "Session":
        """Open a live session holding a device-resident :class:`MarketState`."""
        chunk = chunk_size or self.chunk_size \
            or min(DEFAULT_CHUNK, cfg.num_steps)
        return Session(self, cfg, self._runner(cfg, max(1, chunk)))


class Session:
    """A live simulation: device-resident books + an absolute step cursor.

    Obtained from :meth:`Engine.open`; usable as a context manager. All
    advancement APIs (:meth:`run`, :meth:`stream`, :meth:`step`) move the
    same cursor, so they interleave freely with bitwise-reproducible
    results — any chunking of S steps equals one ``run(S)`` call.
    """

    def __init__(self, engine: Engine, cfg: MarketConfig, runner: ChunkRunner):
        self._engine = engine
        self.cfg = cfg
        self._runner = runner
        self._step_runner: Optional[ChunkRunner] = None
        self._state = runner.init_state(cfg)
        self._aux = runner.init_aux(cfg)
        self._stats = runner.init_stats(cfg)
        self._t = 0
        self._closed = False

    # ---- lifecycle ----
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the device-resident state (the executables stay cached)."""
        self._state = None
        self._aux = None
        self._stats = None
        self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("session is closed")

    # ---- introspection ----
    @property
    def state(self) -> MarketState:
        """Current device-resident state. Do not hold across :meth:`run`:
        the buffers are donated to the next chunk call."""
        self._check_open()
        return self._state

    @property
    def step_count(self) -> int:
        """Absolute number of steps advanced since open/restore."""
        return self._t

    @property
    def stats(self) -> Optional[MarketStats]:
        """Running per-market statistics (``stats_only`` sessions; else None).

        The accumulators are device-resident and carried through every chunk
        call — reading them here materializes a host copy. Use
        ``stats.mean_mid()`` / ``stats.var_mid()`` for the derived moments.
        """
        self._check_open()
        if self._stats is None:
            return None
        return self._stats.to_numpy()

    # ---- advancement ----
    def stream(self, n_steps: Optional[int] = None) -> Iterator[StepBatch]:
        """Advance ``n_steps`` (default ``cfg.num_steps``), yielding one
        :class:`StepBatch` per compiled chunk as it completes."""
        self._check_open()
        remaining = self.cfg.num_steps if n_steps is None else int(n_steps)
        while remaining > 0:
            n = min(self._runner.chunk, remaining)
            self._state, self._aux, batch, self._stats = self._runner.run(
                self._state, self._aux, self._t, n, None, self._stats)
            self._t += n
            remaining -= n
            yield batch

    def run(self, n_steps: Optional[int] = None) -> StepBatch:
        """Advance ``n_steps`` (default ``cfg.num_steps``) and return the
        concatenated :class:`StepBatch` for exactly those steps."""
        self._check_open()
        n = self.cfg.num_steps if n_steps is None else int(n_steps)
        batches = list(self.stream(n))
        if not batches:
            M = self.cfg.num_markets
            empty = self._runner.xp.zeros((M, 0), self._runner.xp.float32)
            return StepBatch(empty, empty, empty)
        return StepBatch.concatenate(batches, xp=self._runner.xp)

    def step(self, actions: Optional[Any] = None) -> StepBatch:
        """Gym-style hook: advance exactly one step, optionally injecting
        external orders through the reserved slot.

        ``actions`` is an :class:`ExternalOrders` (or a ``(side_buy, price,
        qty)`` triple / mapping with those keys), one order per market;
        ``None`` advances the market untouched — bitwise-identical to a
        one-step :meth:`run`. Uses a dedicated single-step executable (shared
        through the engine cache) so warm per-step latency has no chunk
        overhead. Returns the one-column :class:`StepBatch` observation.
        """
        self._check_open()
        if self._step_runner is None:
            self._step_runner = self._engine._runner(self.cfg, 1)
        ext = self._build_ext(actions)
        self._state, self._aux, batch, self._stats = self._step_runner.run(
            self._state, self._aux, self._t, 1, ext, self._stats)
        self._t += 1
        return batch

    def _build_ext(self, actions: Any) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if actions is None:
            return None
        if isinstance(actions, dict):
            actions = ExternalOrders(actions["side_buy"], actions["price"],
                                     actions["qty"])
        side_buy, price, qty = actions
        M, L = self.cfg.num_markets, self.cfg.num_levels
        side = np.broadcast_to(np.asarray(side_buy, dtype=bool).reshape(-1),
                               (M,))
        tick = np.clip(
            np.broadcast_to(np.asarray(price, dtype=np.int64).reshape(-1), (M,)),
            0, L - 1)
        lots = np.broadcast_to(
            np.asarray(qty, dtype=np.float32).reshape(-1), (M,))
        ext_buy = np.zeros((M, L), dtype=np.float32)
        ext_ask = np.zeros((M, L), dtype=np.float32)
        rows = np.arange(M)
        ext_buy[rows, tick] = np.where(side, lots, np.float32(0.0))
        ext_ask[rows, tick] = np.where(side, np.float32(0.0), lots)
        return ext_buy, ext_ask

    # ---- results ----
    def to_result(self, batch: StepBatch) -> SimResult:
        """Assemble a terminal :class:`SimResult` from the final books plus a
        streamed batch — the one-shot ``simulate()`` compatibility shape."""
        self._check_open()
        if self._runner.stats_only:
            # A SimResult has nowhere to carry the accumulators — returning
            # zero-width paths would silently lose every output.
            raise ValueError(
                "stats_only sessions have no path outputs: read "
                "Session.stats instead of the one-shot SimResult shape")
        s = self._state
        return SimResult(bid=s.bid, ask=s.ask, last_price=s.last_price,
                         prev_mid=s.prev_mid, price_path=batch.price,
                         volume_path=batch.volume)

    def run_to_result(self, n_steps: Optional[int] = None) -> SimResult:
        return self.to_result(self.run(n_steps))

    # ---- snapshot / restore ----
    def snapshot(self) -> Dict[str, Any]:
        """Exact host-side capture: books, step cursor, stateful RNG."""
        self._check_open()
        snap: Dict[str, Any] = {
            field: np.asarray(value)
            for field, value in zip(MarketState._fields, self._state)
        }
        snap["t"] = self._t
        snap["rng"] = self._runner.aux_state(self._aux)
        if self._stats is not None:
            snap["stats"] = {
                field: np.asarray(value)
                for field, value in zip(MarketStats._fields, self._stats)
            }
        return snap

    def restore(self, snap: Dict[str, Any]) -> None:
        """Restore from :meth:`snapshot` — resumes the exact stream.

        Snapshots are device-layout agnostic: a snapshot taken on a
        single-device session restores into a sharded one (and vice versa)
        bitwise, because the runner re-places state/stats on restore.
        """
        self._check_open()
        self._state = self._runner.to_device(
            MarketState(*(snap[f] for f in MarketState._fields)))
        self._t = int(snap["t"])
        rng = snap.get("rng")
        self._aux = (self._runner.restore_aux(rng) if rng is not None
                     else self._runner.init_aux(self.cfg)
                     if self._aux is not None else None)
        if self._runner.stats_only:
            stats = snap.get("stats")
            self._stats = (self._runner.stats_to_device(
                MarketStats(*(stats[f] for f in MarketStats._fields)))
                if stats is not None else self._runner.init_stats(self.cfg))

    def save_checkpoint(self, manager, step: Optional[int] = None) -> int:
        """Persist the session through a ``CheckpointManager``; returns the
        checkpoint step (defaults to the session's step cursor)."""
        from repro.checkpoint import manager as ckpt

        step = self._t if step is None else int(step)
        manager.save(step, ckpt.session_tree(self.snapshot()))
        manager.wait()
        return step

    def restore_checkpoint(self, manager, step: Optional[int] = None) -> int:
        """Restore from a ``CheckpointManager``; returns the restored step."""
        from repro.checkpoint import manager as ckpt

        tree = manager.restore(step)
        if tree is None:
            raise FileNotFoundError(
                f"no checkpoint found in {manager.dir}")
        self.restore(ckpt.snapshot_from_tree(tree))
        return self._t
