"""Stateful session API: open/step/close engine lifecycle with compile-once
reuse, chunked streaming, and an RL stepping hook.

The paper's headline regime — 22.1µs warm per-step latency, HBM traffic
independent of step count — is about *persistent state across step
boundaries*. This module is the front door to that regime:

    eng = Engine("pallas-kinetic")
    with eng.open(spec) as sess:          # device-resident MarketState
        for batch in sess.stream(10_000): # chunked StepBatch slices
            consume(batch)
        obs = sess.step(actions)          # gym-style RL hook

Design:

  * :class:`Engine` opens sessions on an :class:`repro.core.params
    .EnsembleSpec` — a heterogeneous per-market parameter ensemble — or on
    a plain :class:`MarketConfig`, which coerces to a homogeneous spec
    bitwise-identically. Compiled chunk executables are cached per
    (static-shape, chunk-length) key — ``EnsembleSpec.static_key()``:
    ``(M, A, L, seed)`` — so *any* scenario mixture, and any change of
    parameter values, reuses one warm trace. Opening a second session with
    the same shape triggers **zero** retraces.
  * Each backend supplies a :class:`ChunkRunner`: a fixed ``chunk``-length
    compiled entry taking runtime ``(step0, n_valid)`` scalars plus the
    per-market :class:`MarketParams` operands, so one trace serves any
    requested step count *and* any parameter values; partial tails are
    gated branch-free.
  * State buffers are **donated** back to the executable on every chunk
    (``jax.jit(..., donate_argnums=(0,))``); the params operands are *not*
    donated — they persist device-resident across the session's life.
  * Chunked execution is bitwise-identical to one-shot: the RNG is a pure
    function of the absolute step coordinate and the scenario overlay keys
    on the absolute step, so chunk boundaries are invisible to the stream.
  * :meth:`Session.step` injects external orders through a reserved slot in
    the incoming flow (``simulate_step``'s ``ext_buy``/``ext_ask``) — the
    gym-style hook for future RL workloads; ``actions=None`` is a bitwise
    no-op relative to :meth:`Session.run`.
  * :meth:`Session.snapshot` / :meth:`Session.restore` round-trip the full
    session state (books, step cursor, stateful RNG, the per-market
    parameter operands, and any ``stats_only`` accumulators) exactly, and
    wire into :class:`repro.checkpoint.manager.CheckpointManager` via
    :meth:`Session.save_checkpoint` / :meth:`Session.restore_checkpoint`.
  * Sessions are device-layout transparent: a runner may shard the market
    axis over a ``("markets",)`` mesh (``Engine(backend, devices=N)``) and
    every advancement/snapshot API behaves identically — bitwise — to the
    single-device session; heterogeneous params shard row-wise with the
    books. In ``stats_only`` mode the per-step paths are replaced by
    carried per-market aggregates (:attr:`Session.stats`), making session
    output traffic Θ(M) independent of horizon.

Horizon semantics: ``num_steps`` is the session **horizon** — the default
length of :meth:`Session.run` / :meth:`Session.stream` and the bound every
scenario event is validated against (``shock_step < num_steps``). Advancing
*past* the horizon with an explicit ``n_steps`` is permitted (the RNG and
overlays key on the absolute step, so post-horizon steps are well defined;
a shock that already fired never re-fires), but the default-length form
``run()``/``stream()`` raises once the cursor has reached the horizon —
running "the configured scenario" from there could never fire any of its
events, which previously failed silently.

``engine.simulate()`` / ``engine.simulate_scenario()`` remain as thin
compatibility wrappers over a one-session run.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.core import params as params_mod
from repro.core.config import MarketConfig
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.result import SimResult
from repro.core.stats import MarketStats, init_stats
from repro.core.step import MarketState, initial_state

#: Default compiled chunk length (steps per device call) for streaming runs.
DEFAULT_CHUNK = 64

# backend name -> factory(spec, chunk, **backend_opts) -> ChunkRunner
_FACTORIES: Dict[str, Callable[..., "ChunkRunner"]] = {}
# backend name -> reason string for backends whose registration failed
_FAILED: Dict[str, str] = {}


class StepBatch(NamedTuple):
    """A contiguous slice of per-step outputs streamed from a session."""

    price: Any   # float32[M, n] clearing price (last price when no cross)
    volume: Any  # float32[M, n] transacted volume
    mid: Any     # float32[M, n] pre-clearing mid used for agent decisions

    @property
    def num_steps(self) -> int:
        return int(self.price.shape[-1])

    def to_numpy(self) -> "StepBatch":
        return StepBatch(*(np.asarray(x) for x in self))

    @staticmethod
    def concatenate(batches: "list[StepBatch]", xp=np) -> "StepBatch":
        if len(batches) == 1:
            return batches[0]
        return StepBatch(*(xp.concatenate(parts, axis=-1)
                           for parts in zip(*batches)))


class ExternalOrders(NamedTuple):
    """One external limit order per market for :meth:`Session.step` and
    :meth:`repro.env.MarketEnv.step`.

    Each field is broadcastable to ``[M]``: ``side_buy`` bool, ``price``
    int tick index on the grid ``[0, L)``, ``qty`` float lots ``>= 0``
    (``qty == 0`` is a bitwise no-op order). Shapes/dtypes — and values,
    when concrete — are validated eagerly with a clear ``ValueError``
    (see :func:`repro.env.actions.validate_actions`) instead of a deep
    backend trace error.
    """

    side_buy: Any
    price: Any
    qty: Any


class ChunkRunner:
    """Backend adapter: a compiled (or host-loop) fixed-chunk executor.

    Subclasses set ``chunk`` and ``xp`` and implement :meth:`run`; stateful
    RNG backends additionally override the ``aux`` hooks. A runner is
    immutable and shared by every session opened with the same *static
    shape* — all per-session mutable state, including the per-market
    :class:`MarketParams` operands, lives in :class:`Session`.
    """

    chunk: int = 1
    xp: Any = np
    #: True when :meth:`run` dispatches a compiled executable (jax/pallas) —
    #: i.e. there is something for ``Engine.warm`` to precompile; host-loop
    #: runners leave this False and are always "warm".
    compiled: bool = False
    #: Runners opened with ``stats_only=True`` replace per-step path outputs
    #: with carried :class:`repro.core.stats.MarketStats` accumulators.
    stats_only: bool = False
    #: True when :meth:`env_step_fn` returns a jax-traceable pure function
    #: (embeddable in the RL env's jit/vmap/lax.scan rollouts).
    env_traceable: bool = False
    #: True when the step core accepts a *runtime* RNG seed override (the
    #: env's vmap-over-seeds operand); False when the seed is baked into
    #: the compiled trace (Pallas kernels) or a stateful stream (PCG64).
    env_runtime_seed: bool = False

    def __init__(self) -> None:
        self._trace_count = 0

    @property
    def trace_count(self) -> int:
        """Times the underlying executable was (re)traced; 0 for host loops."""
        return self._trace_count

    def init_state(self, spec: EnsembleSpec) -> MarketState:
        return initial_state(spec, self.xp)

    def to_device(self, state: MarketState) -> MarketState:
        return MarketState(*(self.xp.asarray(np.asarray(x), dtype=self.xp.float32)
                             for x in state))

    def params_to_device(self, params: MarketParams) -> MarketParams:
        """Place the per-market parameter operands (dtype-preserving)."""
        return params.asarray(self.xp)

    # ---- stats_only accumulators (None unless the runner enables them) ----
    def init_stats(self, spec: EnsembleSpec) -> Optional[MarketStats]:
        if not self.stats_only:
            return None
        return init_stats(spec.num_markets, self.xp)

    def stats_to_device(self, stats: MarketStats) -> MarketStats:
        return MarketStats(*(self.xp.asarray(np.asarray(x),
                                             dtype=self.xp.float32)
                             for x in stats))

    # ---- functional env core (repro.env) ----
    def env_step_fn(self) -> Optional[Callable]:
        """Pure per-step core for :class:`repro.env.MarketEnv`, or ``None``.

        The returned callable has the uniform signature

            ``fn(market: MarketState, params: MarketParams, t, ext_buy,
            ext_ask, seed, aux) -> (MarketState, StepOutput, aux)``

        where ``t`` is the absolute step (scalar, traced ok), ``ext_buy`` /
        ``ext_ask`` are float32[M, L] injected order quantities, ``seed`` is
        an optional runtime RNG override (``None`` for the trace-static
        seed) and ``aux`` is the stateful-RNG payload threaded through
        unchanged by counter-RNG backends. It is the *same* ``simulate_step``
        entry the chunked Session path compiles, so the two APIs cannot
        drift; traceable backends (``env_traceable``) return a function that
        embeds in jit/vmap/``lax.scan`` with no host transfer per step.
        """
        return None

    # ---- stateful-RNG hooks (identity for counter-based backends) ----
    def init_aux(self, spec: EnsembleSpec) -> Any:
        return None

    def aux_state(self, aux: Any) -> Any:
        """JSON-serializable payload capturing ``aux``, or None."""
        return None

    def restore_aux(self, payload: Any) -> Any:
        return None

    def run(self, state: MarketState, params: MarketParams, aux: Any,
            step0: int, n: int, ext: Optional[Tuple[Any, Any]],
            stats: Optional[MarketStats] = None,
            ) -> Tuple[MarketState, Any, StepBatch, Optional[MarketStats]]:
        """Advance ``n <= self.chunk`` steps from absolute step ``step0``.

        ``params`` carries the session's per-market scenario operands
        (placed via :meth:`params_to_device`; never donated). ``ext`` is an
        optional ``(ext_buy, ext_ask)`` float32[M, L] pair injected at the
        first step of the chunk. Returns the new state, new aux, a
        :class:`StepBatch` whose paths have exactly ``n`` columns, and the
        updated stats accumulators. In ``stats_only`` mode the carried
        ``stats`` must be threaded through every call (the batch comes back
        with zero-width paths); otherwise ``stats`` is ignored and returned
        as ``None``.
        """
        raise NotImplementedError


def register_backend(name: str):
    """Register a session factory ``f(spec, chunk, **opts) -> ChunkRunner``."""
    def deco(fn):
        _FACTORIES[name] = fn
        _FAILED.pop(name, None)
        return fn
    return deco


def _ensure_builtin() -> None:
    if "numpy" in _FACTORIES:
        return
    from repro.core import jax_backend, numpy_backend  # noqa: F401 (register)

    for mode in ("kinetic", "splitmix64", "pcg64"):
        name = "numpy" if mode == "kinetic" else f"numpy-{mode}"
        _FACTORIES[name] = _numpy_factory(mode)
    _FACTORIES["jax-scan"] = _jax_factory("scan")
    _FACTORIES["jax-per-step"] = _jax_factory("per-step")
    try:
        from repro.kernels import ops as _kernel_ops  # noqa: F401 (register)
    except ImportError as exc:
        # Record the reason instead of swallowing it: surfaced by
        # backend_available() and by Engine/simulate KeyErrors.
        reason = f"{type(exc).__name__}: {exc}"
        for name in ("pallas-naive", "pallas-kinetic"):
            _FAILED.setdefault(name, reason)


def _numpy_factory(rng_mode: str):
    def factory(spec, chunk, **opts):
        from repro.core import numpy_backend

        return numpy_backend.open_chunk_runner(spec, chunk, rng_mode=rng_mode,
                                               **opts)
    return factory


def _jax_factory(mode: str):
    def factory(spec, chunk, **opts):
        from repro.core import jax_backend

        return jax_backend.open_chunk_runner(spec, chunk, mode=mode, **opts)
    return factory


def backends() -> "list[str]":
    _ensure_builtin()
    return sorted(_FACTORIES)


def backend_available(name: str) -> Union[bool, str]:
    """True if ``name`` is registered, the recorded failure-reason string if
    its registration failed (e.g. a Pallas ImportError), False if unknown."""
    _ensure_builtin()
    if name in _FACTORIES:
        return True
    if name in _FAILED:
        return _FAILED[name]
    return False


def _unknown_backend_error(name: str) -> KeyError:
    if name in _FAILED:
        return KeyError(
            f"backend {name!r} failed to register: {_FAILED[name]}")
    return KeyError(f"unknown backend {name!r}; have {sorted(_FACTORIES)}")


def run_runner_to_result(runner: ChunkRunner, spec) -> SimResult:
    """One-session run over ``spec.num_steps`` on a bare runner — the shared
    body of every backend's ``simulate()`` compatibility wrapper."""
    if runner.stats_only:
        # A SimResult has nowhere to carry the accumulators — returning
        # zero-width paths would silently lose every output.
        raise ValueError(
            "stats_only is a Session-API mode: open a session and read "
            "Session.stats instead of using the one-shot simulate() wrappers")
    spec = EnsembleSpec.coerce(spec)
    state = runner.init_state(spec)
    params = runner.params_to_device(spec.params)
    aux = runner.init_aux(spec)
    stats = runner.init_stats(spec)
    batches, t = [], 0
    while t < spec.num_steps:
        n = min(runner.chunk, spec.num_steps - t)
        state, aux, batch, stats = runner.run(state, params, aux, t, n, None,
                                              stats)
        batches.append(batch)
        t += n
    if batches:
        batch = StepBatch.concatenate(batches, xp=runner.xp)
    else:
        empty = runner.xp.zeros((spec.num_markets, 0), runner.xp.float32)
        batch = StepBatch(empty, empty, empty)
    return SimResult(bid=state.bid, ask=state.ask,
                     last_price=state.last_price, prev_mid=state.prev_mid,
                     price_path=batch.price, volume_path=batch.volume)


class Engine:
    """Compiled-executable cache + session factory for one backend.

    ``backend_opts`` are backend-specific knobs (``scan=``, ``mb=``,
    ``interpret=``, ``binning=``, and for the Pallas engines the scaling
    knobs ``devices=``/``mesh=`` market-axis sharding, ``stats_only=``
    in-kernel statistics, ``autotune=``/``agent_chunk=`` tile selection —
    see ``repro.kernels.ops``) folded into every runner this engine
    builds. Executables are cached per (static-shape, chunk-length) —
    :meth:`EnsembleSpec.static_key` + chunk — and shared across sessions:
    re-opening the same shape never recompiles, *whatever* the scenario
    parameter values, because every value-like field rides in the
    :class:`MarketParams` operands rather than the trace.
    ``num_steps`` itself is not part of the key, but it does cap the
    *default* chunk length at ``min(DEFAULT_CHUNK, num_steps)`` — pass an
    explicit ``chunk_size`` to share one executable across specs whose
    ``num_steps`` differ below ``DEFAULT_CHUNK``.
    """

    def __init__(self, backend: str = "jax-scan", *,
                 chunk_size: Optional[int] = None, metrics: bool = True,
                 **backend_opts: Any):
        _ensure_builtin()
        if backend not in _FACTORIES:
            raise _unknown_backend_error(backend)
        self.backend = backend
        self.chunk_size = chunk_size
        self.metrics = bool(metrics)
        self.backend_opts = dict(backend_opts)
        self._runners: Dict[Tuple[Any, ...], ChunkRunner] = {}
        # RL env executables (repro.env), cached under the same
        # shape-semantic keys as the chunk runners: any scenario mixture of
        # one shape trains against one compile.
        self._env_traces: Dict[Tuple[Any, ...], Dict[Any, Any]] = {}

    @property
    def trace_count(self) -> int:
        """Total traces across all cached executables (retrace detector)."""
        return sum(r.trace_count for r in self._runners.values())

    def clear_cache(self) -> None:
        """Drop all cached executables (long-lived shape-sweep processes)."""
        self._runners.clear()
        self._env_traces.clear()

    def _runner(self, spec, chunk: int) -> ChunkRunner:
        spec = EnsembleSpec.coerce(spec)
        key = spec.static_key() + (chunk,)
        runner = self._runners.get(key)
        if runner is None:
            runner = _FACTORIES[self.backend](spec, chunk, **self.backend_opts)
            self._runners[key] = runner
        return runner

    def open(self, spec: Union[EnsembleSpec, MarketConfig], *,
             chunk_size: Optional[int] = None,
             metrics: Optional[bool] = None) -> "Session":
        """Open a live session holding a device-resident :class:`MarketState`.

        ``spec`` is an :class:`EnsembleSpec` or a :class:`MarketConfig`
        (coerced through ``EnsembleSpec.homogeneous`` — bitwise-identical
        to the historical scalar-config path).

        Every session carries a :class:`repro.ops.metrics.MetricsRegistry`
        by default (``Session.metrics``), sampled strictly outside the
        jitted graph — zero additional traces, bitwise-invisible to
        results. Disable per-session with ``metrics=False`` or engine-wide
        with ``Engine(backend, metrics=False)``.
        """
        spec = EnsembleSpec.coerce(spec)
        chunk = chunk_size or self.chunk_size \
            or min(DEFAULT_CHUNK, spec.num_steps)
        registry = None
        if self.metrics if metrics is None else metrics:
            from repro.ops.metrics import MetricsRegistry

            registry = MetricsRegistry()
        return Session(self, spec, self._runner(spec, max(1, chunk)),
                       metrics=registry)

    def warm(self, specs, *, chunk_sizes=None, include_step: bool = True):
        """Precompile every executable ``specs`` will need (see
        :func:`repro.ops.warmup.warm`); returns the post-warm readiness
        probe, so ``engine.warm(specs).ready`` gates serving traffic."""
        from repro.ops import warmup

        return warmup.warm(self, specs, chunk_sizes=chunk_sizes,
                           include_step=include_step)

    def readiness(self):
        """Which cached ``(static_key, chunk)`` executables are warm
        (see :func:`repro.ops.warmup.readiness`)."""
        from repro.ops import warmup

        return warmup.readiness(self)

    def env(self, spec: Union[EnsembleSpec, MarketConfig], **env_opts: Any):
        """Open a pure-functional RL environment over this engine's backend.

        Returns a :class:`repro.env.MarketEnv` whose step core is this
        engine's single-step executable (the one :meth:`Session.step` uses)
        and whose jitted step/rollout traces are cached on the engine under
        the shape-semantic :meth:`EnsembleSpec.static_key` — two envs opened
        on different scenario mixtures of the same shape share every
        compile. ``env_opts`` are :class:`repro.env.MarketEnv` keyword
        options (``obs=``, ``reward=``, ``horizon=``, ``auto_reset=``).
        """
        from repro.env.core import MarketEnv

        return MarketEnv(spec, engine=self, **env_opts)

    def trainer(self, spec: Union[EnsembleSpec, MarketConfig], config=None,
                **env_opts: Any):
        """Open a PPO trainer over this engine (see :mod:`repro.train`).

        Sugar for ``PPOTrainer(self.env(spec, **env_opts), config)``. The
        compiled train step — rollout + GAE + minibatched updates as ONE
        executable — caches on the engine under the same shape-semantic
        ``static_key`` as rollouts, so trainers over different scenario
        mixtures of the same shape share the warm trace.
        """
        from repro.train.ppo import PPOConfig, PPOTrainer

        env = self.env(spec, **env_opts)
        return PPOTrainer(env, config or PPOConfig())


class Session:
    """A live simulation: device-resident books + an absolute step cursor.

    Obtained from :meth:`Engine.open`; usable as a context manager. All
    advancement APIs (:meth:`run`, :meth:`stream`, :meth:`step`) move the
    same cursor, so they interleave freely with bitwise-reproducible
    results — any chunking of S steps equals one ``run(S)`` call.
    """

    def __init__(self, engine: Engine, spec: EnsembleSpec,
                 runner: ChunkRunner, metrics=None):
        self._engine = engine
        self.spec = spec
        self._runner = runner
        self._step_runner: Optional[ChunkRunner] = None
        self._state = runner.init_state(spec)
        self._params = runner.params_to_device(spec.params)
        self._aux = runner.init_aux(spec)
        self._stats = runner.init_stats(spec)
        self._t = 0
        self._closed = False
        self._active_streams = 0
        self.metrics = metrics
        if metrics is not None:
            metrics.gauge("chunk", runner.chunk)
            metrics.gauge("num_markets", spec.num_markets)
            tile = getattr(runner, "tile", None)
            if tile is not None:  # Pallas engines: autotune tile pressure
                from repro.kernels import autotune as tune

                metrics.gauge("tile_mb", tile.mb)
                metrics.gauge("tile_agent_chunk", tile.agent_chunk)
                metrics.gauge("autotune_vmem_bytes", tune.estimate_vmem_bytes(
                    tile, spec.num_levels, spec.num_agents, runner.chunk))

    @property
    def cfg(self) -> EnsembleSpec:
        """The session's ensemble spec (kept under the historical name)."""
        return self.spec

    # ---- lifecycle ----
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the device-resident state (the executables stay cached)."""
        self._state = None
        self._params = None
        self._aux = None
        self._stats = None
        self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("session is closed")

    # ---- introspection ----
    @property
    def state(self) -> MarketState:
        """Current device-resident state. Do not hold across :meth:`run`:
        the buffers are donated to the next chunk call."""
        self._check_open()
        return self._state

    @property
    def params(self) -> MarketParams:
        """Device-resident per-market scenario operands (never donated)."""
        self._check_open()
        return self._params

    @property
    def step_count(self) -> int:
        """Absolute number of steps advanced since open/restore."""
        return self._t

    @property
    def horizon(self) -> int:
        """The configured horizon ``spec.num_steps`` — the default run
        length, and the bound every scenario event is validated against."""
        return self.spec.num_steps

    @property
    def stats(self) -> Optional[MarketStats]:
        """Running per-market statistics (``stats_only`` sessions; else None).

        The accumulators are device-resident and carried through every chunk
        call — reading them here materializes a host copy. Use
        ``stats.mean_mid()`` / ``stats.var_mid()`` for the derived moments.
        """
        self._check_open()
        if self._stats is None:
            return None
        return self._stats.to_numpy()

    # ---- advancement ----
    def _resolve_steps(self, n_steps: Optional[int]) -> int:
        """Horizon semantics for the default-length form (see module doc).

        ``n_steps=None`` means "run the configured horizon" — which is only
        meaningful while the cursor is still inside it. Advancing a session
        that already reached ``num_steps`` would re-run a horizon's worth of
        steps in which no configured scenario event (every ``shock_step`` is
        validated ``< num_steps``) can ever fire — historically a silent
        no-shock run. Pass an explicit ``n_steps`` to stream past the
        horizon deliberately.
        """
        if n_steps is not None:
            n = int(n_steps)
            if n < 0:
                raise ValueError(f"n_steps must be >= 0, got {n}")
            return n
        remaining = self.spec.num_steps - self._t
        if remaining <= 0:
            raise ValueError(
                f"session cursor is at step {self._t} with "
                f"{max(remaining, 0)} steps remaining of the configured "
                f"horizon num_steps={self.spec.num_steps}: run()/stream() "
                "with no argument means 'run the remaining horizon', and "
                "every scenario event lies inside it — pass an explicit "
                "n_steps to advance past the horizon")
        return remaining

    def stream(self, n_steps: Optional[int] = None) -> Iterator[StepBatch]:
        """Advance ``n_steps`` steps, yielding one :class:`StepBatch` per
        compiled chunk as it completes.

        ``n_steps=None`` runs to the configured horizon (``spec.num_steps``)
        from the current cursor, and raises a clear error if the cursor is
        already past it; an explicit ``n_steps`` may advance arbitrarily far
        beyond the horizon (absolute-step RNG keeps post-horizon steps well
        defined — scenario events simply lie behind the cursor). The step
        count (and any horizon error) resolves at the *call*, not lazily at
        first iteration, so the iterator's length is fixed when created.
        """
        self._check_open()
        return self._stream(self._resolve_steps(n_steps))

    def _dispatch(self, runner: ChunkRunner, n: int, ext,
                  kind: str) -> StepBatch:
        """One runner dispatch with host-side metrics sampling around it.

        All sampling is strictly outside the jitted call: wall-clock reads
        and two integer trace-counter reads. Nothing here becomes an
        operand of (or inserts a sync into) the compiled executable, so a
        metrics-on session is bitwise-identical to a metrics-off one.
        """
        m = self.metrics
        if m is not None:
            traces0 = runner.trace_count
            t0 = time.perf_counter()
        self._state, self._aux, batch, self._stats = runner.run(
            self._state, self._params, self._aux, self._t, n, ext,
            self._stats)
        if m is not None:
            m.observe(f"{kind}_seconds", time.perf_counter() - t0)
            m.inc("steps_total", n)
            if kind == "chunk":
                m.inc("chunks_total")
            traced = runner.trace_count - traces0
            if traced:
                m.inc("traces", traced)
        self._t += n
        return batch

    def _stream(self, remaining: int) -> Iterator[StepBatch]:
        self._active_streams += 1
        try:
            while remaining > 0:
                n = min(self._runner.chunk, remaining)
                yield self._dispatch(self._runner, n, None, "chunk")
                remaining -= n
        finally:
            self._active_streams -= 1

    def run(self, n_steps: Optional[int] = None) -> StepBatch:
        """Advance ``n_steps`` and return the concatenated
        :class:`StepBatch` for exactly those steps. ``n_steps=None`` runs to
        the configured horizon (see :meth:`stream` for the semantics)."""
        self._check_open()
        batches = list(self._stream(self._resolve_steps(n_steps)))
        if not batches:
            M = self.spec.num_markets
            empty = self._runner.xp.zeros((M, 0), self._runner.xp.float32)
            return StepBatch(empty, empty, empty)
        return StepBatch.concatenate(batches, xp=self._runner.xp)

    def step(self, actions: Optional[Any] = None) -> StepBatch:
        """Gym-style hook: advance exactly one step, optionally injecting
        external orders through the reserved slot.

        ``actions`` is an :class:`ExternalOrders` (or a ``(side_buy, price,
        qty)`` triple / mapping with those keys), one order per market;
        ``None`` advances the market untouched — bitwise-identical to a
        one-step :meth:`run`. Uses a dedicated single-step executable (shared
        through the engine cache) so warm per-step latency has no chunk
        overhead. Returns the one-column :class:`StepBatch` observation.
        """
        self._check_open()
        if self._step_runner is None:
            self._step_runner = self._engine._runner(self.spec, 1)
        return self._dispatch(self._step_runner, 1, self._build_ext(actions),
                              "step")

    def _build_ext(self, actions: Any) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if actions is None:
            return None
        from repro.env import actions as actions_mod

        orders = actions_mod.validate_actions(
            actions, self.spec.num_markets, self.spec.num_levels)
        return actions_mod.lower_actions(
            orders, self.spec.num_markets, self.spec.num_levels, np)

    # ---- slot mutation (the serving gateway's attach/detach hook) ----
    def swap_markets(self, slots, sub: Union[EnsembleSpec, MarketConfig],
                     *, reset_books: bool = True) -> None:
        """Chunk-boundary slot mutation: replace markets ``slots`` with the
        rows of ``sub`` (an ``len(slots)``-market spec/config), in place.

        This is the serving gateway's attach/detach primitive: a client's
        market is spliced into a running ensemble as a pure *value* update
        — new per-market params rows plus (``reset_books``) that market's
        fresh opening book — so the session keeps its static shape, its
        warm executable (zero retraces), and bitwise-identical trajectories
        for every **other** market: the step loop is row-independent and
        the RNG keys on ``(seed, global market id, absolute step)``, so
        rows outside ``slots`` never see the splice. Detaching is the same
        call with :meth:`EnsembleSpec.parked` rows.

        ``sub`` must agree with the session spec on every static field
        (``num_agents``/``num_levels``/``seed``/``num_steps``); the splice
        happens on host mirrors and re-places state/params through the
        runner, so it works identically on single-device and sharded
        sessions. Like :meth:`restore`, it is rejected during an active
        ``stream()`` — call it between chunks (the engine's only coherent
        preemption points).
        """
        self._check_open()
        if self._active_streams:
            raise RuntimeError(
                "swap_markets() during an active stream(): slot mutations "
                "apply at chunk boundaries — exhaust or close() the "
                "iterator first")
        sub = EnsembleSpec.coerce(sub)
        t0 = time.perf_counter()
        new_spec = self.spec.replace_markets(slots, sub)  # validates slots
        idx = np.asarray(slots, dtype=np.int64).reshape(-1)
        new_state = self._state
        if reset_books:
            host = [np.array(np.asarray(x), np.float32) for x in self._state]
            fresh = initial_state(sub, np)
            for leaf, src in zip(host, fresh):
                leaf[idx] = np.asarray(src, np.float32)
            new_state = self._runner.to_device(MarketState(*host))
        new_stats = self._stats
        if self._stats is not None:
            shost = [np.array(np.asarray(x), np.float32)
                     for x in self._stats]
            zero = init_stats(idx.size, np)
            for leaf, src in zip(shost, zero):
                leaf[idx] = np.asarray(src, np.float32)
            new_stats = self._runner.stats_to_device(MarketStats(*shost))
        # Commit only after every placement succeeded (restore()-style
        # all-or-nothing: a failed splice leaves the session untouched).
        self._params = self._runner.params_to_device(new_spec.params)
        self._state, self._stats = new_state, new_stats
        self.spec = new_spec
        if self.metrics is not None:
            self.metrics.observe("swap_seconds", time.perf_counter() - t0)
            self.metrics.inc("swaps_total", int(idx.size))

    # ---- results ----
    def to_result(self, batch: StepBatch) -> SimResult:
        """Assemble a terminal :class:`SimResult` from the final books plus a
        streamed batch — the one-shot ``simulate()`` compatibility shape."""
        self._check_open()
        if self._runner.stats_only:
            # A SimResult has nowhere to carry the accumulators — returning
            # zero-width paths would silently lose every output.
            raise ValueError(
                "stats_only sessions have no path outputs: read "
                "Session.stats instead of the one-shot SimResult shape")
        s = self._state
        return SimResult(bid=s.bid, ask=s.ask, last_price=s.last_price,
                         prev_mid=s.prev_mid, price_path=batch.price,
                         volume_path=batch.volume)

    def run_to_result(self, n_steps: Optional[int] = None) -> SimResult:
        return self.to_result(self.run(n_steps))

    # ---- snapshot / restore ----
    def snapshot(self) -> Dict[str, Any]:
        """Exact host-side capture: books, step cursor, stateful RNG, and
        the per-market parameter operands (a snapshot is self-contained —
        it restores the scenario mixture it was taken under).

        Mid-``stream()`` snapshots are **chunk-boundary-aligned**: the
        session cursor only ever advances one whole compiled chunk at a
        time (a partial tail is itself dispatched as one gated chunk), so a
        snapshot taken between yielded batches captures the state exactly
        after the last yielded chunk — ``snap["t"]`` equals the steps
        consumed so far, never a mid-chunk step. There is no misaligned
        call to guard against; :meth:`restore` during an active stream is
        rejected instead (the in-flight iterator would keep the old
        cursor).
        """
        self._check_open()
        t0 = time.perf_counter()
        snap: Dict[str, Any] = {
            field: np.asarray(value)
            for field, value in zip(MarketState._fields, self._state)
        }
        snap["t"] = self._t
        snap["rng"] = self._runner.aux_state(self._aux)
        snap["seed"] = self.spec.seed
        snap["num_agents"] = self.spec.num_agents
        snap["num_steps"] = self.spec.num_steps
        # Run-length encoded labels: O(blocks), not O(M), in the JSON meta.
        snap["scenarios"] = [[name, len(list(group))] for name, group
                             in itertools.groupby(self.spec.scenarios)]
        snap["params"] = {
            field: np.asarray(value)
            for field, value in zip(MarketParams._fields, self._params)
        }
        snap["init"] = {
            "quote_qty": np.asarray(self.spec.initial_quote_qty),
            "spread": np.asarray(self.spec.initial_spread),
        }
        if self._stats is not None:
            snap["stats"] = {
                field: np.asarray(value)
                for field, value in zip(MarketStats._fields, self._stats)
            }
        if self.metrics is not None:
            self.metrics.observe("snapshot_seconds", time.perf_counter() - t0)
            self.metrics.inc("snapshots_total")
        return snap

    def restore(self, snap: Dict[str, Any]) -> None:
        """Restore from :meth:`snapshot` — resumes the exact stream,
        including the snapshot's per-market parameters and horizon, so
        ``self.spec`` keeps describing the *live* mixture after a
        cross-spec restore (pre-params snapshots keep the session's
        current operands). Everything that can fail — placement, spec
        validation — happens before any session field is touched, so a
        failed restore leaves the session exactly as it was.

        Snapshots are device-layout agnostic: a snapshot taken on a
        single-device session restores into a sharded one (and vice versa)
        bitwise, because the runner re-places state/params/stats on restore.
        """
        self._check_open()
        if self._active_streams:
            raise RuntimeError(
                "restore() during an active stream(): the in-flight "
                "iterator would keep advancing from the pre-restore cursor. "
                "Exhaust or close() the iterator first (snapshot() stays "
                "safe mid-stream — it is chunk-boundary-aligned).")
        from repro.checkpoint.manager import CheckpointShapeError

        t_start = time.perf_counter()
        # seed and num_agents are baked into the compiled trace (they are
        # in the static cache key) yet appear in no restored array's shape
        # (params are [M, 1]; books are [M, L]), so a mismatch would
        # silently resume on a different random stream — reject loudly.
        # num_agents gets the typed shape error (it is a config-shape
        # field); a CheckpointShapeError is a ValueError, so older callers
        # catching ValueError keep working.
        for field, have, cls in (
                ("seed", self.spec.seed, ValueError),
                ("num_agents", self.spec.num_agents, CheckpointShapeError)):
            got = snap.get(field)
            if got is not None and int(got) != have:
                raise cls(
                    f"snapshot was taken under {field}={int(got)} but this "
                    f"session's executable is compiled for {field}={have}; "
                    f"open the session on a spec with the snapshot's "
                    f"{field} to resume its stream")
        # Shape-validate every array leaf against the live session *before*
        # touching any field — the historical failure mode here was an
        # opaque broadcast/unflatten error deep inside placement.
        M, L = self.spec.num_markets, self.spec.num_levels
        for name, want, blame in (
                ("bid", (M, L), "num_levels"), ("ask", (M, L), "num_levels"),
                ("last_price", (M, 1), "num_markets"),
                ("prev_mid", (M, 1), "num_markets")):
            arr = np.asarray(snap[name])
            if tuple(arr.shape) != want:
                if arr.ndim < 1 or arr.shape[0] != M:
                    blame = "num_markets"
                raise CheckpointShapeError(
                    f"snapshot field {name!r} has shape {tuple(arr.shape)} "
                    f"but this session expects {want} — mismatched {blame} "
                    f"(session has num_markets={M}, num_levels={L}); open "
                    f"the session on a spec matching the snapshot")
        if snap.get("params") is not None:
            # Older snapshots predate some fields (filled inert below) —
            # only shape-check the leaves the payload actually carries.
            for pname in MarketParams._fields:
                if pname not in snap["params"]:
                    continue
                arr = np.asarray(snap["params"][pname])
                if tuple(arr.shape) != (M, 1):
                    raise CheckpointShapeError(
                        f"snapshot params leaf {pname!r} has shape "
                        f"{tuple(arr.shape)}, expected ({M}, 1) — "
                        f"mismatched num_markets (session has "
                        f"num_markets={M})")
        new_state = self._runner.to_device(
            MarketState(*(snap[f] for f in MarketState._fields)))
        new_t = int(snap["t"])
        new_spec, new_params = self.spec, self._params
        params = snap.get("params")
        if params is not None:
            host = params_mod.params_from_dict(params, M, L)
            labels = snap.get("scenarios")
            if labels is not None:  # run-length encoded [name, count] pairs
                labels = tuple(itertools.chain.from_iterable(
                    (name,) * int(count) for name, count in labels))
            init = snap.get("init")
            new_spec = dataclasses.replace(
                self.spec, params=host,
                num_steps=int(snap.get("num_steps", self.spec.num_steps)),
                scenarios=labels if labels is not None
                else ("<restored>",) * self.spec.num_markets,
                **({"initial_quote_qty":
                        np.asarray(init["quote_qty"], np.float32),
                    "initial_spread": np.asarray(init["spread"], np.int32)}
                   if init is not None else {}))
            new_params = self._runner.params_to_device(host)
        rng = snap.get("rng")
        new_aux = (self._runner.restore_aux(rng) if rng is not None
                   else self._runner.init_aux(new_spec)
                   if self._aux is not None else None)
        new_stats = self._stats
        if self._runner.stats_only:
            stats = snap.get("stats")
            new_stats = (self._runner.stats_to_device(
                MarketStats(*(stats[f] for f in MarketStats._fields)))
                if stats is not None else self._runner.init_stats(new_spec))
        self._state, self._t = new_state, new_t
        self.spec, self._params = new_spec, new_params
        self._aux, self._stats = new_aux, new_stats
        if self.metrics is not None:
            self.metrics.observe("restore_seconds",
                                 time.perf_counter() - t_start)
            self.metrics.inc("restores_total")

    def save_checkpoint(self, manager, step: Optional[int] = None,
                        *, wait: bool = True) -> int:
        """Persist the session through a ``CheckpointManager``; returns the
        checkpoint step (defaults to the session's step cursor).

        ``wait=False`` returns as soon as the snapshot is handed to the
        manager's background writer (device→host mirror only — the serving
        gateway's non-blocking checkpoint path); the caller is responsible
        for a later ``manager.wait()`` before relying on durability.
        """
        from repro.checkpoint import manager as ckpt

        step = self._t if step is None else int(step)
        manager.save(step, ckpt.session_tree(self.snapshot()))
        if wait:
            manager.wait()
        return step

    def restore_checkpoint(self, manager, step: Optional[int] = None) -> int:
        """Restore from a ``CheckpointManager``; returns the restored step."""
        from repro.checkpoint import manager as ckpt

        tree = manager.restore(step)
        if tree is None:
            raise FileNotFoundError(
                f"no checkpoint found in {manager.dir}")
        self.restore(ckpt.snapshot_from_tree(tree))
        return self._t
