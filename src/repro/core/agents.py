"""Agent archetype registry (paper §III-C), array-module polymorphic.

Every backend — NumPy reference, JAX step/scan engines, and both Pallas
kernels — executes *this exact function* for agent decisions (the paper's
"shared device-side decide()"), which is what makes the bitwise-identity
experiments meaningful.

Archetypes are registered per strategy-class id; ``decide`` evaluates every
registered archetype on the full [M, A] lattice and selects per-agent with
``where`` masks derived from the **per-market** population counts in
:class:`repro.core.params.MarketParams`. The dispatch is branch-free by
construction — no data-dependent control flow — so the same code fuses
inside the persistent Pallas clearing kernel, lax.scan, and the NumPy host
loop without specialization, and one compiled trace serves *any* scenario
mixture: every scenario-varying knob (noise width, maker spread,
fundamentalist target/strength, marketable-flow probability, quantity cap,
flash-crash schedule, archetype counts) is a ``[M, 1]`` runtime operand
broadcast over the agent axis.

All five RNG channels are drawn every step, for every market. For the
counter-based generators this is free of semantic weight (channels are
independent pure functions of the coordinate, and inactive draws are masked
off), and it is what keeps the *stateful* PCG64 reference per-market
decomposable: the draw schedule no longer depends on which scenario a
market runs, so market ``m`` of a mixed ensemble consumes exactly the rows
the homogeneous run consumed.

All float math is float32 with explicit casts so NumPy (which would otherwise
promote to float64) and JAX produce identical bit patterns.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

import numpy as np

from repro.core import params as params_mod
from repro.core import rng
from repro.core.config import (
    ARBITRAGEUR,
    CH_MKT,
    CH_PRICE,
    CH_QTY,
    CH_SHOCK,
    CH_SIDE,
    FUNDAMENTALIST,
    HFT,
    INFORMED,
    MAKER,
    MOMENTUM,
    NOISE,
    WHALE,
)
from repro.core.params import MarketParams


class ArchetypeContext(NamedTuple):
    """Per-step inputs every archetype sees (all already [M, A]-broadcastable)."""

    params: MarketParams  # per-market [M, 1] scenario parameters
    xp: "module"
    mid: "array"        # float32[M, 1] current mid price
    prev_mid: "array"   # float32[M, 1] previous step's mid price
    step_i: "array"     # int32 scalar step index (traced ok)
    agent_ids: "array"  # int32[1, A] agent indices within a market
    u_side: "array"     # float32[M, A] side-channel uniforms
    u_price: "array"    # float32[M, A] price-channel uniforms
    imbalance: "array"  # float32[M, 1] resting-book imbalance in [-1, 1]
    peer_mid: "array"   # float32[M, 1] coupled peer's previous-chunk mid
    num_levels: int     # L — static price-grid width


# type_id -> (name, fn(ctx) -> (side_buy, price_f)); ids match config constants.
_ARCHETYPES: Dict[int, Tuple[str, Callable]] = {}


def register_archetype(type_id: int, name: str):
    def deco(fn):
        _ARCHETYPES[type_id] = (name, fn)
        return fn
    return deco


def archetype_names() -> Dict[int, str]:
    return {tid: name for tid, (name, _) in sorted(_ARCHETYPES.items())}


@register_archetype(NOISE, "noise")
def _noise(ctx: ArchetypeContext):
    """Random side; price = mid + U[-Δ, Δ] with per-market Δ."""
    f32 = ctx.xp.float32
    side_buy = ctx.u_side < f32(0.5)
    delta = ctx.xp.asarray(ctx.params.noise_delta, dtype=f32)
    eta = (ctx.u_price * f32(2.0) - f32(1.0)) * delta
    return side_buy, ctx.mid + eta


@register_archetype(MOMENTUM, "momentum")
def _momentum(ctx: ArchetypeContext):
    """Trend follower: side = sgn(mid_t - mid_{t-1}); price = mid ± 1."""
    xp, f32 = ctx.xp, ctx.xp.float32
    ret = xp.sign(ctx.mid - ctx.prev_mid)  # float32[M, 1]
    ret = ret + xp.zeros_like(ctx.u_side)  # broadcast [M, A]
    side_buy = xp.where(ret != f32(0.0), ret > f32(0.0), ctx.u_side < f32(0.5))
    price_f = ctx.mid + xp.where(side_buy, f32(1.0), f32(-1.0))
    return side_buy, price_f


@register_archetype(MAKER, "maker")
def _maker(ctx: ArchetypeContext):
    """Market maker: alternate on parity of (a + s); per-market half-spread."""
    xp, f32 = ctx.xp, ctx.xp.float32
    side_buy = ((ctx.agent_ids + ctx.step_i) % xp.int32(2)) == xp.int32(0)
    half = xp.asarray(ctx.params.maker_half_spread, dtype=f32)
    price_f = xp.where(side_buy, ctx.mid - half, ctx.mid + half)
    return side_buy, price_f


@register_archetype(FUNDAMENTALIST, "fundamentalist")
def _fundamentalist(ctx: ArchetypeContext):
    """Mean reversion toward the per-market fundamental price F.

    Buys when mid < F (random side at the fixed point), quoting part-way back
    toward F (per-market strength kappa) with a unit jitter so
    fundamentalists do not collapse onto a single tick.
    """
    xp, f32 = ctx.xp, ctx.xp.float32
    fundamental = xp.asarray(ctx.params.fundamental, dtype=f32)
    dev = fundamental - ctx.mid               # float32[M, 1]
    dev = dev + xp.zeros_like(ctx.u_side)     # broadcast [M, A]
    side_buy = xp.where(dev != f32(0.0), dev > f32(0.0), ctx.u_side < f32(0.5))
    jitter = ctx.u_price * f32(2.0) - f32(1.0)
    kappa = xp.asarray(ctx.params.fundamentalist_kappa, dtype=f32)
    price_f = ctx.mid + dev * kappa + jitter
    return side_buy, price_f


@register_archetype(WHALE, "whale")
def _whale(ctx: ArchetypeContext):
    """Large infrequent sweeps: a marketable block order of ``whale_size``
    lots every ``whale_period`` steps, random side; silent in between.

    The sweep cadence is expressed through the *quantity* (``decide``
    zeroes whale quantities off-cadence), so the fixed draw schedule and
    the branch-free dispatch are untouched — an idle whale submits a
    zero-quantity order that bins to nothing.
    """
    xp, f32 = ctx.xp, ctx.xp.float32
    side_buy = ctx.u_side < f32(0.5)
    L = ctx.num_levels
    price_f = xp.where(side_buy, f32(L - 1), f32(0.0)) + xp.zeros_like(ctx.u_side)
    return side_buy, price_f


@register_archetype(HFT, "hft")
def _hft(ctx: ArchetypeContext):
    """Book-imbalance reactive: join the pressure side one tick through the
    mid when |imbalance| exceeds the per-market trigger, noise side below.
    """
    xp, f32 = ctx.xp, ctx.xp.float32
    imb = ctx.imbalance + xp.zeros_like(ctx.u_side)  # broadcast [M, A]
    thr = xp.asarray(ctx.params.hft_threshold, dtype=f32)
    side_buy = xp.where(xp.abs(imb) > thr, imb > f32(0.0),
                        ctx.u_side < f32(0.5))
    price_f = ctx.mid + xp.where(side_buy, f32(1.0), f32(-1.0))
    return side_buy, price_f


@register_archetype(INFORMED, "informed")
def _informed(ctx: ArchetypeContext):
    """Sees the fundamental shock early: sells marketably through the
    ``informed_horizon`` steps before ``shock_step``, noise-like otherwise
    (markets with no shock scheduled never open the window)."""
    xp, f32 = ctx.xp, ctx.xp.float32
    shock_step = xp.asarray(ctx.params.shock_step, dtype=xp.int32)
    horizon = xp.asarray(ctx.params.informed_horizon, dtype=xp.int32)
    false_b = xp.zeros_like(ctx.u_side) > f32(0.0)  # all-False [M, A]
    window = ((shock_step >= xp.int32(0))
              & (ctx.step_i >= shock_step - horizon)
              & (ctx.step_i < shock_step)) | false_b
    calm_side = ctx.u_side < f32(0.5)
    calm_price = ctx.mid + (ctx.u_price * f32(2.0) - f32(1.0))
    side_buy = xp.where(window, false_b, calm_side)
    price_f = xp.where(window, f32(0.0), calm_price)
    return side_buy, price_f


@register_archetype(ARBITRAGEUR, "arbitrageur")
def _arbitrageur(ctx: ArchetypeContext):
    """Cross-market arbitrage: chase the gap to the coupled peer market's
    previous-chunk mid (self-coupled markets see gap relative to their own
    frozen mid). Buys when the peer trades higher, quoting part-way toward
    the peer with a unit jitter."""
    xp, f32 = ctx.xp, ctx.xp.float32
    gap = ctx.peer_mid - ctx.mid              # float32[M, 1]
    gap = gap + xp.zeros_like(ctx.u_side)     # broadcast [M, A]
    side_buy = xp.where(gap != f32(0.0), gap > f32(0.0), ctx.u_side < f32(0.5))
    kappa = xp.asarray(ctx.params.arb_kappa, dtype=f32)
    jitter = ctx.u_price * f32(2.0) - f32(1.0)
    price_f = ctx.mid + gap * kappa + jitter
    return side_buy, price_f


def decide(cfg, params: MarketParams, mid, prev_mid, step, market_ids,
           agent_ids, xp, uniform_fn=None, atype=None, seed=None,
           imbalance=None, peer_mid=None):
    """Vectorized agent decisions for one step.

    Args:
      cfg:        the static shape carrier (``MarketConfig`` or
                  ``EnsembleSpec``) supplying ``num_agents``, ``num_levels``
                  and the RNG ``seed`` — the only fields baked into traces.
      params:     :class:`MarketParams` of per-market ``[M, 1]`` operands
                  (``[1, 1]`` constants on the legacy scalar path).
      mid:        float32[M, 1] current mid price per market.
      prev_mid:   float32[M, 1] previous step's mid price.
      step:       int32 scalar (traced ok) step index.
      market_ids: int32[M, 1] global market indices (for the RNG coordinate).
      agent_ids:  int32[1, A] (or [A]) agent indices within a market.
      uniform_fn: optional ``f(gid, step, channel) -> float32[M, A]`` RNG
        override (used by the statistical-equivalence reference backends);
        defaults to the production kinetic_hash32 stream.
      atype:      optional precomputed per-market type lattice
        (:func:`repro.core.params.agent_types`) — it is step-invariant, so
        loop drivers hoist it out of the step loop; ``None`` recomputes it
        here (value-identical).
      seed:       optional runtime seed override for the production counter
        stream (scalar, traced ok — the RL env's vmap-over-seeds operand).
        ``None`` uses the trace-static ``cfg.seed``; a concrete value equal
        to ``cfg.seed`` is bitwise-identical to ``None``. Ignored when
        ``uniform_fn`` is supplied (the override owns its own stream).
      imbalance:  optional float32[M, 1] resting-book imbalance
        ``(Σbid - Σask) / (Σbid + Σask)`` feeding the HFT archetype
        (``None`` → zeros: HFTs fall back to their noise side).
      peer_mid:   optional float32[M, 1] coupled peer market's frozen
        (previous-chunk) mid feeding the arbitrageur archetype (``None``
        → ``prev_mid``, i.e. self-coupling).

    Returns:
      side_buy: bool[M, A], price: int32[M, A], qty: float32[M, A]
    """
    A = cfg.num_agents
    L = cfg.num_levels
    f32 = xp.float32

    agent_ids = xp.reshape(xp.asarray(agent_ids, dtype=xp.int32), (1, -1))
    market_ids = xp.reshape(xp.asarray(market_ids, dtype=xp.int32), (-1, 1))
    gid = (market_ids * xp.int32(A) + agent_ids).astype(xp.uint32)  # [M, A]
    step_u = xp.asarray(step).astype(xp.uint32)

    if uniform_fn is None:
        seed = cfg.seed if seed is None else seed

        def u(channel):
            return rng.uniform32(seed, gid, step_u, channel, xp)
    else:
        def u(channel):
            return uniform_fn(gid, step_u, channel)

    # Fixed five-channel draw schedule — scenario-independent by design, so
    # the sequential PCG64 reference stays per-market decomposable across
    # ensemble mixtures (see module docstring). The one exception is the
    # production counter stream (uniform_fn=None): its channels are pure
    # functions of the coordinate, so when every market's shock intensity
    # is a concrete host zero the CH_SHOCK draw is skipped outright —
    # bitwise-invisible, and it spares the NumPy reference a full [M, A]
    # hash channel per step on baseline runs.
    u_side = u(CH_SIDE)
    u_price = u(CH_PRICE)
    u_mkt = u(CH_MKT)
    u_qty = u(CH_QTY)
    skip_shock = (uniform_fn is None
                  and isinstance(params.shock_intensity, np.ndarray)
                  and not params.shock_intensity.any())
    u_shock = None if skip_shock else u(CH_SHOCK)

    if atype is None:  # int32[M, A]-broadcastable per-market type lattice
        atype = params_mod.agent_types(params, A, xp)
    mid = xp.asarray(mid, dtype=xp.float32)
    prev_mid = xp.asarray(prev_mid, dtype=xp.float32)
    step_i = xp.asarray(step).astype(xp.int32)
    imbalance = (xp.zeros_like(mid) if imbalance is None
                 else xp.asarray(imbalance, dtype=xp.float32))
    peer_mid = (prev_mid if peer_mid is None
                else xp.asarray(peer_mid, dtype=xp.float32))

    ctx = ArchetypeContext(params=params, xp=xp, mid=mid, prev_mid=prev_mid,
                           step_i=step_i, agent_ids=agent_ids,
                           u_side=u_side, u_price=u_price,
                           imbalance=imbalance, peer_mid=peer_mid,
                           num_levels=L)

    # Branch-free archetype dispatch: evaluate every registered archetype on
    # the full lattice, select by the per-market type lattice. Masks are
    # disjoint, so the fold order only needs to be deterministic (ascending
    # type id) for bitwise reproducibility; because the final value at each
    # agent is exactly its own archetype's output, evaluating unpopulated
    # archetypes is value-invisible — which is what lets one trace serve
    # any population mixture. The NumPy host loop cannot constant-fold a
    # dead select, so an archetype whose count column is a *concrete* host
    # array of zeros is skipped outright (its mask would be all-False —
    # value-identical); traced backends always see the full fold.
    count_cols = {MAKER: params.num_makers, MOMENTUM: params.num_momentum,
                  FUNDAMENTALIST: params.num_fundamentalists,
                  WHALE: params.num_whales, HFT: params.num_hft,
                  INFORMED: params.num_informed,
                  ARBITRAGEUR: params.num_arbitrageurs}

    def concretely_empty(tid):
        col = count_cols.get(tid)
        return isinstance(col, np.ndarray) and not col.any()

    zero_f = xp.zeros_like(u_side)
    zero_b = zero_f > f32(0.0)  # all-False bool[M, A] broadcast template
    ids = sorted(_ARCHETYPES)
    _, fn0 = _ARCHETYPES[ids[0]]
    side_buy, price_f = fn0(ctx)
    side_buy = side_buy | zero_b
    price_f = price_f + zero_f
    for tid in ids[1:]:
        if concretely_empty(tid):
            continue
        _, fn = _ARCHETYPES[tid]
        s, p = fn(ctx)
        mask = atype == xp.int32(tid)
        side_buy = xp.where(mask, s | zero_b, side_buy)
        price_f = xp.where(mask, p + zero_f, price_f)

    is_maker = atype == MAKER

    # Marketable orders (never for makers): force to the grid boundary.
    p_mkt = xp.asarray(params.p_marketable, dtype=f32)
    marketable = (u_mkt < p_mkt) & ~is_maker
    price_f = xp.where(
        marketable,
        xp.where(side_buy, f32(L - 1), f32(0.0)),
        price_f,
    )

    # Scenario overlay: flash-crash panic, keyed on the per-market shock
    # schedule (branch-free; markets whose shock_step is < 0 or elsewhere
    # see an all-False mask and an untouched stream). Panicking non-makers
    # sell marketably. Skipped when the shock channel was concretely
    # elided above — the panic mask would be all-False.
    if u_shock is not None:
        shock_step = xp.asarray(params.shock_step, dtype=xp.int32)
        shock_int = xp.asarray(params.shock_intensity, dtype=f32)
        at_shock = (step_i == shock_step) | zero_b
        panic = (u_shock < shock_int) & ~is_maker & at_shock
        side_buy = xp.where(panic, zero_b, side_buy)
        price_f = xp.where(panic, f32(0.0) + zero_f, price_f)

    # Round-half-even (identical in NumPy & JAX), prune to the grid (paper
    # §III-A: out-of-window orders are clipped / made marketable).
    price = xp.clip(xp.round(price_f), f32(0.0), f32(L - 1)).astype(xp.int32)

    # Integer quantity q = 1 + floor(u * q_max) in {1..q_max}, kept in f32
    # (exact-integer arithmetic => associative adds => bitwise reproducible).
    q_max = xp.asarray(params.q_max, dtype=f32)
    qty = f32(1.0) + xp.floor(u_qty * q_max)

    # Whale cadence overlay: whales trade ``whale_size`` lots on sweep steps
    # and zero lots otherwise (a zero-quantity order bins to nothing), so
    # their burstiness lives entirely in the quantity lattice and the draw
    # schedule stays fixed. Same concrete-zero elision as the dispatch fold.
    if not concretely_empty(WHALE):
        is_whale = (atype == xp.int32(WHALE)) | zero_b
        period = xp.maximum(
            xp.asarray(params.whale_period, dtype=xp.int32), xp.int32(1))
        at_sweep = ((step_i % period) == xp.int32(0)) | zero_b
        wq = xp.asarray(params.whale_size, dtype=f32) + zero_f
        qty = xp.where(is_whale, xp.where(at_sweep, wq, zero_f), qty)
    return side_buy, price, qty
