"""Agent archetype registry (paper §III-C), array-module polymorphic.

Every backend — NumPy reference, JAX step/scan engines, and both Pallas
kernels — executes *this exact function* for agent decisions (the paper's
"shared device-side decide()"), which is what makes the bitwise-identity
experiments meaningful.

Archetypes are registered per strategy-class id; ``decide`` evaluates every
registered archetype on the full [M, A] lattice and selects per-agent with
``where`` masks derived from the static mixture in :class:`MarketConfig`.
The dispatch is branch-free by construction — no data-dependent control
flow — so the same code fuses inside the persistent Pallas clearing kernel,
lax.scan, and the NumPy host loop without specialization.

All float math is float32 with explicit casts so NumPy (which would otherwise
promote to float64) and JAX produce identical bit patterns.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

from repro.core import rng
from repro.core.config import (
    CH_MKT,
    CH_PRICE,
    CH_QTY,
    CH_SHOCK,
    CH_SIDE,
    FUNDAMENTALIST,
    MAKER,
    MOMENTUM,
    NOISE,
    MarketConfig,
)


class ArchetypeContext(NamedTuple):
    """Per-step inputs every archetype sees (all already [M, A]-broadcastable)."""

    cfg: MarketConfig
    xp: "module"
    mid: "array"        # float32[M, 1] current mid price
    prev_mid: "array"   # float32[M, 1] previous step's mid price
    step_i: "array"     # int32 scalar step index (traced ok)
    agent_ids: "array"  # int32[1, A] agent indices within a market
    u_side: "array"     # float32[M, A] side-channel uniforms
    u_price: "array"    # float32[M, A] price-channel uniforms


# type_id -> (name, fn(ctx) -> (side_buy, price_f)); ids match config constants.
_ARCHETYPES: Dict[int, Tuple[str, Callable]] = {}


def register_archetype(type_id: int, name: str):
    def deco(fn):
        _ARCHETYPES[type_id] = (name, fn)
        return fn
    return deco


def archetype_names() -> Dict[int, str]:
    return {tid: name for tid, (name, _) in sorted(_ARCHETYPES.items())}


@register_archetype(NOISE, "noise")
def _noise(ctx: ArchetypeContext):
    """Random side; price = mid + U[-Δ, Δ]."""
    f32 = ctx.xp.float32
    side_buy = ctx.u_side < f32(0.5)
    eta = (ctx.u_price * f32(2.0) - f32(1.0)) * f32(ctx.cfg.noise_delta)
    return side_buy, ctx.mid + eta


@register_archetype(MOMENTUM, "momentum")
def _momentum(ctx: ArchetypeContext):
    """Trend follower: side = sgn(mid_t - mid_{t-1}); price = mid ± 1."""
    xp, f32 = ctx.xp, ctx.xp.float32
    ret = xp.sign(ctx.mid - ctx.prev_mid)  # float32[M, 1]
    ret = ret + xp.zeros_like(ctx.u_side)  # broadcast [M, A]
    side_buy = xp.where(ret != f32(0.0), ret > f32(0.0), ctx.u_side < f32(0.5))
    price_f = ctx.mid + xp.where(side_buy, f32(1.0), f32(-1.0))
    return side_buy, price_f


@register_archetype(MAKER, "maker")
def _maker(ctx: ArchetypeContext):
    """Market maker: alternate on parity of (a + s); fixed half-spread offset."""
    xp, f32 = ctx.xp, ctx.xp.float32
    side_buy = ((ctx.agent_ids + ctx.step_i) % xp.int32(2)) == xp.int32(0)
    half = f32(ctx.cfg.maker_half_spread)
    price_f = xp.where(side_buy, ctx.mid - half, ctx.mid + half)
    return side_buy, price_f


@register_archetype(FUNDAMENTALIST, "fundamentalist")
def _fundamentalist(ctx: ArchetypeContext):
    """Mean reversion toward the fundamental price F.

    Buys when mid < F (random side at the fixed point), quoting part-way back
    toward F (strength kappa) with a unit jitter so fundamentalists do not
    collapse onto a single tick.
    """
    xp, f32 = ctx.xp, ctx.xp.float32
    dev = f32(ctx.cfg.fundamental) - ctx.mid  # float32[M, 1]
    dev = dev + xp.zeros_like(ctx.u_side)     # broadcast [M, A]
    side_buy = xp.where(dev != f32(0.0), dev > f32(0.0), ctx.u_side < f32(0.5))
    jitter = ctx.u_price * f32(2.0) - f32(1.0)
    price_f = ctx.mid + dev * f32(ctx.cfg.fundamentalist_kappa) + jitter
    return side_buy, price_f


def decide(cfg: MarketConfig, mid, prev_mid, step, market_ids, agent_ids, xp,
           uniform_fn=None):
    """Vectorized agent decisions for one step.

    Args:
      mid:        float32[M, 1] current mid price per market.
      prev_mid:   float32[M, 1] previous step's mid price.
      step:       int32 scalar (traced ok) step index.
      market_ids: int32[M, 1] global market indices (for the RNG coordinate).
      agent_ids:  int32[1, A] (or [A]) agent indices within a market.
      uniform_fn: optional ``f(gid, step, channel) -> float32[M, A]`` RNG
        override (used by the statistical-equivalence reference backends);
        defaults to the production kinetic_hash32 stream.

    Returns:
      side_buy: bool[M, A], price: int32[M, A], qty: float32[M, A]
    """
    A = cfg.num_agents
    L = cfg.num_levels
    f32 = xp.float32

    agent_ids = xp.reshape(xp.asarray(agent_ids, dtype=xp.int32), (1, -1))
    market_ids = xp.reshape(xp.asarray(market_ids, dtype=xp.int32), (-1, 1))
    gid = (market_ids * xp.int32(A) + agent_ids).astype(xp.uint32)  # [M, A]
    step_u = xp.asarray(step).astype(xp.uint32)

    if uniform_fn is None:
        def u(channel):
            return rng.uniform32(cfg.seed, gid, step_u, channel, xp)
    else:
        def u(channel):
            return uniform_fn(gid, step_u, channel)

    u_side = u(CH_SIDE)
    u_price = u(CH_PRICE)
    u_mkt = u(CH_MKT)
    u_qty = u(CH_QTY)

    atype = cfg.agent_types(xp)[None, :]  # int32[1, A]
    mid = xp.asarray(mid, dtype=xp.float32)
    prev_mid = xp.asarray(prev_mid, dtype=xp.float32)
    step_i = xp.asarray(step).astype(xp.int32)

    ctx = ArchetypeContext(cfg=cfg, xp=xp, mid=mid, prev_mid=prev_mid,
                           step_i=step_i, agent_ids=agent_ids,
                           u_side=u_side, u_price=u_price)

    # Branch-free archetype dispatch: evaluate each populated archetype on
    # the full lattice, select by the static per-agent type vector. Masks are
    # disjoint, so the fold order only needs to be deterministic (ascending
    # type id) for bitwise reproducibility. Archetypes whose static count is
    # zero are skipped entirely — their mask would be all-False, so the
    # result is value-identical while the NumPy host loop (which cannot
    # constant-fold the dead select) skips the work.
    zero_f = xp.zeros_like(u_side)
    zero_b = zero_f > f32(0.0)  # all-False bool[M, A] broadcast template
    counts = cfg.archetype_counts()
    ids = [tid for tid in sorted(_ARCHETYPES) if counts.get(tid, 0) > 0]
    _, fn0 = _ARCHETYPES[ids[0]]
    side_buy, price_f = fn0(ctx)
    side_buy = side_buy | zero_b
    price_f = price_f + zero_f
    for tid in ids[1:]:
        _, fn = _ARCHETYPES[tid]
        s, p = fn(ctx)
        mask = atype == xp.int32(tid)
        side_buy = xp.where(mask, s | zero_b, side_buy)
        price_f = xp.where(mask, p + zero_f, price_f)

    is_maker = atype == MAKER

    # Marketable orders (never for makers): force to the grid boundary.
    marketable = (u_mkt < f32(cfg.p_marketable)) & ~is_maker
    price_f = xp.where(
        marketable,
        xp.where(side_buy, f32(L - 1), f32(0.0)),
        price_f,
    )

    # Scenario overlay: flash-crash panic (branch-free; the static python
    # guard keeps baseline configs off the extra RNG channel entirely, so
    # their streams are unchanged). Panicking non-makers sell marketably.
    if cfg.shock_intensity > 0.0 and cfg.shock_step >= 0:
        at_shock = step_i == xp.int32(cfg.shock_step)
        panic = (u(CH_SHOCK) < f32(cfg.shock_intensity)) & ~is_maker
        panic = panic & (at_shock | zero_b)
        side_buy = xp.where(panic, zero_b, side_buy)
        price_f = xp.where(panic, f32(0.0) + zero_f, price_f)

    # Round-half-even (identical in NumPy & JAX), prune to the grid (paper
    # §III-A: out-of-window orders are clipped / made marketable).
    price = xp.clip(xp.round(price_f), f32(0.0), f32(L - 1)).astype(xp.int32)

    # Integer quantity q = 1 + floor(u * q_max) in {1..q_max}, kept in f32
    # (exact-integer arithmetic => associative adds => bitwise reproducible).
    qty = f32(1.0) + xp.floor(u_qty * f32(cfg.q_max))
    return side_buy, price, qty
