"""Agent strategy classes (paper §III-C), array-module polymorphic.

Every backend — NumPy reference, JAX step/scan engines, and both Pallas
kernels — executes *this exact function* for agent decisions (the paper's
"shared device-side decide()"), which is what makes the bitwise-identity
experiments meaningful.

All float math is float32 with explicit casts so NumPy (which would otherwise
promote to float64) and JAX produce identical bit patterns.
"""
from __future__ import annotations

from repro.core import rng
from repro.core.config import (
    CH_MKT,
    CH_PRICE,
    CH_QTY,
    CH_SIDE,
    MAKER,
    MOMENTUM,
    MarketConfig,
)


def decide(cfg: MarketConfig, mid, prev_mid, step, market_ids, agent_ids, xp,
           uniform_fn=None):
    """Vectorized agent decisions for one step.

    Args:
      mid:        float32[M, 1] current mid price per market.
      prev_mid:   float32[M, 1] previous step's mid price.
      step:       int32 scalar (traced ok) step index.
      market_ids: int32[M, 1] global market indices (for the RNG coordinate).
      agent_ids:  int32[1, A] (or [A]) agent indices within a market.
      uniform_fn: optional ``f(gid, step, channel) -> float32[M, A]`` RNG
        override (used by the statistical-equivalence reference backends);
        defaults to the production kinetic_hash32 stream.

    Returns:
      side_buy: bool[M, A], price: int32[M, A], qty: float32[M, A]
    """
    A = cfg.num_agents
    L = cfg.num_levels
    f32 = xp.float32

    agent_ids = xp.reshape(xp.asarray(agent_ids, dtype=xp.int32), (1, -1))
    market_ids = xp.reshape(xp.asarray(market_ids, dtype=xp.int32), (-1, 1))
    gid = (market_ids * xp.int32(A) + agent_ids).astype(xp.uint32)  # [M, A]
    step_u = xp.asarray(step).astype(xp.uint32)

    if uniform_fn is None:
        def u(channel):
            return rng.uniform32(cfg.seed, gid, step_u, channel, xp)
    else:
        def u(channel):
            return uniform_fn(gid, step_u, channel)

    u_side = u(CH_SIDE)
    u_price = u(CH_PRICE)
    u_mkt = u(CH_MKT)
    u_qty = u(CH_QTY)

    atype = cfg.agent_types(xp)[None, :]  # int32[1, A]
    mid = xp.asarray(mid, dtype=xp.float32)
    prev_mid = xp.asarray(prev_mid, dtype=xp.float32)

    # --- NOISE: random side, price = round(mid + U[-Δ, Δ]) ---
    noise_side_buy = u_side < f32(0.5)
    eta = (u_price * f32(2.0) - f32(1.0)) * f32(cfg.noise_delta)
    noise_price = mid + eta

    # --- MOMENTUM: side = sgn(mid_t - mid_{t-1}); price = round(mid ± 1) ---
    ret = xp.sign(mid - prev_mid)  # float32[M, 1]
    ret = ret + xp.zeros_like(u_side)  # broadcast [M, A]
    mom_side_buy = xp.where(ret != f32(0.0), ret > f32(0.0), u_side < f32(0.5))
    mom_price = mid + xp.where(mom_side_buy, f32(1.0), f32(-1.0))

    # --- MAKER: alternate on parity of (a + s); fixed half-spread offset ---
    step_i = xp.asarray(step).astype(xp.int32)
    maker_side_buy = ((agent_ids + step_i) % xp.int32(2)) == xp.int32(0)
    maker_side_buy = maker_side_buy | xp.zeros_like(noise_side_buy)
    half = f32(cfg.maker_half_spread)
    maker_price = xp.where(maker_side_buy, mid - half, mid + half)

    is_mom = atype == MOMENTUM
    is_maker = atype == MAKER
    side_buy = xp.where(is_maker, maker_side_buy,
                        xp.where(is_mom, mom_side_buy, noise_side_buy))
    price_f = xp.where(is_maker, maker_price,
                       xp.where(is_mom, mom_price, noise_price))

    # Marketable orders (never for makers): force to the grid boundary.
    marketable = (u_mkt < f32(cfg.p_marketable)) & ~is_maker
    price_f = xp.where(
        marketable,
        xp.where(side_buy, f32(L - 1), f32(0.0)),
        price_f,
    )

    # Round-half-even (identical in NumPy & JAX), prune to the grid (paper
    # §III-A: out-of-window orders are clipped / made marketable).
    price = xp.clip(xp.round(price_f), f32(0.0), f32(L - 1)).astype(xp.int32)

    # Integer quantity q = 1 + floor(u * q_max) in {1..q_max}, kept in f32
    # (exact-integer arithmetic => associative adds => bitwise reproducible).
    qty = f32(1.0) + xp.floor(u_qty * f32(cfg.q_max))
    return side_buy, price, qty
