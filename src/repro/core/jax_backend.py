"""JAX framework baselines (paper §IV: "JAX GPU" and launch-per-step analogue).

Two engines:
  * ``scan``     — the paper's most competitive framework baseline: the whole
                   S-step loop fused into one XLA computation via
                   ``jax.lax.scan`` under ``jax.jit``.
  * ``per-step`` — a host loop dispatching one jitted step at a time, with the
                   book round-tripping device memory every step. This is the
                   launch-per-step regime whose Θ(S) dispatch overhead and
                   Θ(S·M·L) memory traffic the paper's persistent kernel
                   eliminates.

Both reuse the shared step semantics in :mod:`repro.core.step`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.config import MarketConfig
from repro.core.result import SimResult
from repro.core.step import MarketState, initial_state, simulate_step


def _bin_orders_scatter_jax(side_buy, price, qty, M, L):
    """Scatter-add binning (.at[].add) — XLA's analogue of atomicAdd."""
    qb = qty * side_buy.astype(jnp.float32)
    qs = qty * (~side_buy).astype(jnp.float32)
    m_idx = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[:, None], price.shape)
    buy = jnp.zeros((M, L), jnp.float32).at[m_idx, price].add(qb)
    sell = jnp.zeros((M, L), jnp.float32).at[m_idx, price].add(qs)
    return buy, sell


def _step_fn(cfg: MarketConfig, binning: str, scan_mode: str, state, s):
    M, L = cfg.num_markets, cfg.num_levels
    market_ids = jnp.arange(M, dtype=jnp.int32)[:, None]
    bin_orders = None
    if binning == "scatter":
        bin_orders = lambda sb, p, q: _bin_orders_scatter_jax(sb, p, q, M, L)
    new_state, out = simulate_step(
        cfg, state, s, market_ids, jnp, bin_orders=bin_orders, scan=scan_mode
    )
    return new_state, out


def simulate(cfg: MarketConfig, mode: str = "scan", binning: str = "onehot",
             scan: str = "cumsum") -> SimResult:
    """Run the full simulation. mode: 'scan' | 'per-step'."""
    step = functools.partial(_step_fn, cfg, binning, scan)
    state = initial_state(cfg, jnp)

    if mode == "scan":
        @jax.jit
        def run(state):
            steps = jnp.arange(cfg.num_steps, dtype=jnp.int32)
            final, outs = jax.lax.scan(step, state, steps)
            return final, outs

        final, outs = run(state)
        price_path = outs.price[..., 0].T   # [S, M, 1] -> [M, S]
        volume_path = outs.volume[..., 0].T
    elif mode == "per-step":
        jit_step = jax.jit(step)
        prices, volumes = [], []
        for s in range(cfg.num_steps):
            state, out = jit_step(state, jnp.int32(s))
            # Materialize on host: this is the deliberate per-step device
            # round-trip of the launch-per-step regime.
            prices.append(jax.device_get(out.price))
            volumes.append(jax.device_get(out.volume))
        final = state
        import numpy as np

        price_path = np.concatenate(prices, axis=1)
        volume_path = np.concatenate(volumes, axis=1)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return SimResult(
        bid=final.bid, ask=final.ask,
        last_price=final.last_price, prev_mid=final.prev_mid,
        price_path=jnp.asarray(price_path), volume_path=jnp.asarray(volume_path),
    )
