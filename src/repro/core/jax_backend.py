"""JAX framework baselines (paper §IV: "JAX GPU" and launch-per-step analogue).

Two engines:
  * ``scan``     — the paper's most competitive framework baseline: a fixed
                   chunk of steps fused into one XLA computation via
                   ``jax.lax.scan`` under ``jax.jit``.
  * ``per-step`` — a host loop dispatching one jitted step at a time, with the
                   book round-tripping device memory every step. This is the
                   launch-per-step regime whose Θ(S) dispatch overhead and
                   Θ(S·M·L) memory traffic the paper's persistent kernel
                   eliminates.

Both reuse the shared step semantics in :mod:`repro.core.step`. The session
entry point is :func:`open_chunk_runner`: the chunk length is static while
``(step0, n_valid)`` — and every per-market scenario parameter, via the
:class:`repro.core.params.MarketParams` operand — are runtime values, so one
trace serves any requested step count *and any scenario mixture*, and
repeated warm runs never retrace; the carried state buffers are donated back
to the executable on every call (params are not — they persist across
calls). :func:`simulate` is a compatibility wrapper over a one-session run.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import params as params_mod
from repro.core import session
from repro.core import stats as stats_mod
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.result import SimResult
from repro.core.step import MarketState, resolve_peer_mids, simulate_step


def _bin_orders_scatter_jax(side_buy, price, qty, M, L):
    """Scatter-add binning (.at[].add) — XLA's analogue of atomicAdd."""
    qb = qty * side_buy.astype(jnp.float32)
    qs = qty * (~side_buy).astype(jnp.float32)
    m_idx = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[:, None], price.shape)
    buy = jnp.zeros((M, L), jnp.float32).at[m_idx, price].add(qb)
    sell = jnp.zeros((M, L), jnp.float32).at[m_idx, price].add(qs)
    return buy, sell


def _make_bin_orders(spec: EnsembleSpec, binning: str):
    M, L = spec.num_markets, spec.num_levels
    if binning == "scatter":
        return lambda sb, p, q: _bin_orders_scatter_jax(sb, p, q, M, L)
    return None  # one-hot MXU default inside simulate_step


class JaxChunkRunner(session.ChunkRunner):
    """jit-compiled chunk executor for the two JAX framework regimes."""

    xp = jnp
    compiled = True
    env_traceable = True
    env_runtime_seed = True

    def __init__(self, spec: EnsembleSpec, chunk: int, mode: str,
                 binning: str, scan: str, stats_only: bool = False):
        super().__init__()
        if mode not in ("scan", "per-step"):
            raise ValueError(f"unknown mode {mode!r}")
        self.spec = spec
        self.chunk = int(chunk)
        self.mode = mode
        self.stats_only = bool(stats_only)
        M, L = spec.num_markets, spec.num_levels
        self._market_ids = jnp.arange(M, dtype=jnp.int32)[:, None]
        self._bin_orders = _make_bin_orders(spec, binning)
        self._scan = scan
        self._zero_ext = (jnp.zeros((M, L), jnp.float32),
                          jnp.zeros((M, L), jnp.float32))

        if mode == "scan":
            def chunk_fn(state, stats, params, step0, n_valid,
                         ext_buy, ext_ask):
                self._trace_count += 1  # python side effect: trace-time only
                zeros_ext = jnp.zeros_like(ext_buy)
                # Step-invariant type lattice, hoisted out of the scan.
                atype = params_mod.agent_types(params, spec.num_agents, jnp)
                # Coupling freeze: one gather over the market axis at chunk
                # entry — arbitrageurs see the peer's previous-chunk mid.
                peer_mid = resolve_peer_mids(state.prev_mid,
                                             params.coupling_peer, jnp)

                def body(carry, s):
                    st, acc = carry
                    eb = jnp.where(s == jnp.int32(0), ext_buy, zeros_ext)
                    ea = jnp.where(s == jnp.int32(0), ext_ask, zeros_ext)
                    new_st, out = self._sim_step(st, params, step0 + s,
                                                 eb, ea, atype=atype,
                                                 peer_mid=peer_mid)
                    active = s < n_valid
                    st = MarketState(*(jnp.where(active, new, old)
                                       for new, old in zip(new_st, st)))
                    if self.stats_only:
                        acc = stats_mod.accumulate(acc, out.mid, out.volume,
                                                   active, jnp)
                        return (st, acc), None
                    return (st, acc), (out.price[:, 0], out.volume[:, 0],
                                       out.mid[:, 0])

                steps = jnp.arange(self.chunk, dtype=jnp.int32)
                (final, acc), ys = jax.lax.scan(body, (state, stats), steps)
                if self.stats_only:
                    return final, acc, None
                pp, vp, mp = ys
                return final, None, (pp.T, vp.T, mp.T)

            self._chunk_fn = jax.jit(chunk_fn, donate_argnums=(0, 1))
        else:
            def step_fn(state, params, s, ext_buy, ext_ask, peer_mid):
                self._trace_count += 1
                return self._sim_step(state, params, s, ext_buy, ext_ask,
                                      peer_mid=peer_mid)

            self._step_fn = jax.jit(step_fn, donate_argnums=(0,))
            # stats_only accumulation between dispatches stays on device.
            self._acc_fn = jax.jit(
                lambda acc, mid, vol: stats_mod.accumulate(
                    acc, mid, vol, True, jnp),
                donate_argnums=(0,))

    def _sim_step(self, state, params, s, ext_buy, ext_ask, atype=None,
                  seed=None, peer_mid=None):
        """The single ``simulate_step`` entry shared by the Session chunk
        path (both modes) and the RL env's functional core."""
        return simulate_step(
            self.spec, state, s, self._market_ids, jnp,
            bin_orders=self._bin_orders, scan=self._scan,
            ext_buy=ext_buy, ext_ask=ext_ask, params=params, atype=atype,
            seed=seed, peer_mid=peer_mid,
        )

    def env_step_fn(self):
        """Pure per-step core for :class:`repro.env.MarketEnv` — traceable,
        with a runtime ``seed`` operand (counter RNG)."""
        def step_core(market, params, t, ext_buy, ext_ask, seed, aux):
            new_state, out = self._sim_step(
                market, params, jnp.asarray(t).astype(jnp.int32),
                ext_buy, ext_ask, seed=seed,
                peer_mid=resolve_peer_mids(market.prev_mid,
                                           params.coupling_peer, jnp))
            return new_state, out, aux

        return step_core

    def _empty_batch(self) -> session.StepBatch:
        empty = jnp.zeros((self.spec.num_markets, 0), jnp.float32)
        return session.StepBatch(price=empty, volume=empty, mid=empty)

    def run(self, state: MarketState, params: MarketParams, aux,
            step0: int, n: int, ext,
            stats=None) -> Tuple[MarketState, Any, session.StepBatch, Any]:
        eb, ea = self._zero_ext if ext is None else ext
        if self.mode == "scan":
            state, stats, paths = self._chunk_fn(
                state, stats if self.stats_only else None, params,
                jnp.int32(step0), jnp.int32(n), eb, ea)
            if self.stats_only:
                return state, aux, self._empty_batch(), stats
            pp, vp, mp = paths
            return state, aux, session.StepBatch(
                price=pp[:, :n], volume=vp[:, :n], mid=mp[:, :n]), None

        # Launch-per-step regime: one jitted dispatch per step, outputs
        # materialized on host each step (the deliberate device round-trip).
        zeros = self._zero_ext[0]
        # Same coupling-freeze boundary as the scan/kernel regimes: the
        # peer column is gathered once from the chunk-entry state and held
        # fixed across this chunk's dispatches.
        peer_mid = resolve_peer_mids(state.prev_mid, params.coupling_peer,
                                     jnp)
        prices, volumes, mids = [], [], []
        for k in range(n):
            keep = k == 0 and ext is not None
            state, out = self._step_fn(
                state, params, jnp.int32(step0 + k),
                eb if keep else zeros, ea if keep else zeros, peer_mid)
            if self.stats_only:
                stats = self._acc_fn(stats, out.mid, out.volume)
            else:
                prices.append(jax.device_get(out.price))
                volumes.append(jax.device_get(out.volume))
                mids.append(jax.device_get(out.mid))
        if self.stats_only:
            return state, aux, self._empty_batch(), stats
        batch = session.StepBatch(
            price=jnp.asarray(np.concatenate(prices, axis=1)),
            volume=jnp.asarray(np.concatenate(volumes, axis=1)),
            mid=jnp.asarray(np.concatenate(mids, axis=1)),
        )
        return state, aux, batch, None


def open_chunk_runner(spec, chunk: int, mode: str = "scan",
                      binning: str = "onehot",
                      scan: str = "cumsum",
                      stats_only: bool = False) -> JaxChunkRunner:
    """Session factory for the JAX framework baselines."""
    return JaxChunkRunner(EnsembleSpec.coerce(spec), chunk, mode=mode,
                          binning=binning, scan=scan, stats_only=stats_only)


def simulate(cfg, mode: str = "scan", binning: str = "onehot",
             scan: str = "cumsum") -> SimResult:
    """Compatibility wrapper: one-session run over ``num_steps``."""
    spec = EnsembleSpec.coerce(cfg)
    runner = open_chunk_runner(
        spec, min(session.DEFAULT_CHUNK, spec.num_steps),
        mode=mode, binning=binning, scan=scan)
    return session.run_runner_to_result(runner, spec)
