"""Stateless counter-based RNG (paper §III-G), adapted for TPU.

The paper uses SplitMix64 keyed on ``(seed, gid, step, channel)``. TPU vector
units have no 64-bit integer path, so the production generator here is
``kinetic_hash32`` — the same *pattern* (stateless, splittable, pure function
of coordinates) built from chained 32-bit avalanche mixers (lowbias32 /
murmur3-style finalizers). True SplitMix64 is implemented in NumPy uint64 for
the statistical-equivalence reference backend, mirroring the paper's
CPU-reference-with-different-RNG comparison.

All functions are array-module polymorphic: pass ``xp=numpy`` or
``xp=jax.numpy`` (including inside Pallas kernel bodies). Given identical
inputs they produce bitwise-identical uint32 streams in every backend.
"""
from __future__ import annotations

import numpy as np

# uint32 constants (lowbias32 by C. Wellons + murmur3/xxhash primes)
_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_GOLDEN = 0x9E3779B9
_K_GID = 0x85EBCA6B
_K_STEP = 0xC2B2AE35
_K_CHAN = 0x27D4EB2F


def _u32(xp, value):
    if isinstance(value, int):
        # Pre-wrap Python ints: jnp.asarray would reject ints > int32 max.
        value = np.uint32(value & 0xFFFFFFFF)
    return xp.asarray(value).astype(xp.uint32)


def mix32(x, xp):
    """lowbias32 avalanche finalizer over uint32 arrays."""
    c1 = _u32(xp, _M1)
    c2 = _u32(xp, _M2)
    x = x ^ (x >> 16)
    x = x * c1
    x = x ^ (x >> 15)
    x = x * c2
    x = x ^ (x >> 16)
    return x


def kinetic_hash32(seed, gid, step, channel, xp):
    """Pure function of (seed, gid, step, channel) -> uint32.

    Absorbs each key coordinate with a distinct odd multiplier, applying a
    full avalanche between absorptions (two multiply-xorshift rounds each),
    analogous to SplitMix64's stream splitting.
    """
    seed = _u32(xp, seed)
    gid = _u32(xp, gid)
    step = _u32(xp, step)
    channel = _u32(xp, channel)
    x = seed ^ _u32(xp, _GOLDEN)
    x = mix32(x + gid * _u32(xp, _K_GID), xp)
    x = mix32(x + step * _u32(xp, _K_STEP), xp)
    x = mix32(x + channel * _u32(xp, _K_CHAN), xp)
    return x


def uniform32(seed, gid, step, channel, xp):
    """Uniform float32 in [0, 1) with exactly 24 random mantissa bits.

    Using the top 24 bits keeps the uint32->float32 conversion exact and
    guarantees the result is strictly below 1.0 (a raw 32-bit conversion can
    round up to 2**32 and yield exactly 1.0, which would overflow the
    integer-quantity draw q = 1 + floor(u * q_max)).
    """
    bits = kinetic_hash32(seed, gid, step, channel, xp)
    hi24 = (bits >> 8).astype(xp.float32)
    return hi24 * xp.float32(2.0 ** -24)


# ---------------------------------------------------------------------------
# SplitMix64 (paper Eq. 8-10) — NumPy-only, used by the `numpy-splitmix64`
# reference backend for the statistical-equivalence experiment.
# ---------------------------------------------------------------------------
_SM64_1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_2 = np.uint64(0x94D049BB133111EB)
_SM64_G = np.uint64(0x9E3779B97F4A7C15)


def splitmix64(coord: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer of a uint64 counter coordinate (paper Eq. 8-10)."""
    z = np.asarray(coord, dtype=np.uint64)
    with np.errstate(over="ignore"):  # modular uint64 arithmetic by design
        z = (z ^ (z >> np.uint64(30))) * _SM64_1
        z = (z ^ (z >> np.uint64(27))) * _SM64_2
        return z ^ (z >> np.uint64(31))


def splitmix64_coord(seed, gid, step, channel) -> np.ndarray:
    """Counter coordinate hash(gid, step, channel, seed) (paper Eq. 7)."""
    gid = np.asarray(gid, dtype=np.uint64)
    step = np.asarray(step, dtype=np.uint64)
    channel = np.asarray(channel, dtype=np.uint64)
    seed = np.asarray(seed, dtype=np.uint64)
    with np.errstate(over="ignore"):  # modular uint64 arithmetic by design
        coord = seed * _SM64_G + gid
        coord = splitmix64(coord + step * _SM64_1)
        coord = coord + channel * _SM64_2
    return coord


def splitmix64_uniform(seed, gid, step, channel) -> np.ndarray:
    """Uniform float32 in [0,1) from SplitMix64 (top 24 bits)."""
    bits = splitmix64(splitmix64_coord(seed, gid, step, channel))
    hi24 = (bits >> np.uint64(40)).astype(np.float32)
    return hi24 * np.float32(2.0 ** -24)
