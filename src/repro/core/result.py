"""Simulation result container + aggregate statistics (paper Table II/Fig 7)."""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

# Arrays may be host numpy or device (jax) arrays depending on the backend.
Array = Any


class SimResult(NamedTuple):
    bid: Array          # float32[M, L] final resting bids
    ask: Array          # float32[M, L] final resting asks
    last_price: Array   # float32[M, 1]
    prev_mid: Array     # float32[M, 1]
    price_path: Array   # float32[M, S] clearing-price path
    volume_path: Array  # float32[M, S] per-step transacted volume

    def to_numpy(self) -> "SimResult":
        return SimResult(*(np.asarray(x) for x in self))

    # ---- aggregate market statistics (Table II) ----
    def mean_clearing_price(self) -> float:
        r = self.to_numpy()
        w = r.volume_path > 0
        tot = w.sum()
        if tot == 0:
            return float("nan")
        return float((r.price_path * w).sum() / tot)

    def volume_per_market(self) -> float:
        r = self.to_numpy()
        return float(r.volume_path.sum(axis=1).mean())

    def trade_count(self) -> float:
        r = self.to_numpy()
        return float((r.volume_path > 0).sum(axis=1).mean())

    # ---- stylized-fact statistics (Fig 7) ----
    def returns(self) -> np.ndarray:
        p = np.asarray(self.price_path)
        return np.diff(p, axis=1)

    def volatility(self) -> float:
        return float(self.returns().std())

    def excess_kurtosis(self) -> float:
        r = self.returns().ravel()
        r = r - r.mean()
        v = (r ** 2).mean()
        if v == 0:
            return 0.0
        return float((r ** 4).mean() / v ** 2 - 3.0)

    def volume_volatility_corr(self) -> float:
        """Mean-over-markets Pearson correlation of |returns| with volume.

        The classic volume/volatility stylized fact: per market, corr(
        ``|r_t|``, ``volume_t``) for ``t in [1, S)`` (volume at the step the
        return realizes). Markets with a degenerate (zero-variance) series
        are excluded; returns NaN if every market is degenerate.
        """
        r = np.abs(self.returns())                       # [M, S-1]
        v = np.asarray(self.volume_path)[:, 1:]          # [M, S-1]
        rc = r - r.mean(axis=1, keepdims=True)
        vc = v - v.mean(axis=1, keepdims=True)
        num = (rc * vc).sum(axis=1)
        denom = np.sqrt((rc * rc).sum(axis=1) * (vc * vc).sum(axis=1))
        with np.errstate(invalid="ignore", divide="ignore"):
            corr = num / denom
        return float(np.nanmean(corr))

    def autocorrelation(self, lags: int = 20, absolute: bool = False) -> np.ndarray:
        """Mean-over-markets ACF of returns (or |returns|) up to ``lags``."""
        r = self.returns()
        if absolute:
            r = np.abs(r)
        r = r - r.mean(axis=1, keepdims=True)
        denom = (r * r).sum(axis=1)
        out = np.zeros(lags + 1)
        out[0] = 1.0
        for k in range(1, lags + 1):
            num = (r[:, k:] * r[:, :-k]).sum(axis=1)
            with np.errstate(invalid="ignore", divide="ignore"):
                vals = num / denom
            out[k] = float(np.nanmean(vals))
        return out
