# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.core.session import (  # noqa: F401
    Engine,
    ExternalOrders,
    Session,
    StepBatch,
    backend_available,
)
