"""One simulation step (paper Alg. 1 lines 5-22), shared by all backends.

``simulate_step`` is the complete per-step semantics: scenario overlay ->
microstructure state estimation -> agent decisions -> order aggregation ->
cooperative clearing -> residual book update. Backends differ only in *how*
they bin orders (scatter vs one-hot matmul) and how they drive the S-step
loop (host loop, lax.scan, or a persistent Pallas grid) — never in semantics.

Scenario effects are selected by per-market :class:`repro.core.params
.MarketParams` operands and applied with branch-free ``where`` masks on the
traced step index, so *every* scenario — and every per-market mixture of
scenarios — compiles to the same fused kernel as the baseline: no
data-dependent control flow ever reaches the Pallas grid, and no scenario
value is baked into a trace. Legacy scalar-config callers (the one-shot
kernels, the jitted oracle) omit ``params``; the constants are then derived
from ``cfg`` inside the trace, bitwise-identical to the pre-ensemble code
on every counter-RNG backend (the stateful ``numpy-pcg64`` reference —
statistical-equivalence only — shifted by the fixed five-channel draw
schedule; see :mod:`repro.core.agents`).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.core import agents, auction
from repro.core import params as params_mod
from repro.core.params import MarketParams


class MarketState(NamedTuple):
    bid: "array"        # float32[M, L] resting bid quantities
    ask: "array"        # float32[M, L] resting ask quantities
    last_price: "array" # float32[M, 1]
    prev_mid: "array"   # float32[M, 1]


class StepOutput(NamedTuple):
    price: "array"   # float32[M, 1] clearing price (or last price if no cross)
    volume: "array"  # float32[M, 1] transacted volume
    mid: "array"     # float32[M, 1] mid price used for decisions


def initial_state(cfg, xp) -> MarketState:
    """Opening state for a ``MarketConfig`` or ``EnsembleSpec`` (both expose
    per-market ``initial_books`` plus the static shape fields)."""
    bid, ask = cfg.initial_books(xp)
    m0 = xp.float32(cfg.mid0)
    ones = xp.ones((cfg.num_markets, 1), dtype=xp.float32)
    return MarketState(bid=bid, ask=ask, last_price=ones * m0, prev_mid=ones * m0)


def bin_orders_onehot(side_buy, price, qty, L, xp, agent_chunk=None):
    """Order aggregation as a one-hot contraction (TPU/MXU idiom).

    BUY[m, l] = sum_a qty[m, a] * [price[m, a] == l & side_buy[m, a]]

    This is the TPU-native replacement for the paper's shared-memory
    atomicAdd histogram; exact-integer f32 adds keep it bitwise-identical to
    scatter-based binning.

    ``agent_chunk`` bounds the [M, Ac, L] one-hot intermediate (the dominant
    VMEM term inside the persistent kernel) by accumulating the contraction
    over static slices of the agent axis. Because every partial sum is an
    exact integer in f32, the result is bitwise-identical for any chunking.
    """
    levels = xp.arange(L, dtype=xp.int32)
    qb = qty * side_buy.astype(xp.float32)
    qs = qty * (~side_buy).astype(xp.float32)
    A = price.shape[-1]
    if not agent_chunk or agent_chunk >= A:
        onehot = (price[..., None] == levels).astype(xp.float32)  # [M, A, L]
        return (xp.einsum("ma,mal->ml", qb, onehot),
                xp.einsum("ma,mal->ml", qs, onehot))
    M = price.shape[0]
    buy = xp.zeros((M, L), dtype=xp.float32)
    sell = xp.zeros((M, L), dtype=xp.float32)
    for a0 in range(0, A, agent_chunk):
        sl = slice(a0, min(a0 + agent_chunk, A))
        onehot = (price[:, sl, None] == levels).astype(xp.float32)
        buy = buy + xp.einsum("ma,mal->ml", qb[:, sl], onehot)
        sell = sell + xp.einsum("ma,mal->ml", qs[:, sl], onehot)
    return buy, sell


def apply_scenario_shock(params: MarketParams, bid, step_idx, xp):
    """Flash-crash liquidity withdrawal (scenario overlay, branch-free).

    At each market's shock step a per-market fraction ``shock_cancel`` of
    every resting bid level is cancelled — buy-side support vanishes just as
    panicking agents market-sell (see :func:`repro.core.agents.decide`).
    ``floor`` keeps the book integer-valued in f32, preserving the
    exact-add bitwise-identity argument (paper §IV-B). Markets with the
    shock disabled (``shock_step < 0``) or scheduled elsewhere see an
    all-False mask — and ``floor(bid * 0) == 0`` — so the overlay is a
    bitwise no-op for them; the same trace serves every schedule. When the
    cancel column is a *concrete* host array of zeros (the NumPy reference
    on no-shock ensembles) the whole overlay is elided outright —
    bitwise-identical, mirroring the ``skip_shock`` elision in
    :func:`repro.core.agents.decide`.
    """
    if (isinstance(params.shock_cancel, np.ndarray)
            and not params.shock_cancel.any()):
        return bid
    f32 = xp.float32
    shock_step = xp.asarray(params.shock_step, dtype=xp.int32)   # [M, 1]
    shock_cancel = xp.asarray(params.shock_cancel, dtype=f32)    # [M, 1]
    at_shock = xp.asarray(step_idx).astype(xp.int32) == shock_step
    cancelled = xp.floor(bid * shock_cancel)
    return xp.where(at_shock, bid - cancelled, bid)


def resolve_peer_mids(prev_mid, coupling_peer, xp, market_ids=None):
    """Gather each market's coupled peer mid over the market axis.

    ``prev_mid`` is the full ``[M, 1]`` (global-axis) mid column at a chunk
    boundary; ``coupling_peer`` holds global peer indices with ``< 0``
    meaning self. ``market_ids`` supplies each row's own global index
    (defaults to ``arange(M)`` — correct whenever ``prev_mid`` spans the
    whole ensemble). Chunk drivers call this once per chunk on the entry
    state, so the value arbitrageurs see is the peer's *previous-chunk*
    mid — frozen at identical boundaries on every backend, which is what
    makes the coupled trajectories bitwise-comparable. The sharded runner
    reconstructs the full column first via a ring halo exchange
    (``lax.ppermute``) and then applies this same gather shard-locally.
    """
    prev_mid = xp.asarray(prev_mid, dtype=xp.float32)
    peer = xp.reshape(xp.asarray(coupling_peer, dtype=xp.int32), (-1, 1))
    if market_ids is None:
        own = xp.arange(prev_mid.shape[0], dtype=xp.int32)[:, None]
    else:
        own = xp.reshape(xp.asarray(market_ids, dtype=xp.int32), (-1, 1))
    resolved = xp.where(peer < xp.int32(0), own, peer)
    return xp.take_along_axis(prev_mid, resolved, axis=0)


def simulate_step(
    cfg,
    state: MarketState,
    step_idx,
    market_ids,
    xp,
    bin_orders: Callable = None,
    scan: str = "cumsum",
    uniform_fn: Callable = None,
    ext_buy=None,
    ext_ask=None,
    agent_chunk=None,
    params: Optional[MarketParams] = None,
    atype=None,
    seed=None,
    peer_mid=None,
):
    """Advance all markets one step. Returns (MarketState, StepOutput).

    ``cfg`` supplies only the static trace parameters (``num_agents``,
    ``num_levels``, ``seed``) — a ``MarketConfig`` or an ``EnsembleSpec``.
    ``params`` carries every scenario-varying value as per-market ``[M, 1]``
    runtime operands; when omitted (legacy scalar-config callers) it is
    derived from ``cfg`` as broadcastable ``[1, 1]`` constants inside the
    trace, which folds to exactly the pre-ensemble computation.

    ``ext_buy``/``ext_ask`` (optional float32[M, L]) are externally injected
    order quantities — the session layer's reserved agent slot for RL-style
    stepping. They join the incoming flow after agent binning, exactly as if
    one extra agent had quoted them this step. Zero arrays are a bitwise
    no-op (exact-integer f32 adds), so gated injection never perturbs the
    stream; ``None`` keeps pre-session traces byte-identical.

    ``agent_chunk`` is forwarded to the default one-hot binning (a pure
    VMEM-footprint knob — bitwise-invisible; see :func:`bin_orders_onehot`).
    ``atype`` optionally carries the precomputed (step-invariant) per-market
    agent-type lattice so loop drivers hoist it out of the step loop.
    ``seed`` optionally overrides the counter-RNG seed at runtime (traced
    ok — see :func:`repro.core.agents.decide`); ``None`` keeps the
    trace-static ``cfg.seed`` bitwise-unchanged.

    ``peer_mid`` (optional float32[M, 1]) is the coupled peer market's
    *frozen* mid feeding arbitrageur agents — chunk drivers compute it once
    per chunk from the entry ``prev_mid`` (see
    :func:`resolve_peer_mids`) so every backend freezes coupling at the
    same boundaries. ``None`` falls back to ``state.prev_mid``
    (self-coupling, per step) — value-identical whenever no arbitrageurs
    are populated, which is every legacy call site.
    """
    if params is None:
        # Built with xp, not host numpy: Pallas kernel bodies reject
        # captured host-array constants, so the legacy traced entries embed
        # xp constants (and keep the dead shock selects for XLA to chew
        # on). The concrete-zero elisions fire where they pay — the NumPy
        # host-loop backends, whose session params are host arrays.
        params = params_mod.scalar_params(cfg, xp)
    if bin_orders is None:
        bin_orders = lambda s, p, q: bin_orders_onehot(
            s, p, q, cfg.num_levels, xp, agent_chunk=agent_chunk)
    f32 = xp.float32

    # Scenario overlay (before quoting: the withdrawal moves the mid too).
    resting_bid = apply_scenario_shock(params, state.bid, step_idx, xp)

    # Phase 2: microstructure state estimation (paper Alg.1 lines 5-7)
    _, _, mid = auction.best_quotes(resting_bid, state.ask, state.last_price, xp)

    # Resting-book imbalance for the HFT archetype: exact-integer f32 sums
    # (book mass stays far below 2^24), one IEEE division — deterministic
    # and bitwise-identical across backends, chunkings, and shardings.
    sum_bid = xp.sum(resting_bid, axis=-1, keepdims=True)
    sum_ask = xp.sum(state.ask, axis=-1, keepdims=True)
    depth = sum_bid + sum_ask
    safe_depth = xp.where(depth > f32(0.0), depth, f32(1.0))  # no 0/0 (numpy)
    imbalance = xp.where(depth > f32(0.0), (sum_bid - sum_ask) / safe_depth,
                         xp.zeros_like(depth))

    # Phase 3: agent decisions + order aggregation (lines 8-13)
    agent_ids = xp.arange(cfg.num_agents, dtype=xp.int32)
    side_buy, price, qty = agents.decide(
        cfg, params, mid, state.prev_mid, step_idx, market_ids, agent_ids, xp,
        uniform_fn=uniform_fn, atype=atype, seed=seed,
        imbalance=imbalance, peer_mid=peer_mid,
    )
    buy, sell = bin_orders(side_buy, price, qty)

    # Incoming orders join the resting book; clearing runs over the total.
    total_buy = resting_bid + buy
    total_ask = state.ask + sell
    if ext_buy is not None:
        total_buy = total_buy + ext_buy
    if ext_ask is not None:
        total_ask = total_ask + ext_ask

    # Phase 4: cooperative parallel clearing (lines 14-21)
    cleared = auction.clear(total_buy, total_ask, xp, scan=scan)

    # Phase 5: residual book update + state persistence (line 22)
    executed = cleared["volume"] > f32(0.0)
    new_last = xp.where(
        executed, cleared["p_star"].astype(xp.float32), state.last_price
    )
    new_state = MarketState(
        bid=cleared["new_bid"],
        ask=cleared["new_ask"],
        last_price=new_last,
        prev_mid=mid,
    )
    out = StepOutput(price=new_last, volume=cleared["volume"], mid=mid)
    return new_state, out
