"""One simulation step (paper Alg. 1 lines 5-22), shared by all backends.

``simulate_step`` is the complete per-step semantics: scenario overlay ->
microstructure state estimation -> agent decisions -> order aggregation ->
cooperative clearing -> residual book update. Backends differ only in *how*
they bin orders (scatter vs one-hot matmul) and how they drive the S-step
loop (host loop, lax.scan, or a persistent Pallas grid) — never in semantics.

Scenario effects are selected by static config fields and applied with
branch-free ``where`` masks on the traced step index, so a scenario config
compiles to the same fused kernel as the baseline — no data-dependent
control flow ever reaches the Pallas grid.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

from repro.core import agents, auction
from repro.core.config import MarketConfig


class MarketState(NamedTuple):
    bid: "array"        # float32[M, L] resting bid quantities
    ask: "array"        # float32[M, L] resting ask quantities
    last_price: "array" # float32[M, 1]
    prev_mid: "array"   # float32[M, 1]


class StepOutput(NamedTuple):
    price: "array"   # float32[M, 1] clearing price (or last price if no cross)
    volume: "array"  # float32[M, 1] transacted volume
    mid: "array"     # float32[M, 1] mid price used for decisions


def initial_state(cfg: MarketConfig, xp, market_offset: int = 0) -> MarketState:
    bid, ask = cfg.initial_books(xp)
    m0 = xp.float32(cfg.mid0)
    ones = xp.ones((cfg.num_markets, 1), dtype=xp.float32)
    return MarketState(bid=bid, ask=ask, last_price=ones * m0, prev_mid=ones * m0)


def bin_orders_onehot(side_buy, price, qty, L, xp, agent_chunk=None):
    """Order aggregation as a one-hot contraction (TPU/MXU idiom).

    BUY[m, l] = sum_a qty[m, a] * [price[m, a] == l & side_buy[m, a]]

    This is the TPU-native replacement for the paper's shared-memory
    atomicAdd histogram; exact-integer f32 adds keep it bitwise-identical to
    scatter-based binning.

    ``agent_chunk`` bounds the [M, Ac, L] one-hot intermediate (the dominant
    VMEM term inside the persistent kernel) by accumulating the contraction
    over static slices of the agent axis. Because every partial sum is an
    exact integer in f32, the result is bitwise-identical for any chunking.
    """
    levels = xp.arange(L, dtype=xp.int32)
    qb = qty * side_buy.astype(xp.float32)
    qs = qty * (~side_buy).astype(xp.float32)
    A = price.shape[-1]
    if not agent_chunk or agent_chunk >= A:
        onehot = (price[..., None] == levels).astype(xp.float32)  # [M, A, L]
        return (xp.einsum("ma,mal->ml", qb, onehot),
                xp.einsum("ma,mal->ml", qs, onehot))
    M = price.shape[0]
    buy = xp.zeros((M, L), dtype=xp.float32)
    sell = xp.zeros((M, L), dtype=xp.float32)
    for a0 in range(0, A, agent_chunk):
        sl = slice(a0, min(a0 + agent_chunk, A))
        onehot = (price[:, sl, None] == levels).astype(xp.float32)
        buy = buy + xp.einsum("ma,mal->ml", qb[:, sl], onehot)
        sell = sell + xp.einsum("ma,mal->ml", qs[:, sl], onehot)
    return buy, sell


def apply_scenario_shock(cfg: MarketConfig, bid, step_idx, xp):
    """Flash-crash liquidity withdrawal (scenario overlay, branch-free).

    At the shock step a static fraction ``shock_cancel`` of every resting bid
    level is cancelled — buy-side support vanishes just as panicking agents
    market-sell (see :func:`repro.core.agents.decide`). ``floor`` keeps the
    book integer-valued in f32, preserving the exact-add bitwise-identity
    argument (paper §IV-B). The static python guard means baseline configs
    trace the identical graph as before.
    """
    if cfg.shock_cancel <= 0.0 or cfg.shock_step < 0:
        return bid
    f32 = xp.float32
    at_shock = xp.asarray(step_idx).astype(xp.int32) == xp.int32(cfg.shock_step)
    cancelled = xp.floor(bid * f32(cfg.shock_cancel))
    return xp.where(at_shock, bid - cancelled, bid)


def simulate_step(
    cfg: MarketConfig,
    state: MarketState,
    step_idx,
    market_ids,
    xp,
    bin_orders: Callable = None,
    scan: str = "cumsum",
    uniform_fn: Callable = None,
    ext_buy=None,
    ext_ask=None,
    agent_chunk=None,
):
    """Advance all markets one step. Returns (MarketState, StepOutput).

    ``ext_buy``/``ext_ask`` (optional float32[M, L]) are externally injected
    order quantities — the session layer's reserved agent slot for RL-style
    stepping. They join the incoming flow after agent binning, exactly as if
    one extra agent had quoted them this step. Zero arrays are a bitwise
    no-op (exact-integer f32 adds), so gated injection never perturbs the
    stream; ``None`` keeps pre-session traces byte-identical.

    ``agent_chunk`` is forwarded to the default one-hot binning (a pure
    VMEM-footprint knob — bitwise-invisible; see :func:`bin_orders_onehot`).
    """
    if bin_orders is None:
        bin_orders = lambda s, p, q: bin_orders_onehot(
            s, p, q, cfg.num_levels, xp, agent_chunk=agent_chunk)
    f32 = xp.float32

    # Scenario overlay (before quoting: the withdrawal moves the mid too).
    resting_bid = apply_scenario_shock(cfg, state.bid, step_idx, xp)

    # Phase 2: microstructure state estimation (paper Alg.1 lines 5-7)
    _, _, mid = auction.best_quotes(resting_bid, state.ask, state.last_price, xp)

    # Phase 3: agent decisions + order aggregation (lines 8-13)
    agent_ids = xp.arange(cfg.num_agents, dtype=xp.int32)
    side_buy, price, qty = agents.decide(
        cfg, mid, state.prev_mid, step_idx, market_ids, agent_ids, xp,
        uniform_fn=uniform_fn,
    )
    buy, sell = bin_orders(side_buy, price, qty)

    # Incoming orders join the resting book; clearing runs over the total.
    total_buy = resting_bid + buy
    total_ask = state.ask + sell
    if ext_buy is not None:
        total_buy = total_buy + ext_buy
    if ext_ask is not None:
        total_ask = total_ask + ext_ask

    # Phase 4: cooperative parallel clearing (lines 14-21)
    cleared = auction.clear(total_buy, total_ask, xp, scan=scan)

    # Phase 5: residual book update + state persistence (line 22)
    executed = cleared["volume"] > f32(0.0)
    new_last = xp.where(
        executed, cleared["p_star"].astype(xp.float32), state.last_price
    )
    new_state = MarketState(
        bid=cleared["new_bid"],
        ask=cleared["new_ask"],
        last_price=new_last,
        prev_mid=mid,
    )
    out = StepOutput(price=new_last, volume=cleared["volume"], mid=mid)
    return new_state, out
