"""Sharded, crash-safe checkpointing with async writes + elastic restore.

Layout (per step):
    <dir>/step_000040/
        manifest.json            # tree structure, shapes, dtypes, shard map
        shard_00000_of_00001.npz # per-host flat arrays
    <dir>/LATEST                 # atomic pointer (renamed into place)

Design points for 1000+-node operation:
  * every host writes only its own shard file; the manifest is written by
    host 0 after all shards exist (two-phase commit: a step directory is
    valid iff manifest.json exists and LATEST points at it);
  * writes are atomic (tmp + rename) so a node failure mid-write never
    corrupts the previous checkpoint;
  * async mode hands the arrays to a writer thread so the train loop only
    blocks on the *previous* save (standard checkpoint/compute overlap);
  * restore accepts a different host count than save (elastic restart):
    arrays are re-assembled from any shard layout and re-sharded to the
    current mesh by the caller's device_put.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

# MarketState leaf names packed by session_tree / snapshot_from_tree.
_SESSION_ARRAY_FIELDS = ("bid", "ask", "last_price", "prev_mid")
# Snapshot keys holding dicts of arrays (packed as subtrees, not JSON meta).
_SESSION_ARRAY_SUBTREES = ("params", "stats", "init")


def session_tree(snapshot: Dict[str, Any]) -> Dict[str, Any]:
    """Pack a ``Session.snapshot()`` dict into a checkpointable pytree.

    Array leaves (the book state, the per-market parameter operands, and
    the ``stats_only`` accumulators when present) go in as-is; non-array
    metadata — the step cursor and any stateful-RNG payload (PCG64 state
    holds 128-bit ints that numpy cannot represent) — is JSON-encoded into
    a unicode scalar leaf.
    """
    meta = {k: v for k, v in snapshot.items()
            if k not in _SESSION_ARRAY_FIELDS
            and k not in _SESSION_ARRAY_SUBTREES}
    tree = {
        "state": {k: np.asarray(snapshot[k]) for k in _SESSION_ARRAY_FIELDS},
        "meta": np.asarray(json.dumps(meta)),
    }
    for sub in _SESSION_ARRAY_SUBTREES:
        if snapshot.get(sub) is not None:
            tree[sub] = {k: np.asarray(v) for k, v in snapshot[sub].items()}
    return tree


def snapshot_from_tree(tree: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`session_tree` (for ``Session.restore``)."""
    snap: Dict[str, Any] = dict(tree["state"])
    snap.update(json.loads(str(tree["meta"])))
    for sub in _SESSION_ARRAY_SUBTREES:
        if sub in tree:
            snap[sub] = dict(tree[sub])
    return snap


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/#{i}")
    else:
        yield prefix, tree


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(rebuild(v) for _, v in items)
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


class CheckpointManager:
    def __init__(self, directory, *, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree) -> None:
        """Save a pytree (blocking on the previous async save only)."""
        host_arrays = {}
        for path, leaf in _flatten(tree):
            arr = np.asarray(leaf)
            host_arrays[path] = arr
        self.wait()
        if self.async_write:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_arrays), daemon=True)
            self._pending.start()
        else:
            self._write(step, host_arrays)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_arrays: Dict[str, np.ndarray]) -> None:
        sdir = self._step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)
        shard_name = f"shard_{self.host_id:05d}_of_{self.num_hosts:05d}.npz"
        fd, tmp = tempfile.mkstemp(dir=sdir, suffix=".tmp")
        os.close(fd)
        np.savez(tmp, **{k.replace("/", "|"): v
                         for k, v in host_arrays.items()})
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp,
                   sdir / shard_name)
        if self.host_id == 0:
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host_arrays.items()},
            }
            mtmp = sdir / "manifest.json.tmp"
            mtmp.write_text(json.dumps(manifest))
            os.replace(mtmp, sdir / "manifest.json")
            ltmp = self.dir / "LATEST.tmp"
            ltmp.write_text(sdir.name)
            os.replace(ltmp, self.dir / "LATEST")
            self._gc()

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*")
                       if (p / "manifest.json").exists())
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        sdir = self.dir / ptr.read_text().strip()
        if not (sdir / "manifest.json").exists():
            return None
        return int(sdir.name.split("_")[1])

    def restore(self, step: Optional[int] = None):
        """Load the pytree (elastic: any current host count may read)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        sdir = self._step_dir(step)
        flat: Dict[str, np.ndarray] = {}
        for shard in sorted(sdir.glob("shard_*.npz")):
            with np.load(shard) as z:
                for k in z.files:
                    flat[k.replace("|", "/")] = z[k]
        manifest = json.loads((sdir / "manifest.json").read_text())
        missing = set(manifest["leaves"]) - set(flat)
        if missing:
            raise IOError(f"checkpoint step {step} missing leaves: "
                          f"{sorted(missing)[:5]}...")
        return _unflatten(flat)
