"""Sharded, crash-safe checkpointing with async writes + elastic restore.

Layout (per step):
    <dir>/step_000040/
        manifest.json            # tree structure, shapes, dtypes, shard map
        shard_00000_of_00001.npz # per-host flat arrays
    <dir>/LATEST                 # atomic pointer (renamed into place)

Design points for 1000+-node operation:
  * every host writes only its own shard file; the manifest is written by
    host 0 after all shards exist (two-phase commit: a step directory is
    valid iff manifest.json exists and LATEST points at it);
  * writes are atomic (tmp + rename) so a node failure mid-write never
    corrupts the previous checkpoint;
  * async mode hands the arrays to a writer thread so the train loop only
    blocks on the *previous* save (standard checkpoint/compute overlap);
  * restore accepts a different host count than save (elastic restart):
    arrays are re-assembled from any shard layout and re-sharded to the
    current mesh by the caller's device_put.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

# MarketState leaf names packed by session_tree / snapshot_from_tree.
_SESSION_ARRAY_FIELDS = ("bid", "ask", "last_price", "prev_mid")
# Snapshot keys holding dicts of arrays (packed as subtrees, not JSON meta).
_SESSION_ARRAY_SUBTREES = ("params", "stats", "init")

#: On-disk session-checkpoint format version (the JSON meta leaf carries it).
FORMAT_VERSION = 1


class CheckpointError(Exception):
    """Base class for typed checkpoint failures."""


class CheckpointCorruptError(CheckpointError, IOError):
    """The on-disk payload is damaged (truncated / bit-flipped / unparseable).

    Always names the offending file or leaf. Corrupt data must never load
    silently — callers fall back to an earlier step (see
    ``repro.ops.chaos``) or fail loudly.
    """


class CheckpointVersionError(CheckpointError, ValueError):
    """The checkpoint was written by an incompatible format version."""


class CheckpointShapeError(CheckpointError, ValueError):
    """A restored leaf's shape disagrees with the live session, with the
    offending config field (num_markets / num_levels / num_agents) named."""


def session_tree(snapshot: Dict[str, Any]) -> Dict[str, Any]:
    """Pack a ``Session.snapshot()`` dict into a checkpointable pytree.

    Array leaves (the book state, the per-market parameter operands, and
    the ``stats_only`` accumulators when present) go in as-is; non-array
    metadata — the step cursor and any stateful-RNG payload (PCG64 state
    holds 128-bit ints that numpy cannot represent) — is JSON-encoded into
    a unicode scalar leaf.
    """
    meta = {k: v for k, v in snapshot.items()
            if k not in _SESSION_ARRAY_FIELDS
            and k not in _SESSION_ARRAY_SUBTREES}
    meta["format_version"] = FORMAT_VERSION
    tree = {
        "state": {k: np.asarray(snapshot[k]) for k in _SESSION_ARRAY_FIELDS},
        "meta": np.asarray(json.dumps(meta)),
    }
    for sub in _SESSION_ARRAY_SUBTREES:
        if snapshot.get(sub) is not None:
            tree[sub] = {k: np.asarray(v) for k, v in snapshot[sub].items()}
    return tree


def snapshot_from_tree(tree: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`session_tree` (for ``Session.restore``).

    Raises :class:`CheckpointCorruptError` when the meta leaf is not valid
    JSON and :class:`CheckpointVersionError` for a format this reader does
    not understand (pre-versioning checkpoints, with no ``format_version``
    key, still load).
    """
    missing = [k for k in ("state", "meta") if k not in tree]
    if missing:
        raise CheckpointCorruptError(
            f"checkpoint tree is missing required subtree(s) {missing}")
    snap: Dict[str, Any] = dict(tree["state"])
    try:
        meta = json.loads(str(tree["meta"]))
    except (json.JSONDecodeError, ValueError) as exc:
        raise CheckpointCorruptError(
            f"checkpoint meta leaf is not valid JSON: {exc}") from exc
    version = meta.pop("format_version", None)
    if version is not None and version != FORMAT_VERSION:
        raise CheckpointVersionError(
            f"checkpoint meta leaf has format_version={version}; this "
            f"reader understands format_version={FORMAT_VERSION}")
    snap.update(meta)
    for sub in _SESSION_ARRAY_SUBTREES:
        if sub in tree:
            snap[sub] = dict(tree[sub])
    return snap


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/#{i}")
    else:
        yield prefix, tree


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(rebuild(v) for _, v in items)
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


class CheckpointManager:
    def __init__(self, directory, *, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree) -> None:
        """Save a pytree (blocking on the previous async save only)."""
        host_arrays = {}
        for path, leaf in _flatten(tree):
            arr = np.asarray(leaf)
            host_arrays[path] = arr
        self.wait()
        if self.async_write:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_arrays), daemon=True)
            self._pending.start()
        else:
            self._write(step, host_arrays)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_arrays: Dict[str, np.ndarray]) -> None:
        sdir = self._step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)
        shard_name = f"shard_{self.host_id:05d}_of_{self.num_hosts:05d}.npz"
        fd, tmp = tempfile.mkstemp(dir=sdir, suffix=".tmp")
        os.close(fd)
        np.savez(tmp, **{k.replace("/", "|"): v
                         for k, v in host_arrays.items()})
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp,
                   sdir / shard_name)
        if self.host_id == 0:
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host_arrays.items()},
            }
            mtmp = sdir / "manifest.json.tmp"
            mtmp.write_text(json.dumps(manifest))
            os.replace(mtmp, sdir / "manifest.json")
            ltmp = self.dir / "LATEST.tmp"
            ltmp.write_text(sdir.name)
            os.replace(ltmp, self.dir / "LATEST")
            self._gc()

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*")
                       if (p / "manifest.json").exists())
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        sdir = self.dir / ptr.read_text().strip()
        if not (sdir / "manifest.json").exists():
            return None
        return int(sdir.name.split("_")[1])

    def steps(self) -> "list[int]":
        """All committed checkpoint steps (manifest present), ascending —
        the fallback ladder an elastic/resilient restore walks down."""
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "manifest.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return out

    def restore(self, step: Optional[int] = None):
        """Load the pytree (elastic: any current host count may read).

        Damaged payloads never load silently: an unparseable manifest, an
        unreadable/truncated shard, a missing leaf, or a leaf whose
        shape/dtype disagrees with the manifest raises
        :class:`CheckpointCorruptError` naming the offending file or leaf.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        sdir = self._step_dir(step)
        try:
            manifest = json.loads((sdir / "manifest.json").read_text())
            leaves = dict(manifest["leaves"])
        except FileNotFoundError:
            raise
        except Exception as exc:
            raise CheckpointCorruptError(
                f"checkpoint step {step}: manifest.json is unreadable or "
                f"not valid JSON ({type(exc).__name__}: {exc})") from exc
        flat: Dict[str, np.ndarray] = {}
        for shard in sorted(sdir.glob("shard_*.npz")):
            try:
                with np.load(shard) as z:
                    for k in z.files:
                        flat[k.replace("|", "/")] = z[k]
            except Exception as exc:  # BadZipFile / EOFError / ValueError...
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: shard {shard.name} is "
                    f"corrupt ({type(exc).__name__}: {exc})") from exc
        missing = set(leaves) - set(flat)
        if missing:
            raise CheckpointCorruptError(
                f"checkpoint step {step} missing leaves: "
                f"{sorted(missing)[:5]}")
        for name, info in leaves.items():
            arr = flat[name]
            if (list(arr.shape) != list(info["shape"])
                    or str(arr.dtype) != info["dtype"]):
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: leaf {name!r} has "
                    f"shape={list(arr.shape)} dtype={arr.dtype}, manifest "
                    f"says shape={info['shape']} dtype={info['dtype']}")
        return _unflatten(flat)
