"""Sharded, crash-consistent checkpointing with a non-blocking async writer.

Layout (per step):
    <dir>/step_000040/
        manifest.json            # tree structure, shapes, dtypes, shard map
        shard_00000_of_00001.npz # per-host flat arrays
        COMMIT                   # terminal commit marker (written LAST)
    <dir>/LATEST                 # fast-path pointer (renamed into place)

Commit protocol — a step directory is **committed** iff its terminal
``COMMIT`` marker exists. Every durable byte goes through
:func:`_durable_write` (write-to-tmp → fsync → atomic rename), files are
committed in dependency order (shards → manifest → ``COMMIT`` → ``LATEST``),
and re-saving an existing step *removes* its ``COMMIT`` first — so a crash
at **any** write offset leaves either the previous committed checkpoint
intact or an uncommitted directory that :meth:`CheckpointManager.steps` /
:meth:`~CheckpointManager.restore` skip. A torn write can never produce a
loadable-but-wrong checkpoint (the torn-write chaos fault in
``repro.ops.chaos`` enumerates every offset and asserts exactly that).
``LATEST`` is advisory only: :meth:`~CheckpointManager.latest_step` falls
back to scanning committed directories when the pointer is stale (a crash
between ``COMMIT`` and ``LATEST`` is benign).

Async writes are a **two-stage pipeline** (the serving gateway's hot-path
contract):

  * :meth:`CheckpointManager.save` runs on the caller (engine) thread and
    only mirrors device arrays to host (``np.asarray`` per leaf) before
    handing them to the writer — no serialization, no fsync, no disk I/O
    ever happens on the engine thread;
  * a single persistent writer thread serializes (npz), commits, and GCs;
  * lag is bounded by a **one-deep latest-wins mailbox** — if a save
    arrives while the writer is busy and a newer snapshot is already
    queued, the queued one is *skipped and counted*
    (:attr:`~CheckpointManager.skipped`), never queued behind it. The
    writer can fall at most one checkpoint behind; memory stays O(1)
    snapshots however slow the disk is.

Writer failures are sticky: the first exception is re-raised from
:meth:`~CheckpointManager.wait` (and recorded on
:attr:`~CheckpointManager.error`) instead of vanishing on a daemon thread.

Restore accepts a different host count than save (elastic restart) and
never loads damaged data silently — uncommitted directories, unparseable
manifests, truncated shards, missing leaves, and shape/dtype disagreements
all raise a typed :class:`CheckpointCorruptError` naming the offending
file or leaf.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

# MarketState leaf names packed by session_tree / snapshot_from_tree.
_SESSION_ARRAY_FIELDS = ("bid", "ask", "last_price", "prev_mid")
# Snapshot keys holding dicts of arrays (packed as subtrees, not JSON meta).
_SESSION_ARRAY_SUBTREES = ("params", "stats", "init")

#: On-disk session-checkpoint format version (the JSON meta leaf carries it).
FORMAT_VERSION = 1

#: Terminal commit-marker filename: a step directory is committed iff this
#: file exists (written last, removed first on rewrite).
COMMIT_NAME = "COMMIT"


class CheckpointError(Exception):
    """Base class for typed checkpoint failures."""


class CheckpointCorruptError(CheckpointError, IOError):
    """The on-disk payload is damaged (truncated / bit-flipped / torn /
    unparseable) or the step directory was never committed.

    Always names the offending file or leaf. Corrupt data must never load
    silently — callers fall back to an earlier step (see
    ``repro.ops.chaos``) or fail loudly.
    """


class CheckpointVersionError(CheckpointError, ValueError):
    """The checkpoint was written by an incompatible format version."""


class CheckpointShapeError(CheckpointError, ValueError):
    """A restored leaf's shape disagrees with the live session, with the
    offending config field (num_markets / num_levels / num_agents) named."""


def session_tree(snapshot: Dict[str, Any]) -> Dict[str, Any]:
    """Pack a ``Session.snapshot()`` dict into a checkpointable pytree.

    Array leaves (the book state, the per-market parameter operands, and
    the ``stats_only`` accumulators when present) go in as-is; non-array
    metadata — the step cursor and any stateful-RNG payload (PCG64 state
    holds 128-bit ints that numpy cannot represent) — is JSON-encoded into
    a unicode scalar leaf.
    """
    meta = {k: v for k, v in snapshot.items()
            if k not in _SESSION_ARRAY_FIELDS
            and k not in _SESSION_ARRAY_SUBTREES}
    meta["format_version"] = FORMAT_VERSION
    tree = {
        "state": {k: np.asarray(snapshot[k]) for k in _SESSION_ARRAY_FIELDS},
        "meta": np.asarray(json.dumps(meta)),
    }
    for sub in _SESSION_ARRAY_SUBTREES:
        if snapshot.get(sub) is not None:
            tree[sub] = {k: np.asarray(v) for k, v in snapshot[sub].items()}
    return tree


def snapshot_from_tree(tree: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`session_tree` (for ``Session.restore``).

    Raises :class:`CheckpointCorruptError` when the meta leaf is not valid
    JSON and :class:`CheckpointVersionError` for a format this reader does
    not understand (pre-versioning checkpoints, with no ``format_version``
    key, still load).
    """
    missing = [k for k in ("state", "meta") if k not in tree]
    if missing:
        raise CheckpointCorruptError(
            f"checkpoint tree is missing required subtree(s) {missing}")
    snap: Dict[str, Any] = dict(tree["state"])
    try:
        meta = json.loads(str(tree["meta"]))
    except (json.JSONDecodeError, ValueError) as exc:
        raise CheckpointCorruptError(
            f"checkpoint meta leaf is not valid JSON: {exc}") from exc
    version = meta.pop("format_version", None)
    if version is not None and version != FORMAT_VERSION:
        raise CheckpointVersionError(
            f"checkpoint meta leaf has format_version={version}; this "
            f"reader understands format_version={FORMAT_VERSION}")
    snap.update(meta)
    for sub in _SESSION_ARRAY_SUBTREES:
        if sub in tree:
            snap[sub] = dict(tree[sub])
    return snap


def meta_leaf(meta: Dict[str, Any]) -> np.ndarray:
    """JSON-encode ``meta`` (plus ``format_version``) into a unicode
    scalar leaf — the shared idiom every wire format (session, env,
    trainer) uses for its non-array metadata."""
    out = dict(meta)
    out["format_version"] = FORMAT_VERSION
    return np.asarray(json.dumps(out))


def read_meta(leaf, what: str = "checkpoint") -> Dict[str, Any]:
    """Decode a :func:`meta_leaf`, raising the typed corruption/version
    errors restore paths rely on."""
    try:
        meta = dict(json.loads(str(leaf)))
    except (json.JSONDecodeError, ValueError) as exc:
        raise CheckpointCorruptError(
            f"{what} meta leaf is not valid JSON: {exc}") from exc
    version = meta.pop("format_version", None)
    if version is not None and version != FORMAT_VERSION:
        raise CheckpointVersionError(
            f"{what} meta leaf has format_version={version}; this reader "
            f"understands format_version={FORMAT_VERSION}")
    return meta


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/#{i}")
    else:
        yield prefix, tree


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(rebuild(v) for _, v in items)
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


# ---------------------------------------------------------------------------
# durable-write choke point (the chaos tier's torn-write injection surface)
# ---------------------------------------------------------------------------

def _barrier(label: str) -> None:
    """Crash-injection hook called between every durable sub-operation.

    A no-op in production. ``repro.ops.chaos.crash_during_write`` patches
    it to raise after the N-th call, simulating a process crash at that
    exact write offset — the enumeration the torn-write chaos tests sweep.
    """


def _durable_write(path: Path, data: bytes) -> None:
    """Write ``data`` to ``path`` crash-atomically: tmp → fsync → rename.

    A crash at any point leaves either the previous contents of ``path``
    (or no file) or the complete new contents — never a torn file under
    the final name. The mid-write barrier deliberately exposes the
    partial-tmp state to the chaos sweep.
    """
    tmp = path.with_name(path.name + ".tmp")
    _barrier(f"open:{path.name}")
    with open(tmp, "wb") as f:
        half = len(data) // 2
        f.write(data[:half])
        _barrier(f"mid-write:{path.name}")
        f.write(data[half:])
        f.flush()
        _barrier(f"pre-fsync:{path.name}")
        os.fsync(f.fileno())
    _barrier(f"pre-rename:{path.name}")
    os.replace(tmp, path)
    _barrier(f"post-rename:{path.name}")


def _fsync_dir(path: Path) -> None:
    """Fsync a directory so renames inside it are durable (POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:          # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    """See the module docstring for the commit protocol and async pipeline.

    ``on_write(step, seconds)`` and ``on_gc(oldest_retained_step)`` are
    optional callbacks fired **on the writer thread** after each commit /
    garbage collection — the serving gateway uses them for write-latency
    metrics and splice-journal compaction. They must be thread-safe.
    """

    def __init__(self, directory, *, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3, async_write: bool = True,
                 on_write: Optional[Callable[[int, float], None]] = None,
                 on_gc: Optional[Callable[[int], None]] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self.async_write = async_write
        self.on_write = on_write
        self.on_gc = on_gc
        # ---- async-writer state (all guarded by _cv's lock) ----
        self._cv = threading.Condition()
        self._queued: Optional[Tuple[int, Dict[str, np.ndarray]]] = None
        self._writing: Optional[int] = None
        self._writer: Optional[threading.Thread] = None
        self._stop = False
        self.error: Optional[BaseException] = None  # sticky writer failure
        self.writes = 0            # committed checkpoints
        self.skipped = 0           # saves dropped by the lag-bound policy
        self.last_write_seconds = 0.0

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    # ---- save side ----------------------------------------------------
    def save(self, step: int, tree) -> bool:
        """Persist a pytree; returns True if the save was accepted.

        The caller-thread cost is the device→host mirror only. In async
        mode the snapshot is handed to the writer thread; when the writer
        is busy *and* a newer snapshot is already queued, the queued one is
        replaced (latest wins) and counted in :attr:`skipped` — the
        lag-bounded skip-and-count policy. Returns False only when this
        very snapshot was itself superseded before being accepted (cannot
        happen with a single saver thread). Sync mode writes inline.
        """
        host_arrays = {path: np.asarray(leaf)
                       for path, leaf in _flatten(tree)}
        if not self.async_write:
            self._write(step, host_arrays)
            return True
        with self._cv:
            self._raise_sticky()
            if self._queued is not None:
                # Writer is a full commit behind: drop the stale queued
                # snapshot (never grow a queue), keep the freshest.
                self.skipped += 1
            self._queued = (step, host_arrays)
            self._ensure_writer()
            self._cv.notify_all()
        return True

    @property
    def pending(self) -> int:
        """Snapshots not yet committed (0–2: queued + in-flight write)."""
        with self._cv:
            return (self._queued is not None) + (self._writing is not None)

    def wait(self, timeout: Optional[float] = None) -> None:
        """Block until every accepted save is committed; re-raises the
        first (sticky) writer failure, if any."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._queued is not None or self._writing is not None:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"checkpoint writer still busy after {timeout}s "
                        f"(writing step {self._writing})")
                self._cv.wait(remaining)
            self._raise_sticky()

    def close(self) -> None:
        """Flush and stop the writer thread (idempotent)."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._writer is not None:
            self._writer.join(timeout=60)
            self._writer = None

    def _raise_sticky(self) -> None:
        if self.error is not None:
            raise self.error

    def _ensure_writer(self) -> None:
        if self._writer is None or not self._writer.is_alive():
            self._stop = False
            self._writer = threading.Thread(
                target=self._writer_loop, name="ckpt-writer", daemon=True)
            self._writer.start()

    def _writer_loop(self) -> None:
        while True:
            with self._cv:
                while self._queued is None and not self._stop:
                    self._cv.wait()
                if self._queued is None:        # stop requested, fully drained
                    return
                step, host_arrays = self._queued
                self._queued = None
                self._writing = step
            err: Optional[BaseException] = None
            seconds = 0.0
            try:
                seconds = self._write(step, host_arrays)
            except BaseException as exc:        # sticky: surfaced by wait()
                err = exc
            with self._cv:
                self._writing = None
                if err is not None and self.error is None:
                    self.error = err
                self._cv.notify_all()
            if err is None and self.on_write is not None:
                self.on_write(step, seconds)

    # ---- the commit sequence (writer thread, or inline in sync mode) ----
    def _write(self, step: int, host_arrays: Dict[str, np.ndarray]) -> float:
        t0 = time.perf_counter()
        sdir = self._step_dir(step)
        sdir.mkdir(parents=True, exist_ok=True)
        commit = sdir / COMMIT_NAME
        if commit.exists():
            # Rewriting a committed step: uncommit FIRST so a crash during
            # the rewrite can never leave a committed-but-torn directory.
            os.remove(commit)
            _fsync_dir(sdir)
        shard_name = f"shard_{self.host_id:05d}_of_{self.num_hosts:05d}.npz"
        buf = io.BytesIO()
        np.savez(buf, **{k.replace("/", "|"): v
                         for k, v in host_arrays.items()})
        _durable_write(sdir / shard_name, buf.getvalue())
        if self.host_id == 0:
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host_arrays.items()},
            }
            _durable_write(sdir / "manifest.json",
                           json.dumps(manifest).encode())
            _durable_write(commit, json.dumps(
                {"step": step, "format_version": FORMAT_VERSION}).encode())
            _fsync_dir(sdir)
            _durable_write(self.dir / "LATEST", sdir.name.encode())
            self._gc()
        seconds = time.perf_counter() - t0
        with self._cv:
            self.writes += 1
            self.last_write_seconds = seconds
        return seconds

    def _gc(self) -> None:
        """Drop committed steps beyond ``keep`` plus any dead uncommitted
        directories and stray tmp files (torn-write leftovers)."""
        committed, torn = [], []
        for p in sorted(self.dir.glob("step_*")):
            (committed if (p / COMMIT_NAME).exists() else torn).append(p)
        writing = None
        with self._cv:
            if self._writing is not None:
                writing = self._step_dir(self._writing)
        for p in committed[:-self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)
        for p in torn:
            if writing is None or p != writing:
                shutil.rmtree(p, ignore_errors=True)
        for tmp in self.dir.glob("*.tmp"):
            tmp.unlink(missing_ok=True)
        if self.on_gc is not None:
            remaining = self.steps()
            if remaining:
                self.on_gc(remaining[0])

    # ------------------------------------------------------------------
    def _is_committed(self, sdir: Path) -> bool:
        return (sdir / COMMIT_NAME).exists() \
            and (sdir / "manifest.json").exists()

    def latest_step(self) -> Optional[int]:
        """Newest committed step. ``LATEST`` is a fast path only — when the
        pointer is stale or torn (crash between ``COMMIT`` and ``LATEST``)
        this falls back to scanning committed directories."""
        ptr = self.dir / "LATEST"
        if ptr.exists():
            sdir = self.dir / ptr.read_text().strip()
            if self._is_committed(sdir):
                try:
                    pointed = int(sdir.name.split("_")[1])
                except ValueError:
                    pointed = None
                if pointed is not None:
                    all_steps = self.steps()
                    if all_steps and all_steps[-1] == pointed:
                        return pointed
        steps = self.steps()
        return steps[-1] if steps else None

    def steps(self) -> "list[int]":
        """All **committed** checkpoint steps (terminal ``COMMIT`` marker
        present), ascending — the fallback ladder an elastic/resilient
        restore walks down. Torn/uncommitted directories never appear."""
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if self._is_committed(p):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return out

    def restore(self, step: Optional[int] = None):
        """Load the pytree (elastic: any current host count may read).

        Damaged payloads never load silently: an uncommitted step directory
        (no terminal ``COMMIT`` marker — a torn write), an unparseable
        manifest, an unreadable/truncated shard, a missing leaf, or a leaf
        whose shape/dtype disagrees with the manifest raises
        :class:`CheckpointCorruptError` naming the offending file or leaf.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        sdir = self._step_dir(step)
        if not (sdir / COMMIT_NAME).exists():
            if not sdir.exists():
                raise FileNotFoundError(
                    f"checkpoint step {step}: no directory {sdir.name}")
            raise CheckpointCorruptError(
                f"checkpoint step {step}: directory {sdir.name} has no "
                f"{COMMIT_NAME} marker — the write never committed (torn "
                "write or crash mid-commit); refusing to load")
        try:
            manifest = json.loads((sdir / "manifest.json").read_text())
            leaves = dict(manifest["leaves"])
        except FileNotFoundError:
            raise
        except Exception as exc:
            raise CheckpointCorruptError(
                f"checkpoint step {step}: manifest.json is unreadable or "
                f"not valid JSON ({type(exc).__name__}: {exc})") from exc
        flat: Dict[str, np.ndarray] = {}
        for shard in sorted(sdir.glob("shard_*.npz")):
            try:
                with np.load(shard) as z:
                    for k in z.files:
                        flat[k.replace("|", "/")] = z[k]
            except Exception as exc:  # BadZipFile / EOFError / ValueError...
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: shard {shard.name} is "
                    f"corrupt ({type(exc).__name__}: {exc})") from exc
        missing = set(leaves) - set(flat)
        if missing:
            raise CheckpointCorruptError(
                f"checkpoint step {step} missing leaves: "
                f"{sorted(missing)[:5]}")
        for name, info in leaves.items():
            arr = flat[name]
            if (list(arr.shape) != list(info["shape"])
                    or str(arr.dtype) != info["dtype"]):
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: leaf {name!r} has "
                    f"shape={list(arr.shape)} dtype={arr.dtype}, manifest "
                    f"says shape={info['shape']} dtype={info['dtype']}")
        return _unflatten(flat)
