from repro.checkpoint.manager import (  # noqa: F401 (re-exported API)
    FORMAT_VERSION,
    CheckpointCorruptError,
    CheckpointError,
    CheckpointManager,
    CheckpointShapeError,
    CheckpointVersionError,
)
