from repro.checkpoint.manager import (  # noqa: F401 (re-exported API)
    COMMIT_NAME,
    FORMAT_VERSION,
    CheckpointCorruptError,
    CheckpointError,
    CheckpointManager,
    CheckpointShapeError,
    CheckpointVersionError,
)
