"""CheckpointManager negative paths: typed errors, never silent loads.

Satellite coverage for the ops-hardening PR: every damage mode a restore
can hit raises a *typed* error naming the offending file/leaf/field —
the historical failure mode was an opaque pytree unflatten error (or, for
shape mismatches, a deep broadcast error inside placement).
"""
import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.checkpoint.manager import (COMMIT_NAME, FORMAT_VERSION,
                                      CheckpointCorruptError,
                                      CheckpointError, CheckpointManager,
                                      CheckpointShapeError,
                                      CheckpointVersionError, session_tree,
                                      snapshot_from_tree)
from repro.core.config import MarketConfig
from repro.core.session import Engine
from repro.ops.chaos import corrupt_checkpoint

CFG = MarketConfig(num_markets=4, num_agents=16, num_levels=16, num_steps=12,
                   seed=3)


def _saved_manager(tmp_path, cfg=CFG, backend="numpy-pcg64"):
    sess = Engine(backend).open(cfg)
    sess.run(5)
    mgr = CheckpointManager(tmp_path, async_write=False)
    step = sess.save_checkpoint(mgr)
    return mgr, step, sess


# ---- corrupt payloads ----

def test_truncated_shard_raises_typed_error(tmp_path):
    mgr, step, _ = _saved_manager(tmp_path)
    victim = corrupt_checkpoint(mgr.dir, step, "truncate", "shard")
    with pytest.raises(CheckpointCorruptError, match=victim.name):
        mgr.restore(step)


def test_bitflipped_shard_raises_typed_error(tmp_path):
    mgr, step, _ = _saved_manager(tmp_path)
    victim = corrupt_checkpoint(mgr.dir, step, "bitflip", "shard")
    with pytest.raises(CheckpointCorruptError, match=victim.name):
        mgr.restore(step)


def test_corrupt_manifest_raises_typed_error(tmp_path):
    mgr, step, _ = _saved_manager(tmp_path)
    corrupt_checkpoint(mgr.dir, step, "truncate", "manifest")
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        mgr.restore(step)


def test_missing_leaf_raises_typed_error(tmp_path):
    mgr, step, _ = _saved_manager(tmp_path)
    sdir = mgr.dir / f"step_{step:08d}"
    manifest = json.loads((sdir / "manifest.json").read_text())
    manifest["leaves"]["state/not_a_real_leaf"] = {"shape": [1], "dtype": "float32"}
    (sdir / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(CheckpointCorruptError, match="not_a_real_leaf"):
        mgr.restore(step)


def test_manifest_shape_mismatch_raises_typed_error(tmp_path):
    mgr, step, _ = _saved_manager(tmp_path)
    sdir = mgr.dir / f"step_{step:08d}"
    manifest = json.loads((sdir / "manifest.json").read_text())
    manifest["leaves"]["state/bid"]["shape"] = [99, 99]
    (sdir / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(CheckpointCorruptError, match="state/bid"):
        mgr.restore(step)


# ---- wrong format version ----

def test_wrong_version_meta_leaf_raises_version_error(tmp_path):
    _, _, sess = _saved_manager(tmp_path)
    tree = session_tree(sess.snapshot())
    meta = json.loads(str(tree["meta"]))
    assert meta["format_version"] == FORMAT_VERSION
    meta["format_version"] = FORMAT_VERSION + 1
    tree["meta"] = np.asarray(json.dumps(meta))
    with pytest.raises(CheckpointVersionError, match="format_version"):
        snapshot_from_tree(tree)


def test_preversioning_meta_still_loads(tmp_path):
    """Checkpoints written before format_version existed keep loading."""
    _, _, sess = _saved_manager(tmp_path)
    tree = session_tree(sess.snapshot())
    meta = json.loads(str(tree["meta"]))
    meta.pop("format_version")
    tree["meta"] = np.asarray(json.dumps(meta))
    snap = snapshot_from_tree(tree)
    assert snap["t"] == 5 and "format_version" not in snap


def test_garbage_meta_leaf_raises_corrupt_error(tmp_path):
    _, _, sess = _saved_manager(tmp_path)
    tree = session_tree(sess.snapshot())
    tree["meta"] = np.asarray("{not json")
    with pytest.raises(CheckpointCorruptError, match="JSON"):
        snapshot_from_tree(tree)


# ---- restore-time (M, A, L) shape mismatches name the offending field ----

@pytest.mark.parametrize("field,override", [
    ("num_markets", dict(num_markets=6)),
    ("num_levels", dict(num_levels=32)),
])
def test_shape_mismatch_on_restore_names_field(tmp_path, field, override):
    snap = Engine("numpy").open(dataclasses.replace(CFG, **override)) \
        .snapshot()
    sess = Engine("numpy").open(CFG)
    with pytest.raises(CheckpointShapeError, match=field):
        sess.restore(snap)
    # a failed restore leaves the session untouched and usable
    assert sess.step_count == 0
    sess.run(2)


def test_num_agents_mismatch_names_field():
    snap = Engine("numpy").open(dataclasses.replace(CFG, num_agents=32)) \
        .snapshot()
    sess = Engine("numpy").open(CFG)
    with pytest.raises(CheckpointShapeError, match="num_agents"):
        sess.restore(snap)
    # CheckpointShapeError subclasses ValueError: pre-existing callers that
    # caught ValueError for this mismatch keep working.
    with pytest.raises(ValueError):
        sess.restore(snap)


def test_params_leaf_shape_mismatch_names_num_markets():
    snap = Engine("numpy").open(CFG).snapshot()
    bad = dict(snap)
    bad["params"] = {k: np.vstack([v, v]) for k, v in snap["params"].items()}
    sess = Engine("numpy").open(CFG)
    with pytest.raises(CheckpointShapeError, match="num_markets"):
        sess.restore(bad)


def test_error_hierarchy():
    assert issubclass(CheckpointCorruptError, CheckpointError)
    assert issubclass(CheckpointCorruptError, IOError)
    assert issubclass(CheckpointVersionError, ValueError)
    assert issubclass(CheckpointShapeError, ValueError)


# ---- steps() listing ----

def test_steps_lists_committed_checkpoints(tmp_path):
    sess = Engine("numpy").open(dataclasses.replace(CFG, num_steps=40))
    mgr = CheckpointManager(tmp_path, async_write=False, keep=10)
    for _ in range(3):
        sess.run(4)
        sess.save_checkpoint(mgr)
    assert mgr.steps() == [4, 8, 12]
    assert mgr.latest_step() == 12
    # a directory without a manifest is not a committed checkpoint
    (mgr.dir / "step_00000099").mkdir()
    assert mgr.steps() == [4, 8, 12]


# ---- the COMMIT-marker protocol ----

def test_uncommitted_dir_skipped_and_restore_refused(tmp_path):
    """A step directory without the terminal COMMIT marker (a torn write)
    never appears in the ladder, never wins latest_step() even when the
    advisory LATEST pointer still names it, and refuses an explicit
    restore with a typed error."""
    sess = Engine("numpy").open(dataclasses.replace(CFG, num_steps=40))
    mgr = CheckpointManager(tmp_path, async_write=False, keep=10)
    sess.run(4)
    sess.save_checkpoint(mgr)
    sess.run(4)
    sess.save_checkpoint(mgr)
    (mgr.dir / "step_00000008" / COMMIT_NAME).unlink()
    assert mgr.steps() == [4]
    assert mgr.latest_step() == 4    # LATEST is stale -> fallback scan
    with pytest.raises(CheckpointCorruptError, match=COMMIT_NAME):
        mgr.restore(8)


def test_async_latest_wins_mailbox_skips_and_counts(tmp_path):
    """While the writer is mid-commit, newer saves replace the queued
    snapshot (latest wins, counted in .skipped) instead of growing a
    queue; lag never exceeds one queued + one in-flight snapshot."""
    import repro.checkpoint.manager as ckpt_mod

    sess = Engine("numpy").open(dataclasses.replace(CFG, num_steps=64))
    trees = {}
    for step in (4, 8, 12, 16):
        sess.run(4)
        trees[step] = session_tree(sess.snapshot())
    gate = threading.Event()
    entered = threading.Event()
    real = ckpt_mod._barrier

    def blocking_barrier(label):
        entered.set()
        gate.wait(30)

    mgr = CheckpointManager(tmp_path, async_write=True, keep=10)
    ckpt_mod._barrier = blocking_barrier
    try:
        assert mgr.save(4, trees[4])
        assert entered.wait(30)      # writer is stalled inside step 4
        mgr.save(8, trees[8])        # queued behind the stalled write
        mgr.save(12, trees[12])      # replaces 8 (skip-and-count)
        mgr.save(16, trees[16])      # replaces 12
        assert mgr.pending == 2      # one in flight + one queued, never more
        gate.set()
        mgr.wait()
    finally:
        ckpt_mod._barrier = real
        mgr.close()
    assert mgr.writes == 2 and mgr.skipped == 2 and mgr.pending == 0
    assert mgr.steps() == [4, 16]    # 8/12 never hit disk
    assert mgr.error is None and mgr.last_write_seconds > 0.0


def test_torn_write_sweep_never_restores_corrupt_state(tmp_path):
    """Crash at EVERY durable-write offset inside a commit: the reopened
    ladder restores either the previous committed step or (when the crash
    landed after the COMMIT rename) the complete new one — bitwise intact
    in both cases, and the torn directory is never loadable."""
    from repro.ops import SimulatedCrash, count_write_ops, crash_during_write

    sess = Engine("numpy").open(dataclasses.replace(CFG, num_steps=40))
    sess.run(4)
    tree4 = session_tree(sess.snapshot())
    sess.run(4)
    tree8 = session_tree(sess.snapshot())
    ops = count_write_ops(
        CheckpointManager(tmp_path / "probe", async_write=False), 8, tree8)
    assert ops >= 15       # open/mid-write/fsync/rename barriers x 4 files
    for k in range(ops):
        mgr = CheckpointManager(tmp_path / f"op{k}", async_write=False)
        mgr.save(4, tree4)
        with crash_during_write(k), pytest.raises(SimulatedCrash):
            mgr.save(8, tree8)
        # "restart": a fresh manager over the same directory
        mgr2 = CheckpointManager(tmp_path / f"op{k}", async_write=False)
        latest = mgr2.latest_step()
        assert latest in (4, 8), (k, latest)
        want = tree4 if latest == 4 else tree8
        got = mgr2.restore(latest)
        for key in ("bid", "ask", "last_price", "prev_mid"):
            assert np.array_equal(got["state"][key], want["state"][key]), \
                (k, key)
        assert str(got["meta"]) == str(want["meta"]), k
        if latest == 4:
            assert 8 not in mgr2.steps()
            if (mgr2.dir / "step_00000008").exists():
                with pytest.raises(CheckpointCorruptError, match=COMMIT_NAME):
                    mgr2.restore(8)
