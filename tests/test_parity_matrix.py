"""Paper §IV-B parity matrix, regenerated as an executable test sweep.

The paper's headline correctness claim: across 53 configurations the two
custom engines produce *bitwise-identical* order books, and aggregate
statistics match the CPU reference to within 0.1%. Here the matrix spans
(M, A, L, S) shapes x scenario presets x archetype mixtures:

  * ``pallas-naive`` vs ``pallas-kinetic`` (interpret mode on CPU): every
    result field bitwise identical — the two-custom-engines experiment;
  * engine aggregate statistics vs the NumPy reference: relative drift
    <= 0.1% — the CPU-reference experiment.

Tier-1 runs a fast 11-case subset spanning all scenarios and mixtures
(including the whale / hft / informed archetype presets); the full >= 53-
configuration matrix is ``slow``-marked (nightly CI). A ``tpu``-marked
case re-runs one configuration with real Mosaic lowering.
"""
import numpy as np
import pytest

from repro.core import engine
from repro.core.config import scenario_config, scenario_names

BOOK_FIELDS = ("bid", "ask", "last_price", "prev_mid", "price_path",
               "volume_path")
STATS = ("mean_clearing_price", "volume_per_market", "trade_count",
         "volatility")
STAT_TOL = 1e-3  # the paper's 0.1%

# Archetype mixtures: static weights (maker, momentum, fundamentalist);
# noise takes the remainder. >= 3 distinct mixtures per the paper sweep.
MIXTURES = {
    "paper": dict(alpha_maker=0.15, alpha_momentum=0.15,
                  alpha_fundamentalist=0.0),
    "fundamental": dict(alpha_maker=0.10, alpha_momentum=0.10,
                        alpha_fundamentalist=0.30),
    "mom-heavy": dict(alpha_maker=0.10, alpha_momentum=0.50,
                      alpha_fundamentalist=0.05),
    "noise-only": dict(alpha_maker=0.0, alpha_momentum=0.0,
                       alpha_fundamentalist=0.0),
}

SHAPES = [  # (M, A, L, S) — includes a prime M and A > L cases
    (4, 16, 16, 6),
    (8, 32, 32, 10),
    (5, 48, 64, 12),
]

SCENARIOS = scenario_names()  # 9 presets

# 9 scenarios x 4 mixtures x 3 shapes = 108 >= 53 configurations.
FULL_MATRIX = [
    (sc, mix, shape)
    for sc in SCENARIOS
    for mix in MIXTURES
    for shape in SHAPES
]

# Fast tier-1 subset: smallest shape, all 9 scenarios, all 4 mixtures.
TIER1 = [
    ("baseline", "paper", SHAPES[0]),
    ("baseline", "noise-only", SHAPES[0]),
    ("flash-crash", "fundamental", SHAPES[0]),
    ("flash-crash", "paper", SHAPES[0]),
    ("high-vol", "mom-heavy", SHAPES[0]),
    ("low-vol", "fundamental", SHAPES[0]),
    ("thin-book", "mom-heavy", SHAPES[0]),
    ("wide-book", "noise-only", SHAPES[0]),
    ("whale", "paper", SHAPES[0]),
    ("hft", "fundamental", SHAPES[0]),
    ("informed", "noise-only", SHAPES[0]),
]


def _case_id(case):
    sc, mix, (M, A, L, S) = case
    return f"{sc}-{mix}-M{M}A{A}L{L}S{S}"


def _config(case):
    sc, mix, (M, A, L, S) = case
    return scenario_config(
        sc, num_markets=M, num_agents=A, num_levels=L, num_steps=S,
        seed=FULL_MATRIX.index(case), **MIXTURES[mix])


def _assert_parity(case, interpret=True):
    cfg = _config(case)
    naive = engine.simulate(cfg, backend="pallas-naive",
                            interpret=interpret).to_numpy()
    kinetic = engine.simulate(cfg, backend="pallas-kinetic",
                              interpret=interpret).to_numpy()

    # Claim 1: the two custom engines are bitwise identical, field by field.
    for f in BOOK_FIELDS:
        a, b = getattr(naive, f), getattr(kinetic, f)
        assert a.dtype == b.dtype and a.shape == b.shape, f
        assert (a == b).all(), f"{_case_id(case)}: field {f} differs"

    # Claim 2: aggregate statistics within 0.1% of the NumPy reference.
    reference = engine.simulate(cfg, backend="numpy").to_numpy()
    for stat in STATS:
        got = getattr(kinetic, stat)()
        want = getattr(reference, stat)()
        if np.isnan(want):
            assert np.isnan(got), f"{_case_id(case)}: {stat} nan mismatch"
            continue
        drift = abs(got - want) / max(abs(want), 1e-9)
        assert drift <= STAT_TOL, (
            f"{_case_id(case)}: {stat} drift {drift:.2e} "
            f"(engine={got}, reference={want})")


def test_matrix_regenerates_paper_claim_shape():
    """The matrix itself must span the paper's claimed breadth."""
    assert len(FULL_MATRIX) >= 53
    assert len({sc for sc, _, _ in FULL_MATRIX}) >= 3
    assert len({mix for _, mix, _ in FULL_MATRIX}) >= 3
    assert set(TIER1) <= set(FULL_MATRIX)


@pytest.mark.parametrize("case", TIER1, ids=_case_id)
def test_parity_tier1(case):
    _assert_parity(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", FULL_MATRIX, ids=_case_id)
def test_parity_full_matrix(case):
    _assert_parity(case)


@pytest.mark.tpu
def test_parity_mosaic_lowering():
    """One configuration through the real TPU lowering (not interpret)."""
    import jax

    if jax.default_backend() != "tpu":
        pytest.skip("requires a TPU backend")
    _assert_parity(("flash-crash", "paper", SHAPES[1]), interpret=False)


# ---- scenario-engine unit checks (fast; ride along with the matrix) ----

def test_mixture_population_counts():
    from repro.core.config import FUNDAMENTALIST, MAKER, MOMENTUM, NOISE

    cfg = scenario_config("baseline", num_agents=40, num_steps=4,
                          **MIXTURES["fundamental"])
    types = np.asarray(cfg.agent_types(np))
    assert (types == MAKER).sum() == 4
    assert (types == MOMENTUM).sum() == 4
    assert (types == FUNDAMENTALIST).sum() == 12
    assert (types == NOISE).sum() == 20
    assert abs(sum(cfg.mixture().values()) - 1.0) < 1e-12


def test_scenario_override_precedence():
    cfg = scenario_config("flash-crash", num_steps=20, shock_step=7)
    assert cfg.scenario == "flash-crash"
    assert cfg.shock_step == 7          # explicit override wins
    default = scenario_config("flash-crash", num_steps=20)
    assert default.shock_step == 10     # preset places the shock mid-run


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        scenario_config("no-such-scenario")


def test_conflicting_scenario_override_raises():
    with pytest.raises(ValueError):
        scenario_config("baseline", scenario="flash-crash")
    # a redundant-but-consistent override is fine
    assert scenario_config("baseline", scenario="baseline").scenario == "baseline"


def test_rounding_overshoot_rejected():
    """Per-class rounding may not assign more agents than exist."""
    from repro.core.config import MarketConfig

    with pytest.raises(ValueError):
        MarketConfig(num_agents=2, alpha_maker=0.4, alpha_momentum=0.3,
                     alpha_fundamentalist=0.3)


def test_archetype_counts_sum_to_population():
    cfg = scenario_config("baseline", num_agents=37, num_steps=4,
                          **MIXTURES["mom-heavy"])
    counts = cfg.archetype_counts()
    assert sum(counts.values()) == 37
    types = np.asarray(cfg.agent_types(np))
    for tid, n in counts.items():
        assert (types == tid).sum() == n


def test_flash_crash_moves_the_market():
    """The shock must actually bite: price drops and volatility jumps at
    the shock step relative to the baseline twin."""
    kw = dict(num_markets=8, num_agents=64, num_levels=64, num_steps=16,
              seed=2)
    base = engine.simulate(scenario_config("baseline", **kw),
                           backend="numpy").to_numpy()
    crash_cfg = scenario_config("flash-crash", **kw)
    crash = engine.simulate(crash_cfg, backend="numpy").to_numpy()
    s = crash_cfg.shock_step
    # identical up to the shock (same RNG stream, same dynamics)...
    assert (base.price_path[:, :s] == crash.price_path[:, :s]).all()
    # ...then the crash prints strictly lower on average
    assert crash.price_path[:, s].mean() < base.price_path[:, s].mean()
    assert crash.volatility() > base.volatility()


def test_fundamentalists_dampen_volatility():
    """Mean-reversion pressure should reduce dispersion vs a momentum-heavy
    population under identical noise."""
    kw = dict(num_markets=16, num_agents=64, num_levels=64, num_steps=40,
              seed=4)
    fund = engine.simulate(
        scenario_config("baseline", alpha_maker=0.1, alpha_momentum=0.0,
                        alpha_fundamentalist=0.5, **kw),
        backend="numpy").to_numpy()
    mom = engine.simulate(
        scenario_config("baseline", alpha_maker=0.1, alpha_momentum=0.5,
                        alpha_fundamentalist=0.0, **kw),
        backend="numpy").to_numpy()
    assert fund.volatility() < mom.volatility()


def test_archetype_registry_complete():
    from repro.core import agents
    from repro.core.config import (ARBITRAGEUR, FUNDAMENTALIST, HFT,
                                   INFORMED, MAKER, MOMENTUM, NOISE, WHALE)

    names = agents.archetype_names()
    assert names == {NOISE: "noise", MOMENTUM: "momentum", MAKER: "maker",
                     FUNDAMENTALIST: "fundamentalist", WHALE: "whale",
                     HFT: "hft", INFORMED: "informed",
                     ARBITRAGEUR: "arbitrageur"}


# Satellite: each new archetype preset bitwise across the five counter-RNG
# backends, and statistically (<= 0.1% on aggregates) against the PCG64
# reference stream.
COUNTER_BACKENDS = ("numpy", "jax-scan", "jax-per-step", "pallas-naive",
                    "pallas-kinetic")


@pytest.mark.parametrize("preset", ["whale", "hft", "informed"])
def test_new_archetype_backend_parity(preset):
    cfg = scenario_config(preset, num_markets=6, num_agents=48,
                          num_levels=32, num_steps=12, seed=11)
    results = {b: engine.simulate(cfg, backend=b).to_numpy()
               for b in COUNTER_BACKENDS}
    ref = results["numpy"]
    for b in COUNTER_BACKENDS[1:]:
        for f in BOOK_FIELDS:
            a, r = getattr(results[b], f), getattr(ref, f)
            assert a.dtype == r.dtype and a.shape == r.shape, (b, f)
            assert (a == r).all(), f"{preset}: {b} field {f} differs"
    # The PCG64 stream is a different RNG: only aggregate statistics are
    # comparable. Volume per market is the statistic that concentrates at
    # test scale (observed cross-stream drift <= 0.2% at M=128); the mean
    # clearing price is a diffusive level in these high-vol presets, so it
    # only gets a loose sanity bound (the paper's 0.1% holds at M=4096,
    # cf. tests/test_cross_backend.py).
    long_cfg = scenario_config(preset, num_markets=128, num_agents=64,
                               num_levels=64, num_steps=200, seed=11)
    kin = engine.simulate(long_cfg, backend="numpy").to_numpy()
    pcg = engine.simulate(long_cfg, backend="numpy-pcg64").to_numpy()
    vol_drift = abs(kin.volume_per_market() - pcg.volume_per_market()) \
        / abs(pcg.volume_per_market())
    assert vol_drift <= 1e-2, (
        f"{preset}: volume drift {vol_drift:.2e} vs PCG64")
    px_drift = abs(kin.mean_clearing_price() - pcg.mean_clearing_price()) \
        / abs(pcg.mean_clearing_price())
    assert px_drift <= 0.15, (
        f"{preset}: mean price drift {px_drift:.2e} vs PCG64")
