"""Fault tolerance: checkpoint restart, failure injection, stragglers,
elastic restore, data determinism."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticLMData, make_batch
from repro.launch.steps import make_train_step
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.fault import (FaultInjector, HeartbeatMonitor,
                                 SimulatedNodeFailure, StragglerWatch)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
def _tree():
    return {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": np.zeros(4, np.float32)},
            "opt": ({"mu": np.ones((3, 4), np.float32)},),
            "step": np.int64(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = _tree()
    mgr.save(7, tree)
    out = mgr.restore()
    assert int(out["step"]) == 7
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    assert isinstance(out["opt"], tuple)


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    for s in (10, 20, 30):
        mgr.save(s, _tree())
    mgr.wait()
    assert mgr.latest_step() == 30
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2  # gc kept last 2


def test_checkpoint_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(5, _tree())
    # simulate a crashed later write: step dir without manifest
    (tmp_path / "step_00000009").mkdir()
    assert mgr.latest_step() == 5
    assert int(mgr.restore()["step"]) == 7  # tree content, not dir name


# ---------------------------------------------------------------------------
# fault primitives
# ---------------------------------------------------------------------------
def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 12.0
    assert mon.dead_workers() == [2]
    assert not mon.healthy()


def test_straggler_watch():
    w = StragglerWatch(window=20, k_sigma=3, min_samples=5)
    for s in range(10):
        assert not w.observe(s, 1.0 + 0.01 * (s % 3))
    assert w.observe(10, 10.0)
    assert len(w.flagged) == 1


# ---------------------------------------------------------------------------
# end-to-end: crash mid-run, restart resumes from checkpoint
# ---------------------------------------------------------------------------
def test_driver_restart_after_failure(tmp_path):
    cfg = get_config("qwen2.5-3b", smoke=True)
    shape = ShapeSpec("t", 32, 2, "train")
    train_step, opt = make_train_step(cfg)
    jstep = jax.jit(train_step)
    driver = TrainDriver(
        cfg, shape, jstep, opt.init,
        DriverConfig(total_steps=12, checkpoint_every=4,
                     checkpoint_dir=str(tmp_path), max_restarts=2),
        fault_injector=FaultInjector(fail_at_steps=(6,)),
    )
    out = driver.run()
    assert out["step"] == 12
    # the run restarted: steps 5,6 were re-executed from the step-4 ckpt
    steps_seen = [m["step"] for m in driver.metrics_log]
    assert steps_seen.count(5) >= 2


def test_driver_gives_up_after_max_restarts(tmp_path):
    cfg = get_config("qwen2.5-3b", smoke=True)
    shape = ShapeSpec("t", 32, 2, "train")
    train_step, opt = make_train_step(cfg)
    driver = TrainDriver(
        cfg, shape, jax.jit(train_step), opt.init,
        DriverConfig(total_steps=10, checkpoint_every=100,
                     checkpoint_dir=str(tmp_path), max_restarts=1),
        fault_injector=FaultInjector(fail_at_steps=(2, 3)),
    )
    driver.fault.fired = set()

    class AlwaysFail(FaultInjector):
        def maybe_fail(self, step):
            if step == 2:
                raise SimulatedNodeFailure("persistent failure")

    driver.fault = AlwaysFail()
    with pytest.raises(SimulatedNodeFailure):
        driver.run()


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_stateless():
    d = SyntheticLMData(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    a = d.batch(5)
    b = d.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6)
    assert not (a["tokens"] == c["tokens"]).all()


def test_data_sharding_partitions_batch():
    full = SyntheticLMData(1000, 32, 8, seed=1).batch(2)
    shards = [SyntheticLMData(1000, 32, 8, seed=1, num_shards=4, shard=i)
              .batch(2) for i in range(4)]
    recon = np.concatenate([s["tokens"] for s in shards], axis=0)
    np.testing.assert_array_equal(full["tokens"], recon)


def test_data_labels_are_shifted_tokens():
    d = SyntheticLMData(1000, 64, 4, seed=0)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_make_batch_modalities():
    cfg = get_config("qwen2-vl-72b", smoke=True)
    shape = ShapeSpec("t", 32, 2, "train")
    b = make_batch(cfg, shape, 0)
    assert b["vision_embeds"].shape == (2, cfg.num_vision_tokens, cfg.d_model)
    assert b["mrope_positions"].shape == (2, 3, 32)
    cfg = get_config("whisper-large-v3", smoke=True)
    b = make_batch(cfg, shape, 0)
    assert b["frames"].shape == (2, cfg.source_len, cfg.d_model)
