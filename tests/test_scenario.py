"""Scenario subsystem: coupling, new archetypes, sequential clearing,
stylized-facts validation gate, and the session/ensemble satellites.

Multi-device coverage mirrors tests/test_distributed.py: subprocess probes
force 2 host devices for tier-1, `@pytest.mark.distributed` cases run
in-process under the CI distributed tier. The full pinned realism gate is
`@pytest.mark.scenario` (CI `scenario` tier; tier-1 runs the fast checks).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine
from repro.core.config import MarketConfig, scenario_config
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.kernels import ref
from repro.scenario import (
    CouplingSpec,
    FactCheck,
    ValidationReport,
    coupled_ensemble,
    mechanism_gap,
    validate_spec,
)
from repro.scenario.sequential import GAP_METRICS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FIELDS = ("bid", "ask", "last_price", "prev_mid", "price_path", "volume_path")


def _run_probe(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _device_count() -> int:
    import jax

    return len(jax.devices())


# ---------------------------------------------------------------------------
# CouplingSpec: construction and validation.
# ---------------------------------------------------------------------------

def test_coupling_none_is_all_self():
    spec = CouplingSpec.none(5)
    assert (spec.peer == -1).all()
    assert spec.num_markets == 5
    assert spec.coupled_markets.size == 0


def test_coupling_ring():
    spec = CouplingSpec.ring(4)
    assert spec.peer.tolist() == [1, 2, 3, 0]
    assert spec.coupled_markets.tolist() == [0, 1, 2, 3]
    back = CouplingSpec.ring(4, offset=-1)
    assert back.peer.tolist() == [3, 0, 1, 2]


def test_coupling_ring_rejects_degenerate():
    with pytest.raises(ValueError, match=">= 2 markets"):
        CouplingSpec.ring(1)
    with pytest.raises(ValueError, match="multiple of num_markets"):
        CouplingSpec.ring(4, offset=8)


def test_coupling_pairs():
    spec = CouplingSpec.pairs(6, [(0, 3), (1, 5)])
    assert spec.peer.tolist() == [3, 5, -1, 0, -1, 1]
    with pytest.raises(ValueError, match="itself"):
        CouplingSpec.pairs(4, [(2, 2)])
    with pytest.raises(ValueError, match="more than one pair"):
        CouplingSpec.pairs(4, [(0, 1), (1, 2)])
    with pytest.raises(ValueError, match="out of range"):
        CouplingSpec.pairs(4, [(0, 7)])


def test_coupling_explicit_and_bounds():
    spec = CouplingSpec.explicit({0: 2, 2: 0}, 3)
    assert spec.peer.tolist() == [2, -1, 0]
    with pytest.raises(ValueError, match="peer ids must be -1"):
        CouplingSpec(np.array([0, 9], np.int32))
    with pytest.raises(ValueError, match="at least one market"):
        CouplingSpec(np.array([], np.int32))


def test_coupling_apply_checks_width():
    spec = EnsembleSpec.coerce(MarketConfig(num_markets=4, num_agents=8,
                                            num_steps=4))
    with pytest.raises(ValueError, match="over 6 markets"):
        CouplingSpec.ring(6).apply(spec)
    coupled = coupled_ensemble(spec, CouplingSpec.ring(4))
    assert np.asarray(coupled.params.coupling_peer).ravel().tolist() \
        == [1, 2, 3, 0]
    # same static key -> same warm executable
    assert coupled.static_key() == spec.static_key()


# ---------------------------------------------------------------------------
# Coupled dynamics: the arbitrage channel bites, and only when populated.
# ---------------------------------------------------------------------------

def _arb_config(**kw):
    base = dict(num_markets=6, num_agents=32, num_levels=32, num_steps=16,
                seed=3, alpha_maker=0.15, alpha_arbitrageur=0.25,
                noise_delta=4.0, p_marketable=0.25)
    base.update(kw)
    return MarketConfig(**base)


def test_coupling_changes_arbitrageur_trajectories():
    """Coupling must bite once peer mids diverge. The peer mid freezes at
    chunk entry, and at step 0 every market still quotes the same opening
    mid (self gap == peer gap), so the run needs more than one chunk."""
    spec = EnsembleSpec.coerce(_arb_config())

    def run(s):
        with Engine("numpy", chunk_size=4).open(s) as sess:
            return sess.run(s.num_steps).to_numpy()

    base = run(spec)
    coupled = run(CouplingSpec.ring(6).apply(spec))
    assert not (np.asarray(base.price) == np.asarray(coupled.price)).all()


def test_coupling_inert_without_arbitrageurs():
    """Applying a coupling to an arb-free spec is bitwise a no-op."""
    spec = EnsembleSpec.coerce(_arb_config(alpha_arbitrageur=0.0))
    base = engine.simulate(spec, backend="numpy").to_numpy()
    coupled = engine.simulate(CouplingSpec.ring(6).apply(spec),
                              backend="numpy").to_numpy()
    for f in FIELDS:
        assert (getattr(base, f) == getattr(coupled, f)).all(), f


@pytest.mark.parametrize("backend", ["jax-scan", "jax-per-step",
                                     "pallas-naive", "pallas-kinetic"])
def test_coupled_backend_parity(backend):
    """Coupled runs are bitwise identical across the counter-RNG backends
    when the chunk lengths (= peer-mid freeze boundaries) agree."""
    spec = CouplingSpec.ring(6).apply(EnsembleSpec.coerce(_arb_config()))

    def run(b):
        with Engine(b, chunk_size=4).open(spec) as s:
            return s.run(spec.num_steps).to_numpy()

    want, got = run("numpy"), run(backend)
    for f, a, b in zip(want._fields, want, got):
        assert (np.asarray(a) == np.asarray(b)).all(), (backend, f)


def test_coupled_sessions_share_warm_executable():
    """Rewiring / toggling the coupling is a value change: sessions over
    any coupling graph of the same spec reuse one compiled executable."""
    spec = EnsembleSpec.coerce(_arb_config())
    eng = Engine("jax-scan", chunk_size=4)
    with eng.open(CouplingSpec.ring(6).apply(spec)) as s:
        s.run(spec.num_steps)
    warm = eng.trace_count
    for coupling in (CouplingSpec.none(6), CouplingSpec.pairs(6, [(0, 5)]),
                     CouplingSpec.ring(6, offset=2)):
        with eng.open(coupling.apply(spec)) as s:
            s.run(spec.num_steps)
    assert eng.trace_count == warm


# ---------------------------------------------------------------------------
# Sharded halo exchange: single-device == 2-device, bitwise (subprocess
# probes for tier-1, in-process variants for the distributed CI tier).
# ---------------------------------------------------------------------------

# Odd M across 2 devices (pads on the sharded layout), ring coupling so
# every shard boundary is a cross-device edge, chunk boundary mid-run.
_COUPLED_CFG = ("dict(num_markets=10, num_agents=16, num_levels=32, "
                "num_steps=20, seed=7, alpha_maker=0.15, "
                "alpha_arbitrageur=0.25, noise_delta=4.0)")

_COUPLED_PARITY_CODE = textwrap.dedent(f"""
    import numpy as np, jax
    from repro.core.config import MarketConfig
    from repro.core.params import EnsembleSpec
    from repro.core.session import Engine
    from repro.scenario import CouplingSpec
    assert len(jax.devices()) >= 2, jax.devices()
    spec = CouplingSpec.ring(10).apply(
        EnsembleSpec.coerce(MarketConfig(**{_COUPLED_CFG})))

    def run(**opts):
        eng = Engine("pallas-kinetic", chunk_size=6, **opts)
        with eng.open(spec) as s:
            batch = s.run(spec.num_steps).to_numpy()
        return batch, eng

    single, _ = run()
    sharded, eng = run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), f
    # warm coupled re-run on the sharded engine: no retrace
    warm = eng.trace_count
    with eng.open(CouplingSpec.ring(10, offset=3).apply(spec)) as s:
        s.run(spec.num_steps)
    assert eng.trace_count == warm, (eng.trace_count, warm)
    print("OK")
""")


def test_coupled_sharded_bitwise_parity_subprocess():
    """Ring-coupled ensemble: the ppermute halo exchange reproduces the
    single-device gather bitwise, and rewired coupled runs stay warm."""
    out = _run_probe(_COUPLED_PARITY_CODE, devices=2)
    assert out.strip().splitlines()[-1] == "OK"


@pytest.mark.distributed
@pytest.mark.parametrize("backend", ["pallas-kinetic", "pallas-naive"])
def test_coupled_sharded_bitwise_parity_inprocess(backend):
    if _device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    spec = CouplingSpec.ring(10).apply(EnsembleSpec.coerce(MarketConfig(
        num_markets=10, num_agents=16, num_levels=32, num_steps=20, seed=7,
        alpha_maker=0.15, alpha_arbitrageur=0.25, noise_delta=4.0)))

    def run(**opts):
        with Engine(backend, chunk_size=6, **opts).open(spec) as s:
            return s.run(spec.num_steps).to_numpy()

    single, sharded = run(), run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), (backend, f)


# ---------------------------------------------------------------------------
# Sequential-clearing reference.
# ---------------------------------------------------------------------------

_SEQ_CFG = MarketConfig(num_markets=8, num_agents=24, num_levels=32,
                        num_steps=12, seed=5, alpha_maker=0.15,
                        alpha_momentum=0.15)


def test_sequential_numpy_matches_jax_reference_bitwise():
    """The NumPy host loop and the jitted lax.fori_loop reference are
    bitwise identical (exact-integer f32 arithmetic)."""
    host = engine.simulate(_SEQ_CFG, backend="numpy",
                           clearing="sequential").to_numpy()
    jitted = ref.simulate_reference_sequential(_SEQ_CFG).to_numpy()
    for f in FIELDS:
        a, b = getattr(host, f), getattr(jitted, f)
        assert a.dtype == b.dtype and a.shape == b.shape, f
        assert (a == b).all(), f


def test_sequential_differs_from_parallel():
    """The mechanism itself must matter: same decisions, different books."""
    par = engine.simulate(_SEQ_CFG, backend="numpy").to_numpy()
    seq = engine.simulate(_SEQ_CFG, backend="numpy",
                          clearing="sequential").to_numpy()
    assert not (par.price_path == seq.price_path).all()


def test_sequential_book_masses_stay_integral():
    """Fills are exact integers in f32: books never accumulate dust."""
    seq = engine.simulate(_SEQ_CFG, backend="numpy",
                          clearing="sequential").to_numpy()
    for f in ("bid", "ask", "volume_path"):
        arr = np.asarray(getattr(seq, f))
        assert (arr == np.round(arr)).all(), f
        assert (arr >= 0).all(), f


def test_sequential_rejects_unknown_mode():
    with pytest.raises(ValueError, match="clearing"):
        engine.simulate(_SEQ_CFG, backend="numpy", clearing="continuous")


def test_mechanism_gap_reports_all_metrics():
    row = mechanism_gap(_SEQ_CFG)
    for m in GAP_METRICS:
        for suffix in ("parallel", "sequential", "delta"):
            assert f"{m}_{suffix}" in row, (m, suffix)
        assert row[f"{m}_delta"] == pytest.approx(
            row[f"{m}_sequential"] - row[f"{m}_parallel"])
    # the parallel column is the production engine's own numbers
    want = engine.simulate(_SEQ_CFG, backend="numpy").to_numpy()
    assert row["mean_clearing_price_parallel"] == pytest.approx(
        want.mean_clearing_price())
    # and the mechanisms genuinely disagree somewhere
    assert any(row[f"{m}_delta"] != 0.0 for m in GAP_METRICS)


# ---------------------------------------------------------------------------
# Stylized-facts gate: typed checks (fast); the pinned CI gate is
# scenario-marked (it runs four 64x500 simulations).
# ---------------------------------------------------------------------------

def test_factcheck_semantics():
    ok = FactCheck.check("kurt", 4.2, ">", 3.0)
    assert ok.passed and "PASS" in str(ok)
    bad = FactCheck.check("kurt", 2.0, ">", 3.0)
    assert not bad.passed and "FAIL" in str(bad)
    assert not FactCheck.check("nan", float("nan"), ">", -1e9).passed
    with pytest.raises(ValueError, match="op"):
        FactCheck.check("kurt", 1.0, ">=", 0.0)


def test_validation_report_structure():
    cfg = scenario_config("high-vol", num_markets=8, num_agents=32,
                          num_steps=24, alpha_maker=0.15,
                          alpha_momentum=0.4, seed=1)
    rep = validate_spec(cfg, backend="numpy", min_excess_kurtosis=-100.0,
                        min_vv_corr=-2.0, require_acf_decay=False)
    assert isinstance(rep, ValidationReport)
    assert rep.scenario == "high-vol" and rep.passed
    assert rep.failures == ()
    d = rep.to_dict()
    assert d["passed"] and {c["name"] for c in d["checks"]} \
        == {"excess_kurtosis", "volume_volatility_corr"}
    # an unsatisfiable threshold flips the report
    rep2 = validate_spec(cfg, backend="numpy", min_vv_corr=2.0,
                         require_acf_decay=False)
    assert not rep2.passed
    assert [c.name for c in rep2.failures] == ["volume_volatility_corr"]
    assert "FAIL" in rep2.summary()


@pytest.mark.scenario
def test_pinned_mixtures_pass_realism_gate():
    """The CI realism gate: every pinned mixture exhibits fat tails,
    positive volume/volatility correlation, and a decaying |r| ACF, and
    the path moments agree with the in-kernel statistics accumulators."""
    from repro.scenario import validate_pinned

    reports = validate_pinned("jax-scan", stats_check=True)
    assert set(reports) == {"high-vol-momentum", "whale", "hft", "informed"}
    failed = {n: r.summary() for n, r in reports.items() if not r.passed}
    assert not failed, failed


# ---------------------------------------------------------------------------
# New-archetype behavior units.
# ---------------------------------------------------------------------------

def test_whale_cadence_drives_volume_spikes():
    cfg = scenario_config("whale", num_markets=16, num_agents=64,
                          num_steps=48, seed=9)
    r = engine.simulate(cfg, backend="numpy").to_numpy()
    vol = np.asarray(r.volume_path)
    steps = np.arange(cfg.num_steps)
    sweep = (steps % cfg.whale_period) == 0
    assert vol[:, sweep].mean() > 1.5 * vol[:, ~sweep].mean()


def test_hft_joins_the_pressure_side():
    from repro.core import agents
    from repro.core import params as params_mod

    cfg = MarketConfig(num_markets=4, num_agents=8, num_levels=32,
                       num_steps=4, alpha_maker=0.0, alpha_momentum=0.0,
                       alpha_hft=1.0, hft_threshold=0.3, p_marketable=0.0,
                       seed=2)
    p = params_mod.scalar_params(cfg, np)
    mid = np.full((4, 1), 16.0, np.float32)
    ids = np.arange(4, dtype=np.int32).reshape(-1, 1)
    agent_ids = np.arange(8, dtype=np.int32)
    # beyond-threshold bid pressure -> every HFT buys one tick through mid
    imb = np.array([[0.9], [-0.9], [0.9], [-0.9]], np.float32)
    side, price, qty = agents.decide(cfg, p, mid, mid, np.int32(1), ids,
                                     agent_ids, np, imbalance=imb)
    assert side[0].all() and side[2].all()
    assert (~side[1]).all() and (~side[3]).all()
    assert (price[0] == 17).all() and (price[1] == 15).all()
    # below threshold the side is the noise draw, not the imbalance sign
    calm, _, _ = agents.decide(cfg, p, mid, mid, np.int32(1), ids,
                               agent_ids, np,
                               imbalance=np.full((4, 1), 0.1, np.float32))
    assert 0 < calm.sum() < calm.size


def test_informed_sell_window_before_shock():
    from repro.core import agents
    from repro.core import params as params_mod

    cfg = MarketConfig(num_markets=2, num_agents=16, num_levels=32,
                       num_steps=20, alpha_maker=0.0, alpha_momentum=0.0,
                       alpha_informed=1.0, shock_step=10,
                       informed_horizon=4, shock_intensity=0.3, seed=2)
    p = params_mod.scalar_params(cfg, np)
    mid = np.full((2, 1), 16.0, np.float32)
    ids = np.arange(2, dtype=np.int32).reshape(-1, 1)
    agent_ids = np.arange(16, dtype=np.int32)
    # inside [shock-horizon, shock): everyone sells marketably at level 0
    side, price, _ = agents.decide(cfg, p, mid, mid, np.int32(7), ids,
                                   agent_ids, np)
    assert (~side).all() and (price == 0).all()
    # outside the window: noise-like (both sides appear)
    side2, price2, _ = agents.decide(cfg, p, mid, mid, np.int32(2), ids,
                                     agent_ids, np)
    assert 0 < side2.sum() < side2.size
    assert (price2 > 0).any()


def test_arbitrageur_chases_peer_gap():
    from repro.core import agents
    from repro.core import params as params_mod

    cfg = MarketConfig(num_markets=2, num_agents=8, num_levels=32,
                       num_steps=4, alpha_maker=0.0, alpha_momentum=0.0,
                       alpha_arbitrageur=1.0, seed=2)
    p = params_mod.scalar_params(cfg, np)
    mid = np.full((2, 1), 16.0, np.float32)
    ids = np.arange(2, dtype=np.int32).reshape(-1, 1)
    agent_ids = np.arange(8, dtype=np.int32)
    peer = np.array([[20.0], [12.0]], np.float32)
    side, _, _ = agents.decide(cfg, p, mid, mid, np.int32(1), ids,
                               agent_ids, np, peer_mid=peer)
    assert side[0].all()      # peer above -> buy
    assert (~side[1]).all()   # peer below -> sell


# ---------------------------------------------------------------------------
# Satellites: session horizon error, NaN/inf rejection, snapshot
# back-compat.
# ---------------------------------------------------------------------------

def test_run_past_horizon_names_cursor_and_remaining():
    cfg = MarketConfig(num_markets=2, num_agents=8, num_steps=6, seed=1)
    with Engine("numpy").open(cfg) as s:
        s.run(6)
        with pytest.raises(ValueError) as exc:
            s.run(None)
    msg = str(exc.value)
    assert "step 6" in msg and "0 steps remaining" in msg
    assert "num_steps=6" in msg and "explicit n_steps" in msg


def test_with_values_rejects_non_finite_naming_field():
    spec = EnsembleSpec.coerce(MarketConfig(num_markets=3, num_agents=8,
                                            num_steps=4))
    with pytest.raises(ValueError, match=r"params\.noise_delta"):
        spec.with_values(noise_delta=float("nan"))
    with pytest.raises(ValueError, match=r"params\.arb_kappa"):
        spec.with_values(arb_kappa=[1.0, float("inf"), 1.0])


def test_product_rejects_non_finite_naming_field():
    base = MarketConfig(num_markets=2, num_agents=8, num_steps=4)
    with pytest.raises(ValueError, match=r"params\.fundamentalist_kappa"):
        EnsembleSpec.product(base,
                             {"fundamentalist_kappa": [0.1, float("nan")]})


def test_snapshot_restore_without_new_param_columns():
    """Snapshots written before the scenario engine (no archetype counts,
    no coupling column) restore with the inert defaults and continue the
    exact stream."""
    cfg = MarketConfig(num_markets=4, num_agents=16, num_levels=32,
                       num_steps=12, seed=6, alpha_maker=0.15)
    eng = Engine("numpy")
    with eng.open(cfg) as s:
        s.run(6)
        snap = s.snapshot()
        want = s.run(6).to_numpy()
    legacy_fields = ("num_whales", "num_hft", "num_informed",
                     "num_arbitrageurs", "whale_size", "whale_period",
                     "hft_threshold", "informed_horizon", "arb_kappa",
                     "coupling_peer")
    for f in legacy_fields:
        snap["params"].pop(f, None)
    with eng.open(cfg) as s:
        s.restore(snap)
        got = s.run(6).to_numpy()
    for f, a, b in zip(want._fields, want, got):
        assert (np.asarray(a) == np.asarray(b)).all(), f
