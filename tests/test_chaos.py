"""Chaos tier (`-m chaos`): every fault class recovers bitwise.

Each test drives :func:`repro.ops.chaos.run_plan` through a fault and
asserts the recovered trajectory is **bitwise-identical** to a fault-free
run — replayed chunks equal the originally streamed ones, the concatenated
stream equals the baseline, and the typed corruption errors actually fired
(damaged checkpoints must never load silently). Single-device cases run
in-process; the sharded cases re-run the same plans in a forced-2-device
subprocess (the `_run_probe` pattern from test_distributed.py), so both
paths are covered on any machine.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.config import scenario_config
from repro.core.session import Engine
from repro.ops import (AutotuneOOM, CheckpointCorruption, DeviceLoss,
                       FaultPlan, run_plan)

pytestmark = pytest.mark.chaos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Flash-crash so the recovery window replays a shock (shock_step=11 sits
# between the step-6 checkpoint and the step-18 faults); chunk=6 makes
# 12/18 chunk-boundary fault coordinates.
CFG_KW = dict(num_markets=6, num_agents=16, num_levels=32, num_steps=24,
              shock_step=11, seed=7)
CHUNK = 6

BACKENDS = ["pallas-kinetic", "numpy-pcg64"]


def _cfg():
    return scenario_config("flash-crash", **CFG_KW)


def _baseline(backend):
    with Engine(backend, chunk_size=CHUNK).open(_cfg()) as s:
        return s.run(CFG_KW["num_steps"]).to_numpy()


def _assert_bitwise(report, want, ctx):
    assert report.replay_matched, f"{ctx}: replayed chunks diverged"
    got = report.batch
    for f, a, b in zip(want._fields, want, got):
        assert (np.asarray(a) == np.asarray(b)).all(), \
            f"{ctx}: stream field {f} differs after recovery"


# ---------------------------------------------------------------------------
# single-device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_device_loss_restores_from_last_snapshot(backend, tmp_path):
    """Plain restart: rebuild the engine, restore the newest checkpoint,
    replay — bitwise."""
    want = _baseline(backend)
    plan = FaultPlan([DeviceLoss(at_step=18)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend=backend, ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, f"{backend} device-loss")
    ev = rep.events[0]
    assert ev.at_step == 18 and ev.recovered_from == 18
    assert not ev.errors


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind,target", [("truncate", "shard"),
                                         ("bitflip", "shard"),
                                         ("truncate", "manifest"),
                                         ("bitflip", "manifest")])
def test_checkpoint_corruption_falls_back_typed(backend, kind, target,
                                                tmp_path):
    """A damaged newest checkpoint raises a typed CheckpointCorruptError —
    never loads silently — and recovery falls back to the previous intact
    step, still bitwise."""
    want = _baseline(backend)
    plan = FaultPlan([CheckpointCorruption(at_step=18, kind=kind,
                                           target=target)],
                     checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend=backend, ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, f"{backend} corruption {kind}/{target}")
    ev = rep.events[0]
    # the corrupt step-18 checkpoint was rejected; step 12 loaded
    assert ev.recovered_from == 12
    assert any("CheckpointCorruptError" in e or "CheckpointError" in e
               for e in ev.errors), ev.errors


def test_autotune_oom_falls_back_to_conservative_tile(tmp_path):
    """Restarting with an OOM-shaped autotune sweep degrades to the
    heuristic tile (never crashes); the recovered stream stays bitwise."""
    from repro.kernels import autotune as tune

    want = _baseline("pallas-kinetic")
    plan = FaultPlan([AutotuneOOM(at_step=12)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend="pallas-kinetic", ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, "pallas-kinetic autotune-oom")
    ev = rep.events[0]
    assert "fell_back=True" in ev.detail
    assert ev.errors and all("RESOURCE_EXHAUSTED" in e for e in ev.errors)
    report = tune.last_sweep_report()
    assert report is not None and report.fell_back
    tune.clear_tune_cache()


def test_multiple_faults_in_one_plan(tmp_path):
    """Faults compose: a corruption at 12 then a device loss at 18."""
    want = _baseline("pallas-kinetic")
    plan = FaultPlan([CheckpointCorruption(at_step=12, kind="bitflip"),
                      DeviceLoss(at_step=18)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend="pallas-kinetic", ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, "pallas-kinetic multi-fault")
    assert [e.at_step for e in rep.events] == [12, 18]
    assert rep.events[0].recovered_from == 6   # step-12 ckpt was corrupted
    assert rep.events[1].recovered_from == 18  # rewritten intact on replay


def test_torn_checkpoint_write_every_offset(tmp_path):
    """Crash the checkpoint commit at EVERY durable-write offset: the
    restart must restore a committed checkpoint — the previous one, or the
    new one when the crash landed after the COMMIT rename — and replay
    bitwise. A torn write never yields loadable-but-wrong state."""
    from repro.checkpoint.manager import CheckpointManager, session_tree
    from repro.ops import TornCheckpointWrite, count_write_ops

    want = _baseline("numpy-pcg64")
    with Engine("numpy-pcg64", chunk_size=CHUNK).open(_cfg()) as s:
        s.run(12)
        tree = session_tree(s.snapshot())
    ops = count_write_ops(
        CheckpointManager(tmp_path / "probe", async_write=False), 12, tree)
    assert ops >= 15
    for k in range(ops):
        plan = FaultPlan([TornCheckpointWrite(at_step=12, crash_at_op=k)],
                         checkpoint_every=CHUNK)
        rep = run_plan(plan, _cfg(), backend="numpy-pcg64",
                       ckpt_dir=tmp_path / f"op{k}", chunk_size=CHUNK)
        _assert_bitwise(rep, want, f"torn write at op {k}")
        ev = rep.events[0]
        assert any("SimulatedCrash" in e for e in ev.errors), (k, ev.errors)
        # the step-12 rewrite was uncommitted first, so a crash mid-commit
        # falls back to 6; a crash after the COMMIT rename keeps 12
        assert ev.recovered_from in (6, 12), (k, ev)


def test_plan_validates_chunk_alignment():
    with pytest.raises(ValueError, match="chunk boundary"):
        run_plan(FaultPlan([DeviceLoss(at_step=7)]), _cfg(),
                 backend="numpy", ckpt_dir="/tmp/unused", chunk_size=6)
    with pytest.raises(ValueError, match="checkpoint_every"):
        run_plan(FaultPlan([DeviceLoss(at_step=6)], checkpoint_every=5),
                 _cfg(), backend="numpy", ckpt_dir="/tmp/unused",
                 chunk_size=6)
    with pytest.raises(ValueError, match="window"):
        run_plan(FaultPlan([DeviceLoss(at_step=600)]), _cfg(),
                 backend="numpy", ckpt_dir="/tmp/unused", chunk_size=6)


# ---------------------------------------------------------------------------
# sharded (forced-2-device subprocess, as in test_distributed.py)
# ---------------------------------------------------------------------------

def _run_probe(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_SHARDED_PROLOGUE = textwrap.dedent(f"""
    import tempfile, numpy as np, jax
    assert len(jax.devices()) == 2, jax.devices()
    from repro.core.config import scenario_config
    from repro.core.session import Engine
    from repro.ops import (AutotuneOOM, CheckpointCorruption, DeviceLoss,
                           FaultPlan, run_plan)
    cfg = scenario_config("flash-crash", **{CFG_KW!r})
    with Engine("pallas-kinetic", chunk_size={CHUNK}).open(cfg) as s:
        want = s.run(cfg.num_steps).to_numpy()

    def check(fault, expect_recovered, expect_errors=0):
        with tempfile.TemporaryDirectory() as d:
            rep = run_plan(FaultPlan([fault], checkpoint_every={CHUNK}),
                           cfg, backend="pallas-kinetic", ckpt_dir=d,
                           chunk_size={CHUNK}, engine_opts={{"devices": 2}})
        ev = rep.events[0]
        assert rep.replay_matched, fault
        for f, a, b in zip(want._fields, want, rep.batch):
            assert (np.asarray(a) == np.asarray(b)).all(), (fault, f)
        assert ev.recovered_from == expect_recovered, ev
        assert len(ev.errors) >= expect_errors, ev
        return ev
""")


def test_sharded_device_loss_shrinks_mesh_subprocess():
    """Drop one of two devices mid-run: the snapshot restores onto the
    1-device topology (layout-portable) and the stream stays bitwise equal
    to the single-device baseline."""
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        ev = check(DeviceLoss(at_step=18, devices_after=1), 18)
        assert "devices=1" in ev.detail, ev.detail
        ev = check(DeviceLoss(at_step=18, lost_device=0), 18)
        assert "1 survivors" in ev.detail, ev.detail
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_checkpoint_corruption_subprocess():
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        for kind in ("truncate", "bitflip"):
            ev = check(CheckpointCorruption(at_step=18, kind=kind), 12,
                       expect_errors=1)
            assert any("CheckpointCorruptError" in e for e in ev.errors), ev
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_autotune_oom_subprocess():
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        ev = check(AutotuneOOM(at_step=12), 12, expect_errors=1)
        assert "fell_back=True" in ev.detail, ev.detail
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


# ---------------------------------------------------------------------------
# serving gateway: device loss under concurrent client load
# ---------------------------------------------------------------------------

SCENARIOS = ["baseline", "flash-crash", "high-vol"]


def _assert_serve_bitwise(rep, want, ctx):
    assert set(rep.frames) == set(want.frames), ctx
    for client in want.frames:
        fs0, fs1 = want.frames[client], rep.frames[client]
        assert len(fs0) == len(fs1), \
            f"{ctx}: client {client} got {len(fs1)} frames, want {len(fs0)}"
        for f0, f1 in zip(fs0, fs1):
            assert f0.step0 == f1.step0 and f0.seq == f1.seq, \
                f"{ctx}: client {client} frame misaligned at seq {f0.seq}"
            for field in ("mid", "price", "volume"):
                assert (np.asarray(getattr(f0, field))
                        == np.asarray(getattr(f1, field))).all(), \
                    f"{ctx}: client {client} {field} diverged at {f0.step0}"


def test_serve_device_loss_under_client_load(tmp_path):
    """Kill the engine under concurrent streaming clients (one attached
    after the newest checkpoint, so recovery must replay the splice
    journal): every client observes a ``reconnect`` event and its stream
    continues bitwise-identical to a fault-free run."""
    from repro.ops import run_serve_plan

    kw = dict(scenarios=SCENARIOS, backend="jax-scan", chunk_size=8,
              chunks=10, checkpoint_every=2, late_attach="thin-book",
              late_after=5)
    want = run_serve_plan(ckpt_dir=tmp_path / "ff", **kw)
    rep = run_serve_plan(ckpt_dir=tmp_path / "f1",
                         fault=DeviceLoss(at_step=0), fault_after=3, **kw)
    assert want.reconnects == 0 and rep.reconnects == 1
    for client, events in rep.events.items():
        # every client (including "late", attached before the fault fires)
        # observes the recovery
        assert any(e.kind == "reconnect" for e in events), \
            f"client {client} never saw the reconnect event"
    _assert_serve_bitwise(rep, want, "serve device-loss")
    assert rep.traces_delta == 0, \
        f"{rep.traces_delta} retraces after recovery re-warm"


def test_serve_fault_storm_coalesces_into_one_recovery(tmp_path):
    """A reconnect storm: 16 concurrent clients, four back-to-back device
    losses. The supervisor must coalesce the storm into ONE recovery pass
    — every client sees exactly one ``reconnect`` broadcast — and every
    stream resumes bitwise."""
    from repro.ops import run_serve_plan

    scen = (SCENARIOS * 6)[:16]
    kw = dict(scenarios=scen, backend="numpy-pcg64", chunk_size=8,
              chunks=10, checkpoint_every=2, slots=16)
    want = run_serve_plan(ckpt_dir=tmp_path / "ff", **kw)
    storm = [DeviceLoss(at_step=0)] * 4
    rep = run_serve_plan(ckpt_dir=tmp_path / "f1", fault=storm,
                         fault_after=3, **kw)
    assert rep.recoveries == 1, rep.recoveries   # 4 faults -> ONE pass
    assert rep.reconnects == 1
    for client, events in rep.events.items():
        recs = [e for e in events if e.kind == "reconnect"]
        assert len(recs) == 1, (client, [e.kind for e in events])
        assert recs[0].payload["faults_coalesced"] == 4, recs[0].payload
    _assert_serve_bitwise(rep, want, "serve fault-storm")
    assert rep.traces_delta == 0
    assert rep.health is not None and rep.health["state"] == "serving"


def test_serve_journal_compaction_never_breaks_replay(tmp_path):
    """A 2-deep checkpoint ladder under checkpoint_every=1 forces GC —
    and therefore splice-journal compaction — repeatedly mid-run; a late
    fault must still recover bitwise from what remains."""
    from repro.ops import run_serve_plan
    from repro.serve import SpliceJournal

    # fault_after is in kw for BOTH runs: it also sets how many frames are
    # consumed before the late attach, which fixes the attach boundary
    kw = dict(scenarios=SCENARIOS, backend="numpy-pcg64", chunk_size=8,
              chunks=12, checkpoint_every=1, ckpt_keep=2,
              late_attach="thin-book", late_after=3, fault_after=8)
    want = run_serve_plan(ckpt_dir=tmp_path / "ff", **kw)
    rep = run_serve_plan(ckpt_dir=tmp_path / "f1",
                         fault=DeviceLoss(at_step=0), **kw)
    assert rep.recoveries == 1
    _assert_serve_bitwise(rep, want, "serve compaction")
    assert rep.traces_delta == 0
    # compaction really fired: the t=0 admission splice predates every
    # retained checkpoint and must be gone from the durable journal
    entries = SpliceJournal(tmp_path / "f1").entries()
    assert all(e.t > 0 for e in entries), [e.t for e in entries]


# ---------------------------------------------------------------------------
# full process crash (kill -9) + restart: the durable-restart guarantee
# ---------------------------------------------------------------------------

_CRASH_PHASE1 = textwrap.dedent("""
    import asyncio, json, os, sys
    import numpy as np
    from repro.serve import Gateway, parked_template

    d, out = sys.argv[1], sys.argv[2]
    tpl = parked_template(slots=3, num_agents=16, num_levels=32,
                          num_steps=4096)

    async def main():
        gw = Gateway(tpl, backend="numpy-pcg64", chunk_size=8,
                     queue_maxsize=64, ckpt_dir=d, checkpoint_every=1)
        await gw.start(chunks=10)
        scen = ["baseline", "flash-crash", "high-vol"]
        clients = [gw.open_session(s, client=f"c{i}")
                   for i, s in enumerate(scen)]
        f = open(out, "a")
        written = 0

        async def pump(cs):
            nonlocal written
            while True:
                fr = await cs.next_frame()
                if fr is None:
                    return
                f.write(json.dumps({
                    "client": cs.client, "step0": fr.step0,
                    "mid": np.asarray(fr.mid).tolist(),
                    "price": np.asarray(fr.price).tolist()}) + "\\n")
                f.flush()
                os.fsync(f.fileno())
                written += 1
                if written >= 9:
                    os.kill(os.getpid(), 9)   # kill -9, mid-stream

        await asyncio.gather(*(pump(c) for c in clients))

    asyncio.run(main())
""")

_CRASH_PHASE2 = textwrap.dedent("""
    import asyncio, json, sys
    import numpy as np
    from repro.serve import Gateway, parked_template

    d, out = sys.argv[1], sys.argv[2]
    tpl = parked_template(slots=3, num_agents=16, num_levels=32,
                          num_steps=4096)

    async def main():
        gw = Gateway(tpl, backend="numpy-pcg64", chunk_size=8,
                     queue_maxsize=64, ckpt_dir=d, checkpoint_every=1)
        await gw.start(chunks=12)          # restart path: committed ladder
        assert gw.resumed_from is not None, "no committed ladder found"
        assert not gw.restart_errors, gw.restart_errors
        slots = sorted(gw.scheduler.attached)
        assert slots == [0, 1, 2], slots   # attachments rebuilt from disk
        clients = [gw.resume_session(s, client=f"r{s}") for s in slots]
        rest = await asyncio.gather(*(c.frames(12) for c in clients))
        with open(out, "w") as f:
            for slot, frames in zip(slots, rest):
                for fr in frames:
                    f.write(json.dumps({
                        "client": f"c{slot}", "step0": fr.step0,
                        "mid": np.asarray(fr.mid).tolist(),
                        "price": np.asarray(fr.price).tolist()}) + "\\n")
        for c in clients:
            att = [e for e in c.events if e.kind == "attached"]
            assert att and att[0].payload.get("resumed") is True, c.events
        assert gw.traces_delta == 0, gw.traces_delta
        await gw.stop()
        print("RESUMED", gw.resumed_from)

    asyncio.run(main())
""")


def test_serve_crash_restart_resumes_bitwise(tmp_path):
    """kill -9 a streaming gateway process mid-delivery, restart a fresh
    process over the same ckpt_dir: the newest committed checkpoint is
    restored, journaled splices replay from disk, clients re-subscribe via
    resume_session, and every frame either phase produced bitwise-matches
    a crash-free reference at the same step coordinate."""
    import json as _json

    from repro.ops import run_serve_plan

    want = run_serve_plan(scenarios=SCENARIOS, backend="numpy-pcg64",
                          chunk_size=8, chunks=18, checkpoint_every=1,
                          ckpt_dir=tmp_path / "ref")
    ref = {}
    for client, frames in want.frames.items():
        for fr in frames:
            ref[(client, fr.step0)] = (np.asarray(fr.mid).tolist(),
                                       np.asarray(fr.price).tolist())
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    d = str(tmp_path / "crash")
    out1, out2 = str(tmp_path / "phase1.jsonl"), str(tmp_path / "p2.jsonl")
    p1 = subprocess.run([sys.executable, "-c", _CRASH_PHASE1, d, out1],
                        env=env, capture_output=True, text=True, timeout=300)
    assert p1.returncode == -9, (p1.returncode, p1.stderr[-3000:])
    p2 = subprocess.run([sys.executable, "-c", _CRASH_PHASE2, d, out2],
                        env=env, capture_output=True, text=True, timeout=300)
    assert p2.returncode == 0, p2.stderr[-3000:]
    resumed = int(p2.stdout.split("RESUMED")[1].split()[0])
    assert resumed % 8 == 0
    with open(out1) as f:
        phase1 = [_json.loads(ln) for ln in f]
    with open(out2) as f:
        phase2 = [_json.loads(ln) for ln in f]
    assert len(phase1) == 9            # the fsync'd pre-crash deliveries
    matched = 0
    for r in phase1 + phase2:
        key = (r["client"], r["step0"])
        if key not in ref:             # past the reference horizon
            continue
        m, p = ref[key]
        assert r["mid"] == m and r["price"] == p, \
            f"frame {key} diverged from the crash-free reference"
        matched += 1
    assert matched >= 18, matched      # pre-crash + post-restart overlap
    # phase 2 streamed contiguously from the restored cursor
    steps2 = sorted({r["step0"] for r in phase2})
    assert steps2[0] == resumed
    assert steps2 == list(range(resumed, resumed + 8 * len(steps2), 8))


def test_serve_sharded_device_loss_subprocess():
    """Drop one of two devices under live client load: the gateway rebuilds
    on the survivor, clients reconnect, and post-recovery trajectories
    bitwise-match the fault-free sharded run."""
    out = _run_probe(textwrap.dedent("""
        import tempfile, numpy as np, jax
        assert len(jax.devices()) == 2, jax.devices()
        from repro.ops import DeviceLoss, run_serve_plan
        kw = dict(scenarios=["baseline", "flash-crash", "high-vol"],
                  backend="pallas-kinetic", chunk_size=8, chunks=8,
                  checkpoint_every=2, slots=4, num_agents=16, num_levels=32,
                  engine_opts={"devices": 2})
        with tempfile.TemporaryDirectory() as d:
            want = run_serve_plan(ckpt_dir=d, **kw)
        with tempfile.TemporaryDirectory() as d:
            rep = run_serve_plan(ckpt_dir=d, fault_after=3,
                                 fault=DeviceLoss(at_step=0,
                                                  devices_after=1), **kw)
        assert rep.reconnects == 1, rep.events
        for client in want.frames:
            fs0, fs1 = want.frames[client], rep.frames[client]
            assert len(fs0) == len(fs1), (client, len(fs0), len(fs1))
            for f0, f1 in zip(fs0, fs1):
                assert f0.step0 == f1.step0, (client, f0.step0, f1.step0)
                assert (f0.mid == f1.mid).all(), (client, f0.step0)
                assert (f0.price == f1.price).all(), (client, f0.step0)
        assert rep.traces_delta == 0, rep.traces_delta
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"
