"""Chaos tier (`-m chaos`): every fault class recovers bitwise.

Each test drives :func:`repro.ops.chaos.run_plan` through a fault and
asserts the recovered trajectory is **bitwise-identical** to a fault-free
run — replayed chunks equal the originally streamed ones, the concatenated
stream equals the baseline, and the typed corruption errors actually fired
(damaged checkpoints must never load silently). Single-device cases run
in-process; the sharded cases re-run the same plans in a forced-2-device
subprocess (the `_run_probe` pattern from test_distributed.py), so both
paths are covered on any machine.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.config import scenario_config
from repro.core.session import Engine
from repro.ops import (AutotuneOOM, CheckpointCorruption, DeviceLoss,
                       FaultPlan, run_plan)

pytestmark = pytest.mark.chaos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Flash-crash so the recovery window replays a shock (shock_step=11 sits
# between the step-6 checkpoint and the step-18 faults); chunk=6 makes
# 12/18 chunk-boundary fault coordinates.
CFG_KW = dict(num_markets=6, num_agents=16, num_levels=32, num_steps=24,
              shock_step=11, seed=7)
CHUNK = 6

BACKENDS = ["pallas-kinetic", "numpy-pcg64"]


def _cfg():
    return scenario_config("flash-crash", **CFG_KW)


def _baseline(backend):
    with Engine(backend, chunk_size=CHUNK).open(_cfg()) as s:
        return s.run(CFG_KW["num_steps"]).to_numpy()


def _assert_bitwise(report, want, ctx):
    assert report.replay_matched, f"{ctx}: replayed chunks diverged"
    got = report.batch
    for f, a, b in zip(want._fields, want, got):
        assert (np.asarray(a) == np.asarray(b)).all(), \
            f"{ctx}: stream field {f} differs after recovery"


# ---------------------------------------------------------------------------
# single-device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_device_loss_restores_from_last_snapshot(backend, tmp_path):
    """Plain restart: rebuild the engine, restore the newest checkpoint,
    replay — bitwise."""
    want = _baseline(backend)
    plan = FaultPlan([DeviceLoss(at_step=18)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend=backend, ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, f"{backend} device-loss")
    ev = rep.events[0]
    assert ev.at_step == 18 and ev.recovered_from == 18
    assert not ev.errors


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind,target", [("truncate", "shard"),
                                         ("bitflip", "shard"),
                                         ("truncate", "manifest"),
                                         ("bitflip", "manifest")])
def test_checkpoint_corruption_falls_back_typed(backend, kind, target,
                                                tmp_path):
    """A damaged newest checkpoint raises a typed CheckpointCorruptError —
    never loads silently — and recovery falls back to the previous intact
    step, still bitwise."""
    want = _baseline(backend)
    plan = FaultPlan([CheckpointCorruption(at_step=18, kind=kind,
                                           target=target)],
                     checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend=backend, ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, f"{backend} corruption {kind}/{target}")
    ev = rep.events[0]
    # the corrupt step-18 checkpoint was rejected; step 12 loaded
    assert ev.recovered_from == 12
    assert any("CheckpointCorruptError" in e or "CheckpointError" in e
               for e in ev.errors), ev.errors


def test_autotune_oom_falls_back_to_conservative_tile(tmp_path):
    """Restarting with an OOM-shaped autotune sweep degrades to the
    heuristic tile (never crashes); the recovered stream stays bitwise."""
    from repro.kernels import autotune as tune

    want = _baseline("pallas-kinetic")
    plan = FaultPlan([AutotuneOOM(at_step=12)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend="pallas-kinetic", ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, "pallas-kinetic autotune-oom")
    ev = rep.events[0]
    assert "fell_back=True" in ev.detail
    assert ev.errors and all("RESOURCE_EXHAUSTED" in e for e in ev.errors)
    report = tune.last_sweep_report()
    assert report is not None and report.fell_back
    tune.clear_tune_cache()


def test_multiple_faults_in_one_plan(tmp_path):
    """Faults compose: a corruption at 12 then a device loss at 18."""
    want = _baseline("pallas-kinetic")
    plan = FaultPlan([CheckpointCorruption(at_step=12, kind="bitflip"),
                      DeviceLoss(at_step=18)], checkpoint_every=CHUNK)
    rep = run_plan(plan, _cfg(), backend="pallas-kinetic", ckpt_dir=tmp_path,
                   chunk_size=CHUNK)
    _assert_bitwise(rep, want, "pallas-kinetic multi-fault")
    assert [e.at_step for e in rep.events] == [12, 18]
    assert rep.events[0].recovered_from == 6   # step-12 ckpt was corrupted
    assert rep.events[1].recovered_from == 18  # rewritten intact on replay


def test_plan_validates_chunk_alignment():
    with pytest.raises(ValueError, match="chunk boundary"):
        run_plan(FaultPlan([DeviceLoss(at_step=7)]), _cfg(),
                 backend="numpy", ckpt_dir="/tmp/unused", chunk_size=6)
    with pytest.raises(ValueError, match="checkpoint_every"):
        run_plan(FaultPlan([DeviceLoss(at_step=6)], checkpoint_every=5),
                 _cfg(), backend="numpy", ckpt_dir="/tmp/unused",
                 chunk_size=6)
    with pytest.raises(ValueError, match="window"):
        run_plan(FaultPlan([DeviceLoss(at_step=600)]), _cfg(),
                 backend="numpy", ckpt_dir="/tmp/unused", chunk_size=6)


# ---------------------------------------------------------------------------
# sharded (forced-2-device subprocess, as in test_distributed.py)
# ---------------------------------------------------------------------------

def _run_probe(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_SHARDED_PROLOGUE = textwrap.dedent(f"""
    import tempfile, numpy as np, jax
    assert len(jax.devices()) == 2, jax.devices()
    from repro.core.config import scenario_config
    from repro.core.session import Engine
    from repro.ops import (AutotuneOOM, CheckpointCorruption, DeviceLoss,
                           FaultPlan, run_plan)
    cfg = scenario_config("flash-crash", **{CFG_KW!r})
    with Engine("pallas-kinetic", chunk_size={CHUNK}).open(cfg) as s:
        want = s.run(cfg.num_steps).to_numpy()

    def check(fault, expect_recovered, expect_errors=0):
        with tempfile.TemporaryDirectory() as d:
            rep = run_plan(FaultPlan([fault], checkpoint_every={CHUNK}),
                           cfg, backend="pallas-kinetic", ckpt_dir=d,
                           chunk_size={CHUNK}, engine_opts={{"devices": 2}})
        ev = rep.events[0]
        assert rep.replay_matched, fault
        for f, a, b in zip(want._fields, want, rep.batch):
            assert (np.asarray(a) == np.asarray(b)).all(), (fault, f)
        assert ev.recovered_from == expect_recovered, ev
        assert len(ev.errors) >= expect_errors, ev
        return ev
""")


def test_sharded_device_loss_shrinks_mesh_subprocess():
    """Drop one of two devices mid-run: the snapshot restores onto the
    1-device topology (layout-portable) and the stream stays bitwise equal
    to the single-device baseline."""
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        ev = check(DeviceLoss(at_step=18, devices_after=1), 18)
        assert "devices=1" in ev.detail, ev.detail
        ev = check(DeviceLoss(at_step=18, lost_device=0), 18)
        assert "1 survivors" in ev.detail, ev.detail
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_checkpoint_corruption_subprocess():
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        for kind in ("truncate", "bitflip"):
            ev = check(CheckpointCorruption(at_step=18, kind=kind), 12,
                       expect_errors=1)
            assert any("CheckpointCorruptError" in e for e in ev.errors), ev
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_autotune_oom_subprocess():
    out = _run_probe(_SHARDED_PROLOGUE + textwrap.dedent("""
        ev = check(AutotuneOOM(at_step=12), 12, expect_errors=1)
        assert "fell_back=True" in ev.detail, ev.detail
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"


# ---------------------------------------------------------------------------
# serving gateway: device loss under concurrent client load
# ---------------------------------------------------------------------------

SCENARIOS = ["baseline", "flash-crash", "high-vol"]


def _assert_serve_bitwise(rep, want, ctx):
    assert set(rep.frames) == set(want.frames), ctx
    for client in want.frames:
        fs0, fs1 = want.frames[client], rep.frames[client]
        assert len(fs0) == len(fs1), \
            f"{ctx}: client {client} got {len(fs1)} frames, want {len(fs0)}"
        for f0, f1 in zip(fs0, fs1):
            assert f0.step0 == f1.step0 and f0.seq == f1.seq, \
                f"{ctx}: client {client} frame misaligned at seq {f0.seq}"
            for field in ("mid", "price", "volume"):
                assert (np.asarray(getattr(f0, field))
                        == np.asarray(getattr(f1, field))).all(), \
                    f"{ctx}: client {client} {field} diverged at {f0.step0}"


def test_serve_device_loss_under_client_load(tmp_path):
    """Kill the engine under concurrent streaming clients (one attached
    after the newest checkpoint, so recovery must replay the splice
    journal): every client observes a ``reconnect`` event and its stream
    continues bitwise-identical to a fault-free run."""
    from repro.ops import run_serve_plan

    kw = dict(scenarios=SCENARIOS, backend="jax-scan", chunk_size=8,
              chunks=10, checkpoint_every=2, late_attach="thin-book",
              late_after=5)
    want = run_serve_plan(ckpt_dir=tmp_path / "ff", **kw)
    rep = run_serve_plan(ckpt_dir=tmp_path / "f1",
                         fault=DeviceLoss(at_step=0), fault_after=3, **kw)
    assert want.reconnects == 0 and rep.reconnects == 1
    for client, events in rep.events.items():
        # every client (including "late", attached before the fault fires)
        # observes the recovery
        assert any(e.kind == "reconnect" for e in events), \
            f"client {client} never saw the reconnect event"
    _assert_serve_bitwise(rep, want, "serve device-loss")
    assert rep.traces_delta == 0, \
        f"{rep.traces_delta} retraces after recovery re-warm"


def test_serve_sharded_device_loss_subprocess():
    """Drop one of two devices under live client load: the gateway rebuilds
    on the survivor, clients reconnect, and post-recovery trajectories
    bitwise-match the fault-free sharded run."""
    out = _run_probe(textwrap.dedent("""
        import tempfile, numpy as np, jax
        assert len(jax.devices()) == 2, jax.devices()
        from repro.ops import DeviceLoss, run_serve_plan
        kw = dict(scenarios=["baseline", "flash-crash", "high-vol"],
                  backend="pallas-kinetic", chunk_size=8, chunks=8,
                  checkpoint_every=2, slots=4, num_agents=16, num_levels=32,
                  engine_opts={"devices": 2})
        with tempfile.TemporaryDirectory() as d:
            want = run_serve_plan(ckpt_dir=d, **kw)
        with tempfile.TemporaryDirectory() as d:
            rep = run_serve_plan(ckpt_dir=d, fault_after=3,
                                 fault=DeviceLoss(at_step=0,
                                                  devices_after=1), **kw)
        assert rep.reconnects == 1, rep.events
        for client in want.frames:
            fs0, fs1 = want.frames[client], rep.frames[client]
            assert len(fs0) == len(fs1), (client, len(fs0), len(fs1))
            for f0, f1 in zip(fs0, fs1):
                assert f0.step0 == f1.step0, (client, f0.step0, f1.step0)
                assert (f0.mid == f1.mid).all(), (client, f0.step0)
                assert (f0.price == f1.price).all(), (client, f0.step0)
        assert rep.traces_delta == 0, rep.traces_delta
        print("OK")
    """))
    assert out.strip().splitlines()[-1] == "OK"
