"""ssm_scan Pallas kernel vs the pure-jnp oracle: shape/dtype sweep."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import hbm_traffic_bytes, ssm_scan
from repro.models.ssm import mamba1_scan


def _inputs(B, T, di, N, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(B, T, di).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.randn(B, T, di)).astype(np.float32) * 0.1)
    Bc = jnp.asarray(rng.randn(B, T, N).astype(np.float32))
    Cc = jnp.asarray(rng.randn(B, T, N).astype(np.float32))
    A = -jnp.asarray(np.abs(rng.randn(di, N)).astype(np.float32))
    h0 = jnp.asarray(rng.randn(B, di, N).astype(np.float32) * 0.1)
    return x, dt, Bc, Cc, A, h0


@pytest.mark.parametrize("B,T,di,N,ct", [
    (1, 16, 128, 8, 8),
    (2, 64, 128, 16, 16),
    (3, 32, 256, 16, 32),   # ct > T -> clamped
    (2, 128, 128, 4, 32),
])
def test_ssm_scan_matches_oracle(B, T, di, N, ct):
    args = _inputs(B, T, di, N, seed=B * 100 + T)
    y_ref, h_ref = mamba1_scan(*args, mode="sequential")
    y_k, h_k = ssm_scan(*args, ct=ct, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_state_persists_across_time_chunks():
    """The VMEM scratch must carry h across grid steps: results with many
    small time-chunks must equal a single-chunk run."""
    args = _inputs(2, 64, 128, 8, seed=7)
    y1, h1 = ssm_scan(*args, ct=64, interpret=True)   # one chunk
    y2, h2 = ssm_scan(*args, ct=8, interpret=True)    # eight chunks
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-6)


def test_nonzero_initial_state():
    args = list(_inputs(2, 32, 128, 8, seed=3))
    y_ref, h_ref = mamba1_scan(*args, mode="associative")
    y_k, h_k = ssm_scan(*args, ct=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_traffic_model_reduction():
    t = hbm_traffic_bytes(16, 4096, 512, 16)
    assert t["reduction"] > 10  # the N-fold collapse that motivates the kernel
