"""Ensemble-first front door: per-market scenario params as device operands.

Acceptance sweep for the `EnsembleSpec` API:
  * a homogeneous spec is bitwise-identical to the scalar `MarketConfig`
    path on every registered backend;
  * an ensemble mixing *every* scenario preset runs with exactly
    one trace and each market's order book is bitwise-identical to the
    corresponding single-scenario `MarketConfig` run — on all seven
    backends, including the stateful-PCG64 CPU reference (the fixed
    five-channel draw schedule keeps it per-market decomposable);
  * `Engine.trace_count` stays at 1 across arbitrary parameter-value
    changes (the executable cache keys on shape/structure, never values);
  * snapshots carry the per-market params and restore them (including
    through a `CheckpointManager` disk round-trip);
  * a sharded (2-device `shard_map`) mixed ensemble is bitwise-identical to
    the single-device run;
  * builder validation: static-field mismatches, out-of-range params, and
    shocks placed at/past the horizon are loud errors, and the
    default-length `run()`/`stream()` past the horizon raises instead of
    silently re-running a scenario whose events cannot fire.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine
from repro.core.config import MarketConfig, scenario_config, scenario_names
from repro.core.params import EnsembleSpec, MarketParams
from repro.core.session import Engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALL_BACKENDS = ["numpy", "numpy-splitmix64", "numpy-pcg64", "jax-scan",
                "jax-per-step", "pallas-naive", "pallas-kinetic"]

CFG = MarketConfig(num_markets=6, num_agents=16, num_levels=16, num_steps=10,
                   seed=21)

BATCH_FIELDS = ("price", "volume", "mid")
STATE_FIELDS = ("bid", "ask", "last_price", "prev_mid")

_ENGINES = {}


def _engine(backend: str) -> Engine:
    if backend not in _ENGINES:
        _ENGINES[backend] = Engine(backend)
    return _ENGINES[backend]


def _mixed_spec(num_steps=12, seed=5, markets_per_block=None):
    """One block per registered preset (+ mixture variation).

    Blocks also vary the archetype mixture so the per-market population
    counts — not just the scalar knobs — are exercised as operands. The
    block width is even so the total divides across the 2-device shard
    tests regardless of how many presets are registered.
    """
    presets = scenario_names()                       # every registered preset
    n = len(presets) + 2                             # + two mixture twists
    per = markets_per_block or 6                     # even markets/block
    common = dict(num_markets=per, num_agents=16, num_levels=16,
                  num_steps=num_steps, seed=seed)
    blocks = [scenario_config(p, **common) for p in presets]
    blocks.append(scenario_config(
        "baseline", alpha_maker=0.0, alpha_momentum=0.5,
        alpha_fundamentalist=0.25, **common))
    blocks.append(scenario_config(
        "high-vol", alpha_maker=0.25, alpha_momentum=0.0,
        alpha_fundamentalist=0.5, fundamentalist_kappa=0.9, q_max=3,
        **common))
    spec = EnsembleSpec.from_scenarios(blocks)
    assert spec.num_markets == per * n
    return spec, blocks, per


# ---------------------------------------------------------------------------
# Bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_homogeneous_spec_matches_config_bitwise(backend):
    """EnsembleSpec.homogeneous(cfg) ≡ MarketConfig, batches + final books."""
    eng = _engine(backend)
    with eng.open(CFG) as a, eng.open(EnsembleSpec.homogeneous(CFG)) as b:
        ba, bb = a.run(CFG.num_steps).to_numpy(), b.run(CFG.num_steps).to_numpy()
        for f, x, y in zip(BATCH_FIELDS, ba, bb):
            assert (x == y).all(), (backend, f)
        for f, x, y in zip(STATE_FIELDS, a.state, b.state):
            assert (np.asarray(x) == np.asarray(y)).all(), (backend, f)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_mixed_ensemble_per_market_bitwise(backend):
    """The acceptance criterion: an all-presets ensemble, each
    market bitwise-equal to the corresponding single-scenario MarketConfig
    run, with exactly one trace and one executable for everything."""
    spec, blocks, per = _mixed_spec()
    eng = Engine(backend)  # fresh: count traces from zero
    with eng.open(spec) as sess:
        mixed = sess.run(spec.num_steps).to_numpy()
        mixed_state = [np.asarray(x) for x in sess.state]
    if backend.startswith(("jax", "pallas")):
        assert eng.trace_count == 1

    for b, block in enumerate(blocks):
        solo_cfg = dataclasses.replace(block, num_markets=spec.num_markets)
        # The homogeneous solo run reuses the SAME executable: the cache
        # keys on (M, A, L, seed), which the blocks share by construction.
        with eng.open(solo_cfg) as sess:
            solo = sess.run(solo_cfg.num_steps).to_numpy()
            solo_state = [np.asarray(x) for x in sess.state]
        rows = slice(b * per, (b + 1) * per)
        for f, x, y in zip(BATCH_FIELDS, mixed, solo):
            assert (x[rows] == y[rows]).all(), (backend, block.scenario, f)
        for f, x, y in zip(STATE_FIELDS, mixed_state, solo_state):
            assert (x[rows] == y[rows]).all(), (backend, block.scenario, f)
    if backend.startswith(("jax", "pallas")):
        assert eng.trace_count == 1, "solo runs retraced the ensemble trace"


def test_mixed_ensemble_initial_books_are_per_market():
    """wide-book / thin-book presets differ only through the opening books —
    the per-market seeding must reproduce each preset's rows exactly."""
    spec, blocks, per = _mixed_spec()
    bid, ask = spec.initial_books(np)
    for b, block in enumerate(blocks):
        sb, sa = dataclasses.replace(
            block, num_markets=spec.num_markets).initial_books(np)
        rows = slice(b * per, (b + 1) * per)
        assert (bid[rows] == sb[rows]).all(), block.scenario
        assert (ask[rows] == sa[rows]).all(), block.scenario


# ---------------------------------------------------------------------------
# Compile-once across parameter changes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax-scan", "pallas-kinetic"])
def test_trace_count_stays_one_across_parameter_changes(backend):
    """Parameter values never enter the executable key: sweeping scenario
    knobs, shock schedules, and population mixtures reuses one trace."""
    eng = Engine(backend, chunk_size=5)  # explicit: shared across horizons
    with eng.open(CFG) as sess:
        sess.run(CFG.num_steps)
    assert eng.trace_count == 1
    variants = [
        dataclasses.replace(CFG, noise_delta=2.5, p_marketable=0.4),
        dataclasses.replace(CFG, q_max=2, maker_half_spread=4.0),
        scenario_config("flash-crash", num_markets=6, num_agents=16,
                        num_levels=16, num_steps=10, seed=21, shock_step=4),
        dataclasses.replace(CFG, alpha_maker=0.5, alpha_momentum=0.25,
                            alpha_fundamentalist=0.25),
        dataclasses.replace(CFG, num_steps=7),  # horizon is not in the key
    ]
    for cfg in variants:
        with eng.open(cfg) as sess:
            sess.run(cfg.num_steps)
    spec = EnsembleSpec.homogeneous(CFG).with_values(
        shock_step=[-1, 2, -1, 3, -1, 4], shock_intensity=0.5,
        shock_cancel=0.25)
    with eng.open(spec) as sess:
        sess.run(spec.num_steps)
    assert eng.trace_count == 1


def test_with_values_broadcasts_and_validates():
    spec = EnsembleSpec.homogeneous(CFG)
    v = spec.with_values(noise_delta=3.0, shock_step=np.arange(6) - 1,
                         shock_intensity=0.1)
    assert np.asarray(v.params.noise_delta).shape == (6, 1)
    assert np.asarray(v.params.shock_step)[:, 0].tolist() == [-1, 0, 1, 2, 3, 4]
    with pytest.raises(KeyError, match="no_such"):
        spec.with_values(no_such=1.0)
    with pytest.raises(ValueError, match="shock_step"):
        spec.with_values(shock_step=CFG.num_steps)  # at the horizon


# ---------------------------------------------------------------------------
# Snapshot / restore round-trips the params
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "numpy-pcg64", "jax-scan",
                                     "pallas-kinetic"])
def test_params_snapshot_restore_roundtrip(backend):
    """A snapshot is self-contained: restoring into a session opened on a
    *different* same-shape spec resumes the snapshot's scenario mixture."""
    spec, _, _ = _mixed_spec()
    eng = _engine(backend)
    with eng.open(spec) as sess:
        sess.run(5)
        snap = sess.snapshot()
        want = sess.run(7).to_numpy()
    other = EnsembleSpec.homogeneous(
        dataclasses.replace(CFG, num_markets=spec.num_markets,
                            num_steps=spec.num_steps, seed=spec.seed))
    with eng.open(other) as sess:
        sess.restore(snap)
        for f, a, b in zip(MarketParams._fields, sess.params, spec.params):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        # the spec tracks the live mixture too (labels + param values)
        assert sess.spec.scenarios == spec.scenarios
        for f, a, b in zip(MarketParams._fields, sess.spec.params,
                           spec.params):
            assert (np.asarray(a) == np.asarray(b)).all(), ("spec", f)
        got = sess.run(7).to_numpy()
    for f, a, b in zip(BATCH_FIELDS, want, got):
        assert (a == b).all(), (backend, f)


def test_restore_adopts_snapshot_horizon_and_is_atomic():
    """A snapshot from a longer-horizon scenario restores into a
    shorter-horizon same-shape session (num_steps is not in the cache key):
    the session adopts the snapshot's horizon instead of failing validation,
    and a genuinely broken snapshot leaves the session untouched."""
    eng = _engine("numpy")
    crash = EnsembleSpec.homogeneous(scenario_config(
        "flash-crash", num_markets=6, num_agents=16, num_levels=16,
        num_steps=40, shock_step=20, seed=21))
    with eng.open(crash) as sess:
        sess.run(5)
        snap = sess.snapshot()
        want = sess.run(20).to_numpy()
    with eng.open(CFG) as sess:  # num_steps=10 < shock_step=20
        sess.restore(snap)
        assert sess.horizon == 40  # adopted from the snapshot
        got = sess.run(20).to_numpy()
        for f, a, b in zip(BATCH_FIELDS, want, got):
            assert (a == b).all(), f
    with eng.open(CFG) as sess:
        sess.run(3)
        before = [np.asarray(x).copy() for x in sess.state]
        bad = dict(snap)
        bad["params"] = {f: np.asarray(v) for f, v in snap["params"].items()}
        bad["params"]["shock_step"] = np.full((6, 1), 99, np.int32)  # >= 40
        with pytest.raises(ValueError, match="shock_step"):
            sess.restore(bad)
        assert sess.step_count == 3  # failed restore mutated nothing
        for f, a, b in zip(STATE_FIELDS, before, sess.state):
            assert (a == np.asarray(b)).all(), f


def test_restore_rejects_seed_or_agent_count_mismatch():
    """seed and num_agents are baked into the executable (they are in the
    cache key) but appear in no restored array's shape, so a cross-spec
    restore must be a loud error, never a silent stream change."""
    eng = _engine("numpy")
    with eng.open(CFG) as sess:
        sess.run(3)
        snap = sess.snapshot()
    for field in ("seed", "num_agents"):
        other = dataclasses.replace(CFG, **{field: getattr(CFG, field) * 2
                                            + 1})
        with eng.open(other) as sess:
            with pytest.raises(ValueError, match=field):
                sess.restore(snap)
            assert sess.step_count == 0  # untouched


def test_params_checkpoint_manager_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    spec, _, _ = _mixed_spec()
    eng = _engine("pallas-kinetic")
    mgr = CheckpointManager(tmp_path, async_write=False)
    with eng.open(spec) as sess:
        sess.run(5)
        sess.save_checkpoint(mgr)
        want = sess.run(7).to_numpy()
    fresh_base = EnsembleSpec.homogeneous(
        dataclasses.replace(CFG, num_markets=spec.num_markets,
                            num_steps=spec.num_steps, seed=spec.seed))
    with eng.open(fresh_base) as sess:
        assert sess.restore_checkpoint(mgr) == 5
        for f, a, b in zip(MarketParams._fields, sess.params, spec.params):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        got = sess.run(7).to_numpy()
    for f, a, b in zip(BATCH_FIELDS, want, got):
        assert (a == b).all(), f


# ---------------------------------------------------------------------------
# Sharded mixed ensembles
# ---------------------------------------------------------------------------

def test_sharded_mixed_ensemble_parity_subprocess():
    """2-device shard_map over a heterogeneous ensemble == single device,
    bitwise (each shard receives its rows of every parameter column)."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core.config import scenario_config
        from repro.core.params import EnsembleSpec
        from repro.core.session import Engine
        assert len(jax.devices()) >= 2, jax.devices()
        common = dict(num_markets=4, num_agents=16, num_levels=32,
                      num_steps=20, seed=7)
        spec = EnsembleSpec.from_scenarios(
            ["baseline", "flash-crash", "high-vol"], **common)

        def run(**opts):
            eng = Engine("pallas-kinetic", chunk_size=6, **opts)
            with eng.open(spec) as s:
                batch = s.run(spec.num_steps).to_numpy()
                snap = s.snapshot()
            return batch, snap

        single, ssnap = run()
        sharded, dsnap = run(devices=2)
        for f, a, b in zip(single._fields, single, sharded):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        for f in ("bid", "ask", "last_price", "prev_mid"):
            assert (np.asarray(ssnap[f]) == np.asarray(dsnap[f])).all(), f
        for f, a in ssnap["params"].items():
            assert (a == dsnap["params"][f]).all(), f
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.strip().splitlines()[-1] == "OK"


@pytest.mark.distributed
def test_sharded_mixed_ensemble_parity_inprocess():
    """In-process variant for the CI `distributed` tier."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    common = dict(num_markets=4, num_agents=16, num_levels=32, num_steps=20,
                  seed=7)
    spec = EnsembleSpec.from_scenarios(["baseline", "flash-crash", "low-vol"],
                                       **common)

    def run(**opts):
        with Engine("pallas-kinetic", chunk_size=6, **opts).open(spec) as s:
            return s.run(spec.num_steps).to_numpy()

    single, sharded = run(), run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), f


# ---------------------------------------------------------------------------
# Builders + validation
# ---------------------------------------------------------------------------

def test_product_builder_shape_and_values():
    base = dataclasses.replace(CFG, num_markets=2)
    spec = EnsembleSpec.product(
        base, sweep={"noise_delta": [2.0, 8.0], "p_marketable": [0.1, 0.2,
                                                                 0.3]})
    assert spec.num_markets == 2 * 2 * 3
    nd = np.asarray(spec.params.noise_delta)[:, 0]
    pm = np.asarray(spec.params.p_marketable)[:, 0]
    # cartesian order: noise_delta outer, p_marketable inner, 2 markets each
    assert nd[:6].tolist() == [2.0] * 6 and nd[6:].tolist() == [8.0] * 6
    assert pm[:2].tolist() == [pytest.approx(0.1)] * 2
    assert pm[4:6].tolist() == [pytest.approx(0.3)] * 2
    with pytest.raises(ValueError, match="non-empty"):
        EnsembleSpec.product(base, sweep={})


def test_from_scenarios_accepts_names_and_configs():
    spec = EnsembleSpec.from_scenarios(
        ["baseline", scenario_config("flash-crash", num_markets=4,
                                     num_agents=16, num_levels=16,
                                     num_steps=10, seed=0)],
        num_markets=4, num_agents=16, num_levels=16, num_steps=10, seed=0)
    assert spec.num_markets == 8
    assert spec.scenarios[:4] == ("baseline",) * 4
    assert spec.scenarios[4:] == ("flash-crash",) * 4


def test_from_scenarios_rejects_static_mismatch():
    a = MarketConfig(num_markets=2, num_agents=16, num_levels=16,
                     num_steps=10, seed=0)
    for field, value in (("num_agents", 32), ("num_levels", 32),
                         ("num_steps", 20), ("seed", 1)):
        b = dataclasses.replace(a, **{field: value})
        with pytest.raises(ValueError, match=field):
            EnsembleSpec.from_scenarios([a, b])


def test_spec_validation_rejects_bad_params():
    spec = EnsembleSpec.homogeneous(CFG)
    with pytest.raises(ValueError, match="shock_intensity"):
        spec.with_values(shock_intensity=1.5)
    with pytest.raises(ValueError, match="more than num_agents"):
        spec.with_values(num_makers=CFG.num_agents, num_momentum=1)
    with pytest.raises(ValueError, match="shock_step"):
        spec.with_values(shock_step=[0, 1, 2, 3, 4, CFG.num_steps])
    with pytest.raises(ValueError, match="q_max"):
        spec.with_values(q_max=0)  # qty draw would go non-positive
    with pytest.raises(ValueError, match="fundamental"):
        # no negative-means-midpoint sentinel on the resolved operand
        spec.with_values(fundamental=-1.0)


def test_coerce_rejects_unknown_types():
    with pytest.raises(TypeError, match="MarketConfig or EnsembleSpec"):
        EnsembleSpec.coerce({"num_markets": 4})


# ---------------------------------------------------------------------------
# Horizon semantics (the validation-gap satellite)
# ---------------------------------------------------------------------------

def test_default_run_past_horizon_raises():
    eng = _engine("numpy")
    with eng.open(CFG) as sess:
        sess.run()  # to the horizon
        assert sess.step_count == sess.horizon == CFG.num_steps
        with pytest.raises(ValueError, match="horizon"):
            sess.run()
        with pytest.raises(ValueError, match="horizon"):
            next(sess.stream())
        # explicit n_steps may stream past the horizon deliberately
        assert sess.run(5).num_steps == 5
        with pytest.raises(ValueError, match="n_steps"):
            sess.run(-1)


def test_default_run_completes_remaining_horizon():
    """run() means 'to the horizon', not 'another num_steps': interleaving
    with explicit advances never overshoots scenario events."""
    eng = _engine("numpy")
    with eng.open(CFG) as sess:
        sess.run(4)
        assert sess.run().num_steps == CFG.num_steps - 4
        assert sess.step_count == CFG.num_steps
