"""Clearing math: the paper's analytical ground truth + invariants.

The exhaustive property tests need ``hypothesis`` (declared in
requirements-dev.txt, optional); without it they skip and a seeded
random-book fallback exercises the same invariant checks.
"""
import numpy as np
import pytest

from repro.core import auction

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


BUY = np.array([[10.0, 5.0, 8.0, 0.0, 2.0]], dtype=np.float32)
SELL = np.array([[0.0, 4.0, 7.0, 6.0, 3.0]], dtype=np.float32)


class TestPaperAnalyticalCase:
    """Paper §IV-C, Eq. 11-18: the L=5 configuration-independent baseline."""

    def test_cumulative_profiles(self):
        d = auction.suffix_sum(BUY, np)
        s = auction.prefix_sum(SELL, np)
        assert np.allclose(d, [[25, 15, 10, 2, 2]])    # Eq. 13
        assert np.allclose(s, [[0, 4, 11, 17, 20]])    # Eq. 14

    @pytest.mark.parametrize("scan", ["cumsum", "hillis-steele"])
    def test_clearing(self, scan):
        c = auction.clear(BUY, SELL, np, scan=scan)
        assert c["p_star"][0, 0] == 2                  # Eq. 16
        assert c["volume"][0, 0] == 10.0
        assert np.allclose(c["new_bid"], [[10, 5, 0, 0, 0]])   # Eq. 17
        assert np.allclose(c["new_ask"], [[0, 0, 1, 6, 3]])    # Eq. 18

    def test_all_backends_identical_on_case(self):
        import jax.numpy as jnp

        cn = auction.clear(BUY, SELL, np)
        cj = auction.clear(jnp.asarray(BUY), jnp.asarray(SELL), jnp)
        for k in ("p_star", "volume", "new_bid", "new_ask"):
            assert (np.asarray(cj[k]) == cn[k]).all(), k


def _check_clearing_invariants(buy, sell):
    """Conservation + feasibility + price-priority invariants."""
    c = auction.clear(buy, sell, np)
    v = c["volume"][0, 0]
    tb, ts = c["traded_buy"], c["traded_sell"]
    # traded volume balances on both sides and equals V
    assert np.isclose(tb.sum(), v)
    assert np.isclose(ts.sum(), v)
    # no over-execution, no negative residuals
    assert (tb <= buy + 1e-6).all() and (tb >= 0).all()
    assert (ts <= sell + 1e-6).all() and (ts >= 0).all()
    assert (c["new_bid"] >= 0).all() and (c["new_ask"] >= 0).all()
    # V is the max executable volume over the grid
    d = auction.suffix_sum(buy, np)
    s = auction.prefix_sum(sell, np)
    assert np.isclose(v, np.minimum(d, s).max())
    # price priority: no traded buy below p*, no traded sell above p*
    p = int(c["p_star"][0, 0])
    assert (tb[0, :p] == 0).all()
    assert (ts[0, p + 1:] == 0).all()
    # the book never crosses after clearing: best residual bid <= best ask
    nb, na = c["new_bid"][0], c["new_ask"][0]
    if v > 0 and nb.any() and na.any():
        bb = np.max(np.nonzero(nb)[0])
        ba = np.min(np.nonzero(na)[0])
        assert bb <= ba, (nb, na)


def _check_hillis_steele_matches_cumsum(buy, sell):
    a = auction.clear(buy, sell, np, scan="cumsum")
    b = auction.clear(buy, sell, np, scan="hillis-steele")
    for k in ("p_star", "volume", "new_bid", "new_ask"):
        assert (a[k] == b[k]).all()


if HAVE_HYPOTHESIS:
    def _books(draw, L):
        qty = st.integers(min_value=0, max_value=50)
        buy = draw(st.lists(qty, min_size=L, max_size=L))
        sell = draw(st.lists(qty, min_size=L, max_size=L))
        return (np.asarray([buy], dtype=np.float32),
                np.asarray([sell], dtype=np.float32))

    @st.composite
    def books(draw):
        L = draw(st.sampled_from([4, 8, 16, 32]))
        return _books(draw, L)

    @settings(max_examples=200, deadline=None)
    @given(books())
    def test_clearing_invariants(bs):
        _check_clearing_invariants(*bs)

    @settings(max_examples=100, deadline=None)
    @given(books())
    def test_hillis_steele_bitwise_matches_cumsum(bs):
        _check_hillis_steele_matches_cumsum(*bs)


# ---- clearing invariants across all seven backends' clearing entries ----
#
# Every backend funnels clearing through xp-polymorphic auction.clear():
# the numpy family calls it with np, the jax/pallas families with jnp (the
# pallas kernels transcribe the same math in-kernel; their log-depth scan
# corresponds to the "hillis-steele" variant, so those entries drive it).
SEVEN_BACKENDS = {
    "numpy": ("np", "cumsum"),
    "numpy-splitmix64": ("np", "cumsum"),
    "numpy-pcg64": ("np", "cumsum"),
    "jax-scan": ("jnp", "cumsum"),
    "jax-per-step": ("jnp", "cumsum"),
    "pallas-naive": ("jnp", "hillis-steele"),
    "pallas-kinetic": ("jnp", "hillis-steele"),
}


def _clearing_entry(backend):
    xp_name, scan = SEVEN_BACKENDS[backend]
    if xp_name == "jnp":
        import jax.numpy as jnp
        return jnp, scan
    return np, scan


def _check_backend_clearing_invariants(buy, sell, xp, scan):
    """Grid/volume/conservation invariants, exact in f32 (integer books)."""
    L = buy.shape[-1]
    c = auction.clear(xp.asarray(buy), xp.asarray(sell), xp, scan=scan)
    c = {k: np.asarray(v) for k, v in c.items()}
    p = int(c["p_star"][0, 0])
    v = float(c["volume"][0, 0])
    # clearing price lands on the grid
    assert c["p_star"].dtype == np.int32 and 0 <= p < L
    # executed volume is exactly min(cum-buy, cum-ask) at p*
    d = auction.suffix_sum(buy, np)
    s = auction.prefix_sum(sell, np)
    assert v == min(d[0, p], s[0, p])
    # volume conserved: every filled unit leaves the book, none invented
    # (integer quantities <= 50*32 sum exactly in f32)
    assert float(buy.sum() - c["new_bid"].sum()) == v
    assert float(sell.sum() - c["new_ask"].sum()) == v
    assert float(c["traded_buy"].sum()) == v == float(c["traded_sell"].sum())


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(books(), st.sampled_from(sorted(SEVEN_BACKENDS)))
    def test_clearing_invariants_all_seven_backends(bs, backend):
        _check_backend_clearing_invariants(*bs, *_clearing_entry(backend))


@pytest.mark.parametrize("backend", sorted(SEVEN_BACKENDS))
def test_clearing_invariants_all_seven_backends_fallback(backend):
    """Seeded fallback when hypothesis is absent: same invariants."""
    xp, scan = _clearing_entry(backend)
    rng = np.random.default_rng(7)
    for L in (4, 8, 16, 32):
        for _ in range(8):
            _check_backend_clearing_invariants(*_random_books(rng, L), xp, scan)


def test_session_price_path_stays_on_grid_all_seven_backends():
    """End-to-end: every backend's price path is integer grid levels in
    [0, L) and volume is never negative."""
    from repro.core.config import MarketConfig
    from repro.core.session import Engine

    cfg = MarketConfig(num_markets=4, num_agents=16, num_levels=16,
                       num_steps=12, seed=3)
    for backend in sorted(SEVEN_BACKENDS):
        with Engine(backend).open(cfg) as sess:
            b = sess.run(cfg.num_steps).to_numpy()
        prices, volumes = np.asarray(b.price), np.asarray(b.volume)
        assert (prices == np.round(prices)).all(), backend
        assert (prices >= 0).all() and (prices < cfg.num_levels).all(), backend
        assert (volumes >= 0).all(), backend


def _random_books(rng, L):
    buy = rng.integers(0, 51, size=(1, L)).astype(np.float32)
    sell = rng.integers(0, 51, size=(1, L)).astype(np.float32)
    return buy, sell


def test_clearing_invariants_fallback():
    """Non-hypothesis fallback: seeded random integer books, same checks."""
    rng = np.random.default_rng(1234)
    for L in (4, 8, 16, 32):
        for _ in range(25):
            _check_clearing_invariants(*_random_books(rng, L))


def test_hillis_steele_matches_cumsum_fallback():
    rng = np.random.default_rng(99)
    for L in (4, 8, 16, 32):
        for _ in range(15):
            _check_hillis_steele_matches_cumsum(*_random_books(rng, L))


def test_no_cross_no_trade():
    buy = np.array([[5.0, 0.0, 0.0, 0.0]], dtype=np.float32)
    sell = np.array([[0.0, 0.0, 0.0, 5.0]], dtype=np.float32)
    c = auction.clear(buy, sell, np)
    assert c["volume"][0, 0] == 0.0
    assert (c["new_bid"] == buy).all() and (c["new_ask"] == sell).all()


def test_best_quotes_fallback():
    bid = np.zeros((1, 8), np.float32)
    ask = np.zeros((1, 8), np.float32)
    last = np.full((1, 1), 3.5, np.float32)
    bb, ba, mid = auction.best_quotes(bid, ask, last, np)
    assert bb[0, 0] == -1 and ba[0, 0] == 8
    assert mid[0, 0] == 3.5  # Eq. 3 fallback to last price
