"""Slow stylized-facts smoke: the engine still produces emergent dynamics.

Revives benchmarks/emergent_dynamics.py as a nightly guardrail — the
measurement is :func:`benchmarks.emergent_dynamics.stylized_facts`, the
same function the Fig-7 benchmark reports, on the pinned high-vol
momentum-heavy configuration. The thresholds are qualitative (the paper's
stylized facts), with wide margins against seed noise: measured kurtosis
is ~3.9 and volume/volatility correlation ~0.06-0.09 across seeds.
"""
import numpy as np
import pytest

from benchmarks.emergent_dynamics import high_vol_smoke_config, stylized_facts

pytestmark = pytest.mark.slow


def test_high_vol_preset_exhibits_stylized_facts():
    facts = stylized_facts(high_vol_smoke_config())
    # fat tails: raw kurtosis above the Gaussian value of 3
    assert facts["kurtosis"] > 3.0, facts
    assert facts["excess_kurtosis"] == pytest.approx(facts["kurtosis"] - 3.0)
    # volume stimulation: |returns| positively correlated with volume
    assert facts["volume_volatility_corr"] > 0.0, facts
    # sanity on the rest of the battery
    assert facts["volatility"] > 0 and facts["volume_per_step"] > 0
    assert np.isfinite(facts["acf_abs_lag1"])


def test_stylized_facts_deterministic_across_backends():
    """The battery is a pure function of the trajectory: the numpy
    reference backend reproduces the jax-scan numbers on the same config
    (short run; this is a determinism check, not a threshold check)."""
    cfg = high_vol_smoke_config(num_steps=60)
    a = stylized_facts(cfg, backend="jax-scan")
    b = stylized_facts(cfg, backend="numpy")
    for k in a:
        assert a[k] == pytest.approx(b[k], rel=1e-5), k
