"""Order binning: one-hot MXU contraction vs scatter reference (bitwise),
plus tile-selection edge cases (legacy ``pick_tile`` divisors and the
padded ``auto_tile`` policy that replaced them for the session entries).

The one-hot contraction is the TPU-native replacement for the paper's
shared-memory atomicAdd histogram; because quantities are exact small
integers in f32, the two binnings must agree *exactly* (==, not allclose) —
the foundation of the cross-engine bitwise-identity claim.
"""
import numpy as np
import pytest

from repro.core.step import bin_orders_onehot
from repro.kernels.autotune import (auto_tile, candidate_tiles,
                                    default_agent_chunk, pad_to_multiple)
from repro.kernels.kinetic_clearing import pick_tile


def _bin_orders_scatter_ref(side_buy, price, qty, M, L):
    """Scalar-loop scatter reference (the paper's atomicAdd semantics)."""
    buy = np.zeros((M, L), dtype=np.float32)
    sell = np.zeros((M, L), dtype=np.float32)
    for m in range(M):
        for a in range(price.shape[1]):
            tgt = buy if side_buy[m, a] else sell
            tgt[m, price[m, a]] += qty[m, a]
    return buy, sell


def _random_orders(rng, M, A, L, q_max=8):
    side_buy = rng.random((M, A)) < 0.5
    price = rng.integers(0, L, size=(M, A)).astype(np.int32)
    qty = (1.0 + rng.integers(0, q_max, size=(M, A))).astype(np.float32)
    return side_buy, price, qty


@pytest.mark.parametrize("M,A,L", [
    (1, 1, 4),
    (4, 16, 16),
    (8, 64, 32),
    (3, 200, 128),   # A >> L: heavy per-level accumulation
    (16, 7, 64),     # A < L: sparse histogram
])
def test_onehot_matches_scatter_exactly(M, A, L):
    rng = np.random.default_rng(M * 100 + A)
    side_buy, price, qty = _random_orders(rng, M, A, L)
    want_buy, want_sell = _bin_orders_scatter_ref(side_buy, price, qty, M, L)
    got_buy, got_sell = bin_orders_onehot(side_buy, price, qty, L, np)
    # exact-integer f32 equality, not allclose
    assert got_buy.dtype == np.float32 and got_sell.dtype == np.float32
    assert (got_buy == want_buy).all()
    assert (got_sell == want_sell).all()


def test_onehot_matches_scatter_jax():
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    side_buy, price, qty = _random_orders(rng, 6, 48, 32)
    want_buy, want_sell = _bin_orders_scatter_ref(side_buy, price, qty, 6, 32)
    got_buy, got_sell = bin_orders_onehot(
        jnp.asarray(side_buy), jnp.asarray(price), jnp.asarray(qty), 32, jnp)
    assert (np.asarray(got_buy) == want_buy).all()
    assert (np.asarray(got_sell) == want_sell).all()


def test_onehot_mass_conservation():
    rng = np.random.default_rng(11)
    side_buy, price, qty = _random_orders(rng, 4, 32, 16)
    buy, sell = bin_orders_onehot(side_buy, price, qty, 16, np)
    assert buy.sum() + sell.sum() == qty.sum()
    assert (buy.sum(axis=1) + sell.sum(axis=1) == qty.sum(axis=1)).all()


class TestPickTile:
    def test_divisor_and_bound(self):
        for m in range(1, 300):
            mb = pick_tile(m)
            assert 1 <= mb <= min(8, m)
            assert m % mb == 0

    def test_prime_m_degenerates_to_one(self):
        # A prime M > target has no divisor <= target except 1.
        for m in (11, 13, 8191):
            assert pick_tile(m) == 1

    def test_m_smaller_than_target(self):
        # M <= target: the whole ensemble is one tile.
        for m in (1, 2, 3, 5, 7, 8):
            assert pick_tile(m) == m
        assert pick_tile(3, target=8) == 3

    def test_custom_target(self):
        assert pick_tile(64, target=16) == 16
        assert pick_tile(24, target=16) == 12
        assert pick_tile(17, target=16) == 1


class TestAutoTile:
    """The padded tile policy: prime/odd M must never degrade to MB=1."""

    def test_prime_matches_even_tile_shape(self):
        # The seed's pick_tile pathology: M=63 ran MB=1. The padded policy
        # must give M=63 the exact tile shape (and grid) of M=64.
        assert auto_tile(63) == auto_tile(64)
        assert auto_tile(63).mb == 8
        assert auto_tile(63).m_padded == 64
        assert auto_tile(63).grid == 8

    def test_never_degrades(self):
        for m in (1, 3, 7, 11, 13, 63, 97, 8191):
            choice = auto_tile(m)
            assert choice.mb == 8, m
            assert choice.m_padded % choice.mb == 0, m
            assert choice.m_padded >= m, m
            assert choice.m_padded - m < choice.mb, m

    def test_agent_chunk_heuristic(self):
        assert default_agent_chunk(64) is None
        assert default_agent_chunk(128) is None
        assert default_agent_chunk(256) == 128
        assert auto_tile(16, num_agents=256).agent_chunk == 128

    def test_pad_to_multiple(self):
        assert pad_to_multiple(63, 8) == 64
        assert pad_to_multiple(64, 8) == 64
        assert pad_to_multiple(1, 8) == 8

    def test_candidates_cover_sublane_tiles(self):
        cands = candidate_tiles(63, 256)
        assert len(cands) == len(set(cands))
        assert all(c.mb % 8 == 0 for c in cands)
        assert all(c.m_padded % c.mb == 0 for c in cands)
        assert {c.mb for c in cands} == {8, 16}

    def test_candidates_honor_pinned_agent_chunk(self):
        # An explicit agent_chunk (a caller's VMEM bound) is never swept.
        assert all(c.agent_chunk == 32
                   for c in candidate_tiles(63, 256, agent_chunk=32))
        assert all(c.agent_chunk is None
                   for c in candidate_tiles(63, 256, agent_chunk=None))

    def test_sweep_winner_repadded_per_ensemble_size(self):
        from repro.kernels import autotune as tune

        tune.clear_tune_cache()
        try:
            key = tune.tune_key(32, 16, 4, kernel="k")
            fb = auto_tile(63, 16)
            first = tune.autotune_tile(key, lambda c: 1.0,
                                       candidate_tiles(63, 16),
                                       fallback=fb, num_markets=63)
            # cache hit for a different M reuses (mb, agent_chunk) but must
            # re-derive m_padded for the caller's ensemble size
            again = tune.autotune_tile(key, lambda c: 1.0, [],
                                       fallback=fb, num_markets=200)
            assert again.mb == first.mb
            assert again.m_padded == pad_to_multiple(200, first.mb)
        finally:
            tune.clear_tune_cache()

    def test_sweep_all_failed_falls_back_to_heuristic(self):
        from repro.kernels import autotune as tune

        tune.clear_tune_cache()
        try:
            def boom(choice):
                raise RuntimeError("tile rejected")

            fb = auto_tile(63, 256)  # keeps the A-derived agent_chunk
            got = tune.autotune_tile(tune.tune_key(32, 256, 4, kernel="k"),
                                     boom, candidate_tiles(63, 256),
                                     fallback=fb, num_markets=63)
            assert got == fb
        finally:
            tune.clear_tune_cache()


@pytest.mark.parametrize("agent_chunk", [1, 3, 16, 200])
def test_onehot_agent_chunking_bitwise(agent_chunk):
    """The VMEM-bounding agent chunking must be bitwise-invisible."""
    rng = np.random.default_rng(17)
    side_buy, price, qty = _random_orders(rng, 5, 48, 32)
    want = bin_orders_onehot(side_buy, price, qty, 32, np)
    got = bin_orders_onehot(side_buy, price, qty, 32, np,
                            agent_chunk=agent_chunk)
    assert (got[0] == want[0]).all()
    assert (got[1] == want[1]).all()
