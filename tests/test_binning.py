"""Order binning: one-hot MXU contraction vs scatter reference (bitwise),
plus ``pick_tile`` edge cases.

The one-hot contraction is the TPU-native replacement for the paper's
shared-memory atomicAdd histogram; because quantities are exact small
integers in f32, the two binnings must agree *exactly* (==, not allclose) —
the foundation of the cross-engine bitwise-identity claim.
"""
import numpy as np
import pytest

from repro.core.step import bin_orders_onehot
from repro.kernels.kinetic_clearing import pick_tile


def _bin_orders_scatter_ref(side_buy, price, qty, M, L):
    """Scalar-loop scatter reference (the paper's atomicAdd semantics)."""
    buy = np.zeros((M, L), dtype=np.float32)
    sell = np.zeros((M, L), dtype=np.float32)
    for m in range(M):
        for a in range(price.shape[1]):
            tgt = buy if side_buy[m, a] else sell
            tgt[m, price[m, a]] += qty[m, a]
    return buy, sell


def _random_orders(rng, M, A, L, q_max=8):
    side_buy = rng.random((M, A)) < 0.5
    price = rng.integers(0, L, size=(M, A)).astype(np.int32)
    qty = (1.0 + rng.integers(0, q_max, size=(M, A))).astype(np.float32)
    return side_buy, price, qty


@pytest.mark.parametrize("M,A,L", [
    (1, 1, 4),
    (4, 16, 16),
    (8, 64, 32),
    (3, 200, 128),   # A >> L: heavy per-level accumulation
    (16, 7, 64),     # A < L: sparse histogram
])
def test_onehot_matches_scatter_exactly(M, A, L):
    rng = np.random.default_rng(M * 100 + A)
    side_buy, price, qty = _random_orders(rng, M, A, L)
    want_buy, want_sell = _bin_orders_scatter_ref(side_buy, price, qty, M, L)
    got_buy, got_sell = bin_orders_onehot(side_buy, price, qty, L, np)
    # exact-integer f32 equality, not allclose
    assert got_buy.dtype == np.float32 and got_sell.dtype == np.float32
    assert (got_buy == want_buy).all()
    assert (got_sell == want_sell).all()


def test_onehot_matches_scatter_jax():
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    side_buy, price, qty = _random_orders(rng, 6, 48, 32)
    want_buy, want_sell = _bin_orders_scatter_ref(side_buy, price, qty, 6, 32)
    got_buy, got_sell = bin_orders_onehot(
        jnp.asarray(side_buy), jnp.asarray(price), jnp.asarray(qty), 32, jnp)
    assert (np.asarray(got_buy) == want_buy).all()
    assert (np.asarray(got_sell) == want_sell).all()


def test_onehot_mass_conservation():
    rng = np.random.default_rng(11)
    side_buy, price, qty = _random_orders(rng, 4, 32, 16)
    buy, sell = bin_orders_onehot(side_buy, price, qty, 16, np)
    assert buy.sum() + sell.sum() == qty.sum()
    assert (buy.sum(axis=1) + sell.sum(axis=1) == qty.sum(axis=1)).all()


class TestPickTile:
    def test_divisor_and_bound(self):
        for m in range(1, 300):
            mb = pick_tile(m)
            assert 1 <= mb <= min(8, m)
            assert m % mb == 0

    def test_prime_m_degenerates_to_one(self):
        # A prime M > target has no divisor <= target except 1.
        for m in (11, 13, 8191):
            assert pick_tile(m) == 1

    def test_m_smaller_than_target(self):
        # M <= target: the whole ensemble is one tile.
        for m in (1, 2, 3, 5, 7, 8):
            assert pick_tile(m) == m
        assert pick_tile(3, target=8) == 3

    def test_custom_target(self):
        assert pick_tile(64, target=16) == 16
        assert pick_tile(24, target=16) == 12
        assert pick_tile(17, target=16) == 1
