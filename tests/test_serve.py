"""Serving gateway tier-1 suite (in-process transport).

Covers the serving invariants the gateway's design rests on:

  * slot splices (``Session.swap_markets``) leave every *other* market's
    trajectory bitwise-unchanged and never retrace — the property that
    makes multi-tenant serving over one warm trace sound;
  * a parked slot costs no extra trace (detach is a value mutation);
  * the gateway sustains 32 concurrent streaming clients with
    ``traces_delta == 0`` after warmup (the acceptance bar);
  * a deliberately stalled client provably does not delay other clients'
    frame delivery (bounded per-chunk latency, contiguous sequence
    numbers, bounded publisher-side drops for the stalled queue only);
  * backpressure policies, force-delivered control events, the lag-one
    double buffer, the bounded quantile window, the health endpoint, and
    the wire codecs.

Everything here runs on host-device backends in-process; the chaos tier
(``tests/test_chaos.py -m chaos``) covers device loss under client load.
"""
import asyncio
import json
import time
import urllib.request

import numpy as np
import pytest

from repro.core.config import scenario_config
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.ops.metrics import QuantileWindow
from repro.serve import (POLICIES, DoubleBuffer, Event, Frame, FrameBus,
                         Gateway, GatewayDegraded, GatewayFull,
                         GatewayRecovering, SlotScheduler, SpliceEntry,
                         SpliceJournal, decode, parked_template)

SWAP_BACKENDS = ["numpy", "numpy-pcg64", "jax-scan", "pallas-kinetic"]

KW = dict(num_agents=16, num_levels=32, num_steps=64, seed=11)
CHUNK = 16


def _spec(markets=6, scenario="baseline", **over):
    return EnsembleSpec.coerce(
        scenario_config(scenario, num_markets=markets, **{**KW, **over}))


def _tpl(slots=6, **over):
    return parked_template(slots=slots, **{**KW, **over})


# ---------------------------------------------------------------------------
# swap_markets: the slot-splice invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", SWAP_BACKENDS)
def test_swap_leaves_other_markets_bitwise_unchanged(backend):
    """Splicing rows into a live session must not perturb any other row —
    the per-market RNG/dynamics independence multi-tenant serving needs."""
    spec = _spec()
    eng = Engine(backend, chunk_size=CHUNK)
    with eng.open(spec) as s:
        base = s.run(64).to_numpy()
    sub = _spec(1, "flash-crash", seed=KW["seed"], shock_step=40)
    with eng.open(spec) as s:
        a = s.run(16)
        s.swap_markets([4], sub)
        b = s.run(16)
        s.swap_markets([2], EnsembleSpec.parked(spec, 1))
        c = s.run(32)
        got = type(base).concatenate([x.to_numpy() for x in (a, b, c)],
                                     xp=np)
    untouched = [0, 1, 3, 5]
    for field, want, have in zip(base._fields, base, got):
        assert (np.asarray(want)[untouched]
                == np.asarray(have)[untouched]).all(), \
            f"{backend}: spliced rows leaked into other markets' {field}"
        # row 2 bitwise up to its detach, row 4 up to its attach
        assert (np.asarray(want)[2, :32] == np.asarray(have)[2, :32]).all()
        assert (np.asarray(want)[4, :16] == np.asarray(have)[4, :16]).all()


@pytest.mark.parametrize("backend", ["jax-scan", "pallas-kinetic"])
def test_swap_and_parked_slots_never_retrace(backend):
    """Attach, detach, and parked rows are value mutations: zero traces
    beyond the first compile, whatever the scenario mixture."""
    spec = _spec()
    eng = Engine(backend, chunk_size=CHUNK)
    with eng.open(spec) as s:
        s.run(CHUNK)
        warm = eng.trace_count
        for i, scen in enumerate(("flash-crash", "high-vol", "thin-book")):
            s.swap_markets([i], _spec(1, scen, seed=KW["seed"]))
            s.run(CHUNK)
        s.swap_markets([0, 1, 2], EnsembleSpec.parked(spec, 3))
        s.run(CHUNK)
        assert eng.trace_count == warm, \
            f"{backend}: slot churn retraced the executable"


def test_swap_validates_slots_and_static_fields():
    spec = _spec()
    with Engine("numpy").open(spec) as s:
        with pytest.raises(ValueError, match="slots"):
            s.swap_markets([1, 1], _spec(2))
        with pytest.raises(ValueError):
            s.swap_markets([99], _spec(1))
        with pytest.raises(ValueError, match="num_agents"):
            s.swap_markets([0], _spec(1, num_agents=8))


# ---------------------------------------------------------------------------
# SlotScheduler
# ---------------------------------------------------------------------------

def test_scheduler_admission_and_coalescing():
    tpl = _tpl(3)
    sched = SlotScheduler(tpl)
    s0 = sched.attach("baseline")
    s1 = sched.attach("flash-crash")
    s2 = sched.attach("high-vol")
    assert (s0, s1, s2) == (0, 1, 2) and sched.free == 0
    with pytest.raises(GatewayFull):
        sched.attach("baseline")
    sched.detach(s1)                      # park + free immediately...
    assert sched.attach("thin-book") == s1    # ...so the slot is reusable
    with pytest.raises(KeyError):
        sched.detach(99)
    # detach-then-attach between boundaries coalesces to ONE splice row
    with Engine("numpy", chunk_size=CHUNK).open(tpl) as sess:
        applied = sched.drain(sess)
        assert applied is not None
        slots, sub = applied
        assert slots == (0, 1, 2) and sub.num_markets == 3
        assert sub.scenarios[1] == "thin-book"   # the attach won
        assert sched.drain(sess) is None         # queue fully drained


def test_scheduler_rejects_static_mismatch_at_admission():
    sched = SlotScheduler(_tpl(2))
    with pytest.raises(ValueError, match="static field"):
        sched.attach(_spec(1, num_agents=KW["num_agents"] * 2))
    with pytest.raises(ValueError, match="one market"):
        sched.attach(_spec(2))
    assert sched.free == 2                # failed admissions reserve nothing


# ---------------------------------------------------------------------------
# FrameBus: bounded backpressure
# ---------------------------------------------------------------------------

def _frame(slot, seq):
    z = np.zeros(2, np.float32)
    return Frame(slot=slot, seq=seq, step0=seq * 2, num_steps=2,
                 mid=z, price=z, volume=z)


def test_bus_drop_oldest_never_blocks():
    async def main():
        bus = FrameBus()
        sub = bus.subscribe(0, maxsize=2, policy="drop-oldest")
        for seq in range(5):
            bus.publish([(0, _frame(0, seq))])
        assert sub.qsize() == 2 and sub.dropped == 3
        got = [await sub.get(), await sub.get()]
        assert [f.seq for f in got] == [3, 4]     # newest survive
    asyncio.run(main())


def test_bus_disconnect_policy_sheds_slow_client():
    async def main():
        bus = FrameBus()
        slow = bus.subscribe(0, maxsize=1, policy="disconnect")
        fast = bus.subscribe(0, maxsize=8, policy="drop-oldest")
        for seq in range(3):
            bus.publish([(0, _frame(0, seq))])
        assert slow.closed and not fast.closed
        assert bus.clients == (fast.client,)
        # the closed event is force-delivered despite the full queue
        items = []
        while (item := await slow.get()) is not None:
            items.append(item)
        events = [i for i in items if isinstance(i, Event)]
        assert events and events[-1].kind == "closed"
        assert events[-1].payload["reason"] == "backpressure"
    asyncio.run(main())


def test_bus_broadcast_and_policy_validation():
    async def main():
        bus = FrameBus()
        subs = [bus.subscribe(i, maxsize=1) for i in range(3)]
        bus.publish([(i, _frame(i, 0)) for i in range(3)])
        bus.broadcast(Event("reconnect", {"resume_step": 0}))
        for sub in subs:      # event forced through the full queues
            item = await sub.get()
            while not isinstance(item, Event):
                item = await sub.get()
            assert item.kind == "reconnect"
        with pytest.raises(ValueError, match="policy"):
            bus.subscribe(9, policy="warp-speed")
        assert "drop-oldest" in POLICIES and "disconnect" in POLICIES
    asyncio.run(main())


# ---------------------------------------------------------------------------
# DoubleBuffer + QuantileWindow + wire codecs
# ---------------------------------------------------------------------------

def test_double_buffer_is_lag_one():
    buf = DoubleBuffer(lambda x: x * 10)
    assert buf.push("a", 1) is None and buf.depth == 1
    assert buf.push("b", 2) == ("a", 10)
    assert buf.push("c", 3) == ("b", 20)
    assert buf.flush() == ("c", 30) and buf.depth == 0
    assert buf.flush() is None
    assert buf.conversions == 3


def test_quantile_window_is_bounded_and_exact():
    w = QuantileWindow(size=8)
    for v in range(100):
        w.add(float(v))
    assert w.count == 100
    # only the last 8 observations (92..99) are in the window
    assert w.percentile(0) == 92.0 and w.percentile(100) == 99.0
    assert w.percentile(50) == 96.0
    s = w.summary()
    assert s["window"] == 8 and s["p99"] == 99.0


def test_frame_event_json_roundtrip():
    f = _frame(3, 7)._replace(stats={"n_trades": 4.0})
    f2 = decode(f.to_json())
    assert isinstance(f2, Frame) and f2.slot == 3 and f2.seq == 7
    assert np.array_equal(f2.mid, f.mid) and f2.stats["n_trades"] == 4.0
    e = decode(Event("attached", {"slot": 3}).to_json())
    assert isinstance(e, Event) and e.payload["slot"] == 3
    with pytest.raises(ValueError, match="unknown wire"):
        decode(json.dumps({"type": "gibberish"}))


# ---------------------------------------------------------------------------
# Gateway end-to-end (in-process transport)
# ---------------------------------------------------------------------------

def test_gateway_32_clients_zero_retraces():
    """The acceptance bar: 32 concurrent streaming clients over one warm
    engine, arbitrary scenario mixture, zero traces after warmup."""
    async def main():
        gw = Gateway(_tpl(32, num_steps=4096), backend="jax-scan",
                     chunk_size=8, queue_maxsize=16)
        await gw.start(chunks=8)
        mix = ["baseline", "flash-crash", "high-vol", "thin-book"]
        clients = [gw.open_session(mix[i % len(mix)]) for i in range(32)]
        assert gw.health()["slots_free"] == 0
        with pytest.raises(GatewayFull):
            gw.open_session("baseline")
        streams = await asyncio.gather(*(c.frames(8) for c in clients))
        await gw.stop()
        assert all(len(fs) == 8 for fs in streams)
        for c, fs in zip(clients, streams):
            assert [f.seq for f in fs] == list(range(8))  # no gaps
            assert all(f.slot == c.slot for f in fs)
        assert gw.traces_delta == 0, \
            f"{gw.traces_delta} retraces serving 32 clients"
        # distinct scenarios actually produce distinct markets
        assert not np.array_equal(
            np.concatenate([f.mid for f in streams[0]]),
            np.concatenate([f.mid for f in streams[1]]))
    asyncio.run(main())


def test_stalled_client_does_not_delay_others():
    """One consumer never reads its queue; every other client's per-frame
    delivery latency stays bounded (the stalled client's frames drop —
    bounded queue — instead of stalling the step loop)."""
    async def run_once(stall: bool):
        gw = Gateway(_tpl(8, num_steps=8192), backend="jax-scan",
                     chunk_size=8, queue_maxsize=4)
        await gw.start(chunks=30)
        live = [gw.open_session("baseline") for _ in range(4)]
        stalled = gw.open_session("flash-crash") if stall else None
        lat = []

        async def consume(cs):
            for _ in range(20):
                t0 = time.perf_counter()
                f = await asyncio.wait_for(cs.next_frame(), timeout=30)
                lat.append(time.perf_counter() - t0)
                if f is None:
                    break

        await asyncio.gather(*(consume(c) for c in live))
        sub = None if stalled is None else stalled.subscription
        await gw.stop()
        lat.sort()
        return lat[int(0.99 * (len(lat) - 1))], sub

    async def main():
        p99_clean, _ = await run_once(False)
        p99_stall, sub = await run_once(True)
        # comparative bound: a frozen consumer must not blow up everyone
        # else's p99 (generous factor absorbs CI timer noise)
        assert p99_stall <= max(10 * p99_clean, 0.5), \
            f"stalled client delayed others: {p99_stall:.3f}s " \
            f"vs clean {p99_clean:.3f}s"
        # and the stalled client's bounded queue did its job
        assert sub.qsize() <= 4
        assert sub.dropped > 0, "expected drop-oldest evictions"
    asyncio.run(main())


def test_gateway_detach_reuses_slot_and_metrics_series():
    async def main():
        gw = Gateway(_tpl(4, num_steps=4096), backend="numpy",
                     chunk_size=8, queue_maxsize=32)
        await gw.start(chunks=6)
        a = gw.open_session("baseline", client="alice")
        b = gw.open_session("flash-crash", client="bob")
        await asyncio.gather(a.frames(2), b.frames(1))
        b.close()
        await b.frames(10)   # drain leftovers until the closed event
        c = gw.open_session("thin-book", client="carol")
        assert c.slot == b.slot           # freed slot reused
        await c.frames(1)
        await gw.stop()
        snap = gw.metrics.snapshot()
        assert snap["counters"]["frames_published_total"] > 0
        assert snap["counters"]["sessions_opened_total"] == 3
        assert snap["counters"]["swaps_total"] >= 2
        assert "chunk_latency_seconds" in snap["windows"]
        assert snap["windows"]["chunk_latency_seconds"]["count"] >= 5
        kinds = [e.kind for e in b.events]
        assert kinds and kinds[-1] == "closed"
    asyncio.run(main())


def test_gateway_requires_running_and_warm_start():
    async def main():
        gw = Gateway(_tpl(2), backend="numpy", chunk_size=8)
        with pytest.raises(RuntimeError, match="start"):
            gw.open_session("baseline")
        await gw.start()
        with pytest.raises(RuntimeError, match="already started"):
            await gw.start()
        with pytest.raises(RuntimeError, match="ckpt_dir"):
            gw.inject_fault(object())
        await gw.stop()
    asyncio.run(main())


# ---------------------------------------------------------------------------
# durability + supervision (PR 8)
# ---------------------------------------------------------------------------

def test_splice_journal_roundtrip_compaction_and_torn_tail(tmp_path):
    """The WAL round-trips specs bitwise, tolerates only a torn trailing
    line, raises typed corruption for anything else, and compaction drops
    exactly the entries no restore can ever need."""
    from repro.serve.journal import JournalCorruptError

    j = SpliceJournal(tmp_path)
    e0 = SpliceEntry(t=0, slots=(0, 1), labels=("baseline", "high-vol"),
                     spec=_spec(2))
    e1 = SpliceEntry(t=16, slots=(2,), labels=(None,),
                     spec=_spec(1, scenario="thin-book"))
    j.append(e0)
    j.append(e1)
    j.close()
    back = SpliceJournal(tmp_path).entries()
    assert [(e.t, e.slots, e.labels) for e in back] == \
        [(0, (0, 1), ("baseline", "high-vol")), (16, (2,), (None,))]
    for got, want in zip(back, (e0, e1)):
        assert got.spec.static_key() == want.spec.static_key()
        for f, a, b in zip(got.spec.params._fields, got.spec.params,
                           want.spec.params):
            assert np.array_equal(np.asarray(a), np.asarray(b)), f
    # torn trailing line (crash mid-append): tolerated, dropped on read
    path = tmp_path / "splices.journal"
    intact = path.read_bytes()
    path.write_bytes(intact + b'{"t": 24, "slots"')
    assert [e.t for e in SpliceJournal(tmp_path).entries()] == [0, 16]
    # damage a NON-trailing line: typed refusal, never partial replay
    lines = intact.split(b"\n")
    path.write_bytes(b"\n".join([lines[0][: len(lines[0]) // 2]]
                                + lines[1:]))
    with pytest.raises(JournalCorruptError, match="line 1"):
        SpliceJournal(tmp_path).entries()
    # compaction drops strictly-older entries, crash-atomically
    path.write_bytes(intact)
    j2 = SpliceJournal(tmp_path)
    assert j2.compact(oldest_retained_step=8) == 1
    assert [e.t for e in j2.entries()] == [16]
    assert j2.compact(oldest_retained_step=8) == 0     # idempotent
    j2.append(e0)                      # appends reopen the new inode
    assert [e.t for e in j2.entries()] == [16, 0]
    j2.close()


def test_admission_paused_while_recovering(tmp_path):
    """Typed GatewayRecovering while the supervisor owns the engine."""
    async def main():
        gw = Gateway(_tpl(2, num_steps=4096), backend="numpy", chunk_size=8,
                     ckpt_dir=tmp_path, checkpoint_every=2)
        await gw.start()
        gw._state = "recovering"       # as _recover_supervised sets mid-pass
        with pytest.raises(GatewayRecovering, match="retry"):
            gw.open_session("baseline")
        with pytest.raises(GatewayRecovering):
            gw.resume_session(0)
        assert gw.health()["ready"] is False
        gw._state = "serving"
        cs = gw.open_session("baseline")    # admission resumes
        assert await cs.frames(1)
        await gw.stop()
    asyncio.run(main())


def test_exhausted_recovery_degrades_to_read_only(tmp_path):
    """When every recovery attempt fails the gateway degrades instead of
    crashing: clients see a ``degraded`` broadcast and a typed close,
    admission raises GatewayDegraded, health reports 503-shape diagnostics
    — and stop() still shuts down cleanly."""
    from repro.ops import DeviceLoss

    async def main():
        gw = Gateway(_tpl(2, num_steps=4096), backend="numpy", chunk_size=8,
                     ckpt_dir=tmp_path, checkpoint_every=2,
                     max_recovery_attempts=2,
                     recovery_backoff=(0.001, 0.002))
        await gw.start()
        a = gw.open_session("baseline", client="a")
        assert await a.frames(2)

        def recovery_impossible(fault, target):
            raise RuntimeError("injected: recovery impossible")

        gw._recover = recovery_impossible
        gw.inject_fault(DeviceLoss(at_step=0))
        for _ in range(500):
            if gw.state == "degraded":
                break
            await asyncio.sleep(0.01)
        assert gw.state == "degraded"
        with pytest.raises(GatewayDegraded, match="degraded"):
            gw.open_session("baseline")
        with pytest.raises(GatewayDegraded):
            gw.resume_session(0)
        h = gw.health()
        assert h["ready"] is False and h["state"] == "degraded"
        assert "recovery impossible" in h["degraded_reason"]
        assert gw.metrics.counter("recovery_attempts_total") == 2
        assert gw.metrics.counter("recoveries_total") == 0
        assert gw.metrics.gauge_value("degraded") == 1
        while await a.next_frame() is not None:     # drain pre-fault frames
            pass
        kinds = [e.kind for e in a.events]
        assert "degraded" in kinds and kinds[-1] == "closed"
        closed = [e for e in a.events if e.kind == "closed"][-1]
        assert closed.payload["reason"] == "degraded"
        await gw.stop()
        assert gw.state == "degraded"   # stop() preserves the diagnosis
    asyncio.run(main())


def test_resume_session_reattaches_without_splice(tmp_path):
    """resume_session re-subscribes to a live slot with no swap: the
    restart front door (and a cheap reconnect for a dropped consumer)."""
    async def main():
        gw = Gateway(_tpl(2, num_steps=4096), backend="numpy", chunk_size=8,
                     ckpt_dir=tmp_path, checkpoint_every=2)
        await gw.start()
        with pytest.raises(KeyError, match="not attached"):
            gw.resume_session(0)
        a = gw.open_session("baseline", client="a")
        assert await a.frames(2)
        journal_before = gw.health()["journal_entries"]
        b = gw.resume_session(a.slot, client="b")
        fb = await b.frames(2)
        assert fb and all(f.slot == a.slot for f in fb)
        att = [e for e in b.events if e.kind == "attached"]
        assert att and att[0].payload["resumed"] is True
        assert gw.health()["journal_entries"] == journal_before  # no splice
        await gw.stop()
    asyncio.run(main())


def test_stop_flushes_async_checkpoint_writer(tmp_path):
    """Shutdown under load drains the async writer: the ladder on disk is
    fully committed (terminal COMMIT markers, no stray tmp files) and
    loadable by a fresh manager."""
    from repro.checkpoint import COMMIT_NAME, CheckpointManager

    async def main():
        gw = Gateway(_tpl(3, num_steps=4096), backend="numpy", chunk_size=8,
                     ckpt_dir=tmp_path, checkpoint_every=1)
        await gw.start()
        for i, s in enumerate(("baseline", "high-vol")):
            gw.open_session(s, client=f"c{i}")
        assert await gw._sessions["c0"].frames(4)
        await gw.stop()                # clients still attached + streaming
        h = gw.health()
        assert h["checkpoint"]["pending"] == 0
        assert h["checkpoint"]["writes"] >= 1
        mgr = CheckpointManager(tmp_path, async_write=False)
        steps = mgr.steps()
        assert steps and mgr.latest_step() == steps[-1]
        assert (mgr.dir / f"step_{steps[-1]:08d}" / COMMIT_NAME).exists()
        assert not list(mgr.dir.glob("*.tmp"))
        assert mgr.restore(steps[-1]) is not None
    asyncio.run(main())


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

def test_health_endpoint_over_http():
    from repro.serve.transport import HealthServer

    async def main():
        gw = Gateway(_tpl(2, num_steps=4096), backend="numpy",
                     chunk_size=8)
        server = HealthServer(gw)
        port = await server.start()

        def get(path):
            try:
                with urllib.request.urlopen(
                        f"http://127.0.0.1:{port}{path}", timeout=5) as r:
                    return r.status, json.loads(r.read())
            except urllib.error.HTTPError as e:
                return e.code, json.loads(e.read())

        loop = asyncio.get_running_loop()
        status, body = await loop.run_in_executor(None, get, "/healthz")
        assert status == 503 and body["ready"] is False   # not started yet
        await gw.start()
        status, body = await loop.run_in_executor(None, get, "/healthz")
        assert status == 200 and body["ready"] is True
        assert body["traces_delta"] == 0 and body["slots"] == 2
        status, _ = await loop.run_in_executor(None, get, "/nope")
        assert status == 404
        await server.stop()
        await gw.stop()
    asyncio.run(main())


def test_websocket_transport_gated_on_optional_dep():
    from repro.serve import transport

    gw = Gateway(_tpl(2), backend="numpy")
    if transport._websockets is None:
        with pytest.raises(RuntimeError, match="websockets"):
            transport.WebSocketServer(gw)
    else:   # pragma: no cover - env-dependent
        assert transport.WebSocketServer(gw) is not None
