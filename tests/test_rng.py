"""Stateless RNG: cross-backend bitwise identity + statistical quality.

The hypothesis property test is optional (requirements-dev.txt); without it
a fixed-coordinate determinism sweep runs instead.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import rng

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


def test_numpy_jax_bitwise_identical():
    gid = np.arange(4096, dtype=np.uint32).reshape(64, 64)
    for step in (0, 1, 499):
        for ch in range(4):
            a = rng.kinetic_hash32(7, gid, step, ch, np)
            b = np.asarray(rng.kinetic_hash32(7, jnp.asarray(gid), step, ch, jnp))
            assert (a == b).all()


def test_uniform_range_and_mean():
    gid = np.arange(1 << 16, dtype=np.uint32)
    u = rng.uniform32(3, gid, 5, 1, np)
    assert u.dtype == np.float32
    assert (u >= 0).all() and (u < 1).all()
    assert abs(float(u.mean()) - 0.5) < 5e-3
    assert abs(float(u.var()) - 1 / 12) < 5e-3


def test_channel_and_step_decorrelation():
    gid = np.arange(1 << 14, dtype=np.uint32)
    u0 = rng.uniform32(3, gid, 5, 0, np)
    u1 = rng.uniform32(3, gid, 5, 1, np)
    u2 = rng.uniform32(3, gid, 6, 0, np)
    for a, b in ((u0, u1), (u0, u2)):
        corr = np.corrcoef(a, b)[0, 1]
        assert abs(corr) < 0.02


def _check_determinism(seed, gid, step, ch):
    with np.errstate(over="ignore"):  # modular uint32 arithmetic by design
        a = rng.kinetic_hash32(seed, np.uint32(gid), step, ch, np)
        b = rng.kinetic_hash32(seed, np.uint32(gid), step, ch, np)
    assert a == b


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**20),
           st.integers(0, 10000), st.integers(0, 7))
    def test_determinism(seed, gid, step, ch):
        _check_determinism(seed, gid, step, ch)


def test_determinism_fallback():
    """Non-hypothesis fallback: seeded random coordinate sweep."""
    r = np.random.default_rng(7)
    for _ in range(50):
        _check_determinism(int(r.integers(0, 2**32)), int(r.integers(0, 2**20)),
                           int(r.integers(0, 10000)), int(r.integers(0, 8)))


def test_splitmix64_reference_vector():
    # Published known-answer: seed 0, first output of SplitMix64 is
    # mix(0 + GOLDEN) = 0xE220A8397B1DCDAF.
    out = rng.splitmix64(np.uint64(0x9E3779B97F4A7C15))
    assert out == np.uint64(0xE220A8397B1DCDAF), hex(int(out))


def test_splitmix64_uniform_stats():
    gid = np.arange(1 << 15, dtype=np.uint64)
    u = rng.splitmix64_uniform(9, gid, 3, 1)
    assert (u >= 0).all() and (u < 1).all()
    assert abs(float(u.mean()) - 0.5) < 1e-2
