"""Tests for repro.train — on-device PPO over the market env.

Tier-1 keeps sizes tiny (the smoke configs train in seconds on CPU);
the full market-maker learning run is `train`+`slow`-marked and rides
the nightly job. The invariants mirror the engine's discipline:

* the whole update loop — rollout + GAE + minibatched gradient steps —
  compiles to ONE executable, and repeat calls never retrace;
* trainer state (policy, Adam moments, PRNG key, env states) round-trips
  through CheckpointManager bitwise, so a resume continues the learning
  curve exactly;
* batched experience (vmap over runtime seeds × scenario mixtures) and
  the sharded collection path compose with the same parity guarantees.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.config import MarketConfig
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.env import InventoryPenalty, MarketFeatures, SpreadCapture, Sum
from repro.train import (PPOConfig, PPOTrainer, fit, make_market_maker,
                         restore_train_checkpoint, save_train_checkpoint)

CFG = MarketConfig(num_markets=4, num_agents=16, num_levels=16, num_steps=12,
                   seed=3)

#: tiny-but-real config: 2 vmapped seed-envs over the market axis.
SMOKE = PPOConfig(rollout_len=8, num_updates=2, num_envs=2, num_epochs=2,
                  num_minibatches=4, hidden=(16,), seed=0)

REWARD = Sum((SpreadCapture(), InventoryPenalty(0.001)))


def _mixture(seed=3):
    return EnsembleSpec.from_scenarios(
        ["flash-crash", "high-vol"], num_markets=2, num_agents=16,
        num_levels=16, num_steps=12, seed=seed)


def _trainer(backend="jax-scan", cfg=SMOKE, spec=None, **engine_opts):
    eng = Engine(backend, **engine_opts)
    env = eng.env(spec if spec is not None else _mixture(),
                  reward=REWARD, obs=MarketFeatures())
    return eng, PPOTrainer(env, cfg)


# ---------------------------------------------------------------------------
# One executable; zero warm retraces across updates and train() calls.
# ---------------------------------------------------------------------------

def test_train_is_one_executable_zero_retraces():
    eng, tr = _trainer()
    ts = tr.init()
    ts, metrics = tr.train(ts, 2)
    warm = eng.trace_count
    ts, metrics = tr.train(ts, 2)
    ts, metrics = tr.train(ts, 2)
    assert eng.trace_count == warm, (eng.trace_count, warm)
    for k in ("reward", "loss", "pg_loss", "v_loss", "entropy",
              "approx_kl", "value"):
        v = np.asarray(metrics[k])
        assert v.shape == (2,) and np.isfinite(v).all(), k
    assert int(np.asarray(ts.update_idx)) == 6


def test_train_updates_move_params():
    import jax

    _, tr = _trainer()
    ts0 = tr.init()
    ts1, _ = tr.train(ts0, 2)
    moved = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree_util.tree_leaves(ts0.params),
                             jax.tree_util.tree_leaves(ts1.params))]
    assert all(moved), moved


def test_train_trace_shared_across_mixtures_of_same_shape():
    """A trainer on a different scenario mixture of the same shape reuses
    the warm train executable (shape-semantic engine-wide cache)."""
    eng = Engine("jax-scan")
    env_a = eng.env(_mixture(), reward=REWARD, obs=MarketFeatures())
    tr_a = PPOTrainer(env_a, SMOKE)
    ts, _ = tr_a.train(tr_a.init(), 2)
    warm = eng.trace_count
    env_b = eng.env(EnsembleSpec.from_scenarios(
        ["flash-crash", "flash-crash"], num_markets=2, num_agents=16,
        num_levels=16, num_steps=12, seed=3), reward=REWARD,
        obs=MarketFeatures())
    tr_b = PPOTrainer(env_b, SMOKE)
    tr_b.train(tr_b.init(), 2)
    assert eng.trace_count == warm, (eng.trace_count, warm)


def test_train_smoke_on_pallas_backend():
    """The train graph compiles and runs over the Pallas kernel path
    (markets are the batch there: the kernel bakes the RNG seed)."""
    cfg = dataclasses.replace(SMOKE, num_envs=1, rollout_len=4,
                              num_minibatches=2, num_epochs=1)
    eng, tr = _trainer("pallas-kinetic", cfg)
    ts, metrics = tr.train(tr.init(), 2)
    warm = eng.trace_count
    tr.train(ts, 2)
    assert eng.trace_count == warm
    assert np.isfinite(np.asarray(metrics["loss"])).all()


def test_engine_trainer_sugar():
    eng = Engine("jax-scan")
    tr = eng.trainer(_mixture(), SMOKE, reward=REWARD, obs=MarketFeatures())
    ts, metrics = tr.train(tr.init(), 2)
    assert np.asarray(metrics["reward"]).shape == (2,)


# ---------------------------------------------------------------------------
# Config validation.
# ---------------------------------------------------------------------------

def test_num_envs_rejected_on_baked_seed_backend():
    eng = Engine("pallas-kinetic")
    env = eng.env(_mixture(), reward=REWARD, obs=MarketFeatures())
    with pytest.raises(ValueError, match="seed"):
        PPOTrainer(env, SMOKE)  # SMOKE has num_envs=2


def test_host_backend_rejected():
    eng = Engine("numpy")
    env = eng.env(CFG, reward=REWARD, obs=MarketFeatures())
    with pytest.raises(ValueError, match="traceable"):
        PPOTrainer(env, SMOKE)


def test_minibatch_divisibility_checked():
    eng = Engine("jax-scan")
    env = eng.env(_mixture(), reward=REWARD, obs=MarketFeatures())
    with pytest.raises(ValueError, match="num_minibatches"):
        PPOTrainer(env, dataclasses.replace(SMOKE, num_minibatches=7))


# ---------------------------------------------------------------------------
# Checkpoint: bitwise continuation of the learning curve.
# ---------------------------------------------------------------------------

def test_checkpoint_resume_bitwise_continues_curve(tmp_path):
    import jax

    _, tr = _trainer()
    # straight-through: 4 updates in two warm spans
    ts_a, _ = tr.train(tr.init(), 2)
    ts_a, m_a = tr.train(ts_a, 2)
    # interrupted: 2 updates, save, restore, 2 more
    ts_b, _ = tr.train(tr.init(), 2)
    mgr = CheckpointManager(tmp_path, async_write=False)
    step = save_train_checkpoint(mgr, tr, ts_b)
    assert step == 2
    ts_r = restore_train_checkpoint(mgr, tr)
    ts_b, m_b = tr.train(ts_r, 2)
    for pa, pb in zip(jax.tree_util.tree_leaves(ts_a.params),
                      jax.tree_util.tree_leaves(ts_b.params)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb))
    for oa, ob in zip(jax.tree_util.tree_leaves(ts_a.opt_state),
                      jax.tree_util.tree_leaves(ts_b.opt_state)):
        assert np.array_equal(np.asarray(oa), np.asarray(ob))
    for k in m_a:
        assert np.array_equal(np.asarray(m_a[k]), np.asarray(m_b[k])), k
    assert int(np.asarray(ts_b.update_idx)) == 4


def test_fit_spans_threshold_and_checkpoints(tmp_path):
    _, tr = _trainer()
    mgr = CheckpointManager(tmp_path, async_write=False)
    out = fit(tr, total_updates=4, updates_per_call=2,
              ckpt_manager=mgr, ckpt_every=2)
    assert out["updates"] == 4
    assert out["history"]["reward"].shape == (4,)
    assert out["env_steps"] == 4 * SMOKE.rollout_len * SMOKE.num_envs * 4
    assert out["env_steps_per_s"] > 0
    assert mgr.restore() is not None
    # a threshold below any reachable reward stops after the first span
    out2 = fit(tr, total_updates=4, updates_per_call=2,
               reward_threshold=-1e9)
    assert out2["updates"] == 2 and out2["time_to_threshold"] is not None
    with pytest.raises(ValueError, match="divide"):
        fit(tr, total_updates=5, updates_per_call=2)


# ---------------------------------------------------------------------------
# Evaluation: learned greedy policy vs the scripted maker archetype.
# ---------------------------------------------------------------------------

def test_evaluate_greedy_and_scripted_baseline():
    from repro.env import rollout

    eng, tr = _trainer()
    ts = tr.init()
    batch = tr.evaluate(ts.params, n_steps=8)
    assert np.asarray(batch.reward).shape == (8, 4)
    assert np.isfinite(np.asarray(batch.reward)).all()
    # held-out mixture of the same shape (the spec seed stays — it is part
    # of the shape-semantic static_key): no retrace for eval either
    held_out = eng.env(EnsembleSpec.from_scenarios(
        ["baseline", "thin-book"], num_markets=2, num_agents=16,
        num_levels=16, num_steps=12, seed=3), reward=REWARD,
        obs=MarketFeatures())
    warm = eng.trace_count
    tr.evaluate(ts.params, env=held_out, n_steps=8)
    assert eng.trace_count == warm
    mm = make_market_maker(16)
    _, b = rollout(held_out, mm, 8)
    assert np.isfinite(np.asarray(b.reward)).all()


# ---------------------------------------------------------------------------
# Sharded collection parity (in-process; the distributed CI job).
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_sharded_train_collection_parity_in_process():
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices in-process")
    cfg = dataclasses.replace(SMOKE, num_envs=1, rollout_len=4,
                              num_minibatches=2, num_epochs=1)
    _, tr1 = _trainer("pallas-kinetic", cfg)
    _, tr2 = _trainer("pallas-kinetic", cfg, devices=2)
    ts1, ts2 = tr1.init(seed=0), tr2.init(seed=0)
    # identical init params (same PRNG), replicated on the mesh for tr2
    for a, b in zip(jax.tree_util.tree_leaves(ts1.params),
                    jax.tree_util.tree_leaves(ts2.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # greedy collection through the carried rollout path: sharded ==
    # single-device, bitwise (the tentpole's parity discipline)
    b1 = tr1.evaluate(ts1.params, n_steps=6)
    b2 = tr2.evaluate(ts2.params, n_steps=6)
    assert (np.asarray(b1.obs) == np.asarray(b2.obs)).all()
    assert (np.asarray(b1.reward) == np.asarray(b2.reward)).all()
    # and a jitted update span runs on the sharded path
    _, m1 = tr1.train(ts1, 2)
    _, m2 = tr2.train(ts2, 2)
    np.testing.assert_allclose(np.asarray(m1["reward"]),
                               np.asarray(m2["reward"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# Nightly: the learned market-maker actually learns.
# ---------------------------------------------------------------------------

@pytest.mark.train
@pytest.mark.slow
def test_market_maker_training_improves_reward():
    """Training reward trends up over the flash-crash + high-vol mixture
    (full-scale beat-the-scripted-maker evaluation rides the nightly
    train_bench)."""
    cfg = PPOConfig(rollout_len=32, num_updates=24, num_envs=4,
                    num_epochs=2, num_minibatches=8, hidden=(32, 32),
                    lr=1e-3, ent_coef=0.003, seed=0)
    _, tr = _trainer(cfg=cfg)
    out = fit(tr, total_updates=24, updates_per_call=8)
    rewards = out["history"]["reward"]
    head, tail = rewards[:6].mean(), rewards[-6:].mean()
    assert tail > head - 0.05, (head, tail)
    assert np.isfinite(out["history"]["loss"]).all()
