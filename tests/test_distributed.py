"""Distribution machinery: market-axis ensemble sharding, sharding rules,
HLO analyzer, mini dry-run.

Two flavours of multi-device coverage:

  * subprocess probes (`_run_probe`) force N host devices in a child
    process, so the main pytest process stays single-device — these run in
    tier-1 on any machine;
  * `@pytest.mark.distributed` cases run *in-process* and skip unless the
    process already has >= 2 devices — the CI `distributed` tier runs them
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _device_count() -> int:
    import jax

    return len(jax.devices())


def _run_probe(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Sharded market ensembles (shard_map over the persistent chunk kernels).
# ---------------------------------------------------------------------------

# Odd M across 2 devices (pads on both layouts), flash-crash shock placed so
# a chunk boundary straddles it; chunk_size=6 -> chunks [0,6), [6,12)...
# straddle shock_step=9.
_SHARD_CFG = ("dict(num_markets=10, num_agents=16, num_levels=32, "
              "num_steps=20, shock_step=9, seed=7)")

_SHARD_PARITY_CODE = textwrap.dedent(f"""
    import numpy as np, jax
    from repro.core.config import scenario_config
    from repro.core.session import Engine
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = scenario_config("flash-crash", **{_SHARD_CFG})

    def run(**opts):
        eng = Engine("pallas-kinetic", chunk_size=6, **opts)
        with eng.open(cfg) as s:
            batch = s.run(cfg.num_steps).to_numpy()
            snap = s.snapshot()
        return batch, snap

    single, ssnap = run()
    sharded, dsnap = run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), f
    for f in ("bid", "ask", "last_price", "prev_mid"):
        assert (np.asarray(ssnap[f]) == np.asarray(dsnap[f])).all(), f
    print("OK")
""")


def test_sharded_ensemble_bitwise_parity_subprocess():
    """2-device shard_map run == single-device run, bitwise, including a
    shock-straddling chunk boundary (tier-1: runs in a forced-2-device
    subprocess on any machine)."""
    out = _run_probe(_SHARD_PARITY_CODE, devices=2)
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_snapshot_across_shard_boundary_subprocess():
    """A snapshot taken on a single-device session restores into a sharded
    session (and back) and continues the exact stream."""
    out = _run_probe(textwrap.dedent(f"""
        import numpy as np, jax
        from repro.core.config import scenario_config
        from repro.core.session import Engine
        cfg = scenario_config("flash-crash", **{_SHARD_CFG})
        eng1 = Engine("pallas-kinetic", chunk_size=6)
        eng2 = Engine("pallas-kinetic", chunk_size=6, devices=2)
        with eng1.open(cfg) as s:
            s.run(8)
            snap = s.snapshot()
            want = s.run(12).to_numpy()
        with eng2.open(cfg) as s:
            s.restore(snap)
            got = s.run(12).to_numpy()
            back = s.snapshot()
        for f, a, b in zip(want._fields, want, got):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        # ... and back across the boundary: sharded snapshot -> single device
        with eng1.open(cfg) as s:
            s.restore(back)
            assert s.step_count == 20
        print("OK")
    """), devices=2)
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_stats_only_subprocess():
    """devices=2 + stats_only compose: Θ(M) outputs, same statistics."""
    out = _run_probe(textwrap.dedent(f"""
        import numpy as np, jax
        from repro.core.config import scenario_config
        from repro.core.session import Engine
        from repro.core.stats import MarketStats
        cfg = scenario_config("flash-crash", **{_SHARD_CFG})

        def stats(**opts):
            with Engine("pallas-kinetic", stats_only=True, chunk_size=6,
                        **opts).open(cfg) as s:
                s.run(cfg.num_steps)
                return s.stats

        single, sharded = stats(), stats(devices=2)
        for f, a, b in zip(MarketStats._fields, single, sharded):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        print("OK")
    """), devices=2)
    assert out.strip().splitlines()[-1] == "OK"


@pytest.mark.distributed
@pytest.mark.parametrize("backend", ["pallas-kinetic", "pallas-naive"])
def test_sharded_ensemble_bitwise_parity_inprocess(backend):
    """In-process variant for the CI `distributed` tier (XLA_FLAGS forces
    >= 2 host devices before pytest starts); skips on 1-device runs."""
    if _device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    from repro.core.config import scenario_config
    from repro.core.session import Engine

    cfg = scenario_config("flash-crash", num_markets=10, num_agents=16,
                          num_levels=32, num_steps=20, shock_step=9, seed=7)

    def run(**opts):
        with Engine(backend, chunk_size=6, **opts).open(cfg) as s:
            return s.run(cfg.num_steps).to_numpy()

    single, sharded = run(), run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), (backend, f)


@pytest.mark.distributed
def test_sharded_session_no_warm_retrace_inprocess():
    if _device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    from repro.core.config import MarketConfig
    from repro.core.session import Engine

    cfg = MarketConfig(num_markets=10, num_agents=16, num_levels=32,
                       num_steps=18, seed=1)
    eng = Engine("pallas-kinetic", chunk_size=6, devices=2)
    with eng.open(cfg) as s:
        s.run(6)
        warm = eng.trace_count
        s.run(6)
        s.run(4)  # partial tail: n_valid gating, same trace
        assert eng.trace_count == warm


def test_markets_mesh_validation():
    from repro.launch.mesh import make_markets_mesh

    mesh = make_markets_mesh(1)
    assert mesh.axis_names == ("markets",)
    with pytest.raises(ValueError, match="devices"):
        make_markets_mesh(_device_count() + 1)


def test_hlo_analyzer_loop_accounting():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import summarize
        n = 128
        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=16)
            return out
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((n,n), jnp.float32),
                             jax.ShapeDtypeStruct((n,n), jnp.float32)).compile()
        s = summarize(c.as_text())
        print(s["flops"] / (16 * 2 * n**3))
    """))
    ratio = float(out.strip().splitlines()[-1])
    assert 0.95 < ratio < 1.10  # trip-count-aware (XLA's own reports ~1/16)


def test_sharded_matmul_collectives_detected():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import summarize
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        n = 128
        def g(x, w1, w2):
            return ((x @ w1) @ w2).sum()
        jf = jax.jit(g, in_shardings=(
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P()))
        cc = jf.lower(*[jax.ShapeDtypeStruct((n,n), jnp.float32)]*3).compile()
        s = summarize(cc.as_text())
        print(s["collective_breakdown"]["all-reduce"] > 0)
        print(abs(s["flops"] - 2*2*n**3/8) / (2*2*n**3/8) < 0.05)
    """))
    lines = out.strip().splitlines()
    assert lines[-2] == "True" and lines[-1] == "True"


def test_mini_dryrun_smoke_arch():
    """Full dry-run path (lower+compile+analysis) for a smoke config on an
    8-device (2,4) mesh — the same machinery the production dry-run uses."""
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import sharding as shd, specs as specs_mod
        from repro.launch.steps import make_train_step
        from repro.launch import hlo_analysis
        from repro.models.model import Model
        import dataclasses
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = get_config("llama4-scout-17b-a16e", smoke=True)
        model = Model(cfg)
        train_step, opt = make_train_step(cfg)
        ap = model.abstract_params()
        ao = jax.eval_shape(opt.init, ap)
        psh = shd.param_shardings(mesh, ap)
        osh = shd.param_shardings(mesh, ao)
        repl = NamedSharding(mesh, P())
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        bsh = specs_mod.batch_shardings(mesh, cfg, batch)
        def fn(p, o, s, b):
            with shd.activate(mesh):
                return train_step(p, o, s, b)
        jf = jax.jit(fn, in_shardings=(psh, osh, repl, bsh),
                     out_shardings=(psh, osh, repl, None))
        compiled = jf.lower(ap, ao, jax.ShapeDtypeStruct((), jnp.int32),
                            batch).compile()
        ma = compiled.memory_analysis()
        h = hlo_analysis.summarize(compiled.as_text())
        print(json.dumps({"flops": h["flops"], "bytes": h["hbm_bytes"],
                          "wire": h["collective_wire_bytes"],
                          "arg": ma.argument_size_in_bytes}))
    """))
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["flops"] > 0 and rec["bytes"] > 0
    assert rec["wire"] > 0  # TP/EP requires collectives
    assert rec["arg"] > 0


def test_cache_shardings_rules():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch import specs as specs_mod

    # build shardings against an abstract 2D mesh without devices: use the
    # single host device mesh shaped (1,1); rules must still produce specs
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    cfg = get_config("falcon-mamba-7b", smoke=True)
    cache = specs_mod.abstract_cache(cfg, 2, 16)
    sh = specs_mod.cache_shardings(mesh, cfg, cache)
    leaves = jax.tree_util.tree_leaves(
        sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert leaves, "no shardings built"


def test_param_sharding_rules_structure():
    import jax

    from repro.configs import get_config
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model

    mesh = make_host_mesh()
    for arch in ("kimi-k2-1t-a32b", "whisper-large-v3", "zamba2-2.7b"):
        cfg = get_config(arch, smoke=True)
        ap = Model(cfg).abstract_params()
        sh = shd.param_shardings(mesh, ap, fsdp=True)
        # structure must match exactly (tree_map would fail otherwise)
        jax.tree_util.tree_map(lambda a, b: None, ap, sh)
