"""Distribution machinery: market-axis ensemble sharding, sharding rules,
HLO analyzer.

Two flavours of multi-device coverage:

  * subprocess probes (`_run_probe`) force N host devices in a child
    process, so the main pytest process stays single-device — these run in
    tier-1 on any machine;
  * `@pytest.mark.distributed` cases run *in-process* and skip unless the
    process already has >= 2 devices — the CI `distributed` tier runs them
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _device_count() -> int:
    import jax

    return len(jax.devices())


def _run_probe(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Sharded market ensembles (shard_map over the persistent chunk kernels).
# ---------------------------------------------------------------------------

# Odd M across 2 devices (pads on both layouts), flash-crash shock placed so
# a chunk boundary straddles it; chunk_size=6 -> chunks [0,6), [6,12)...
# straddle shock_step=9.
_SHARD_CFG = ("dict(num_markets=10, num_agents=16, num_levels=32, "
              "num_steps=20, shock_step=9, seed=7)")

_SHARD_PARITY_CODE = textwrap.dedent(f"""
    import numpy as np, jax
    from repro.core.config import scenario_config
    from repro.core.session import Engine
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = scenario_config("flash-crash", **{_SHARD_CFG})

    def run(**opts):
        eng = Engine("pallas-kinetic", chunk_size=6, **opts)
        with eng.open(cfg) as s:
            batch = s.run(cfg.num_steps).to_numpy()
            snap = s.snapshot()
        return batch, snap

    single, ssnap = run()
    sharded, dsnap = run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), f
    for f in ("bid", "ask", "last_price", "prev_mid"):
        assert (np.asarray(ssnap[f]) == np.asarray(dsnap[f])).all(), f
    print("OK")
""")


def test_sharded_ensemble_bitwise_parity_subprocess():
    """2-device shard_map run == single-device run, bitwise, including a
    shock-straddling chunk boundary (tier-1: runs in a forced-2-device
    subprocess on any machine)."""
    out = _run_probe(_SHARD_PARITY_CODE, devices=2)
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_snapshot_across_shard_boundary_subprocess():
    """A snapshot taken on a single-device session restores into a sharded
    session (and back) and continues the exact stream."""
    out = _run_probe(textwrap.dedent(f"""
        import numpy as np, jax
        from repro.core.config import scenario_config
        from repro.core.session import Engine
        cfg = scenario_config("flash-crash", **{_SHARD_CFG})
        eng1 = Engine("pallas-kinetic", chunk_size=6)
        eng2 = Engine("pallas-kinetic", chunk_size=6, devices=2)
        with eng1.open(cfg) as s:
            s.run(8)
            snap = s.snapshot()
            want = s.run(12).to_numpy()
        with eng2.open(cfg) as s:
            s.restore(snap)
            got = s.run(12).to_numpy()
            back = s.snapshot()
        for f, a, b in zip(want._fields, want, got):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        # ... and back across the boundary: sharded snapshot -> single device
        with eng1.open(cfg) as s:
            s.restore(back)
            assert s.step_count == 20
        print("OK")
    """), devices=2)
    assert out.strip().splitlines()[-1] == "OK"


def test_sharded_stats_only_subprocess():
    """devices=2 + stats_only compose: Θ(M) outputs, same statistics."""
    out = _run_probe(textwrap.dedent(f"""
        import numpy as np, jax
        from repro.core.config import scenario_config
        from repro.core.session import Engine
        from repro.core.stats import MarketStats
        cfg = scenario_config("flash-crash", **{_SHARD_CFG})

        def stats(**opts):
            with Engine("pallas-kinetic", stats_only=True, chunk_size=6,
                        **opts).open(cfg) as s:
                s.run(cfg.num_steps)
                return s.stats

        single, sharded = stats(), stats(devices=2)
        for f, a, b in zip(MarketStats._fields, single, sharded):
            assert (np.asarray(a) == np.asarray(b)).all(), f
        print("OK")
    """), devices=2)
    assert out.strip().splitlines()[-1] == "OK"


@pytest.mark.distributed
@pytest.mark.parametrize("backend", ["pallas-kinetic", "pallas-naive"])
def test_sharded_ensemble_bitwise_parity_inprocess(backend):
    """In-process variant for the CI `distributed` tier (XLA_FLAGS forces
    >= 2 host devices before pytest starts); skips on 1-device runs."""
    if _device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    from repro.core.config import scenario_config
    from repro.core.session import Engine

    cfg = scenario_config("flash-crash", num_markets=10, num_agents=16,
                          num_levels=32, num_steps=20, shock_step=9, seed=7)

    def run(**opts):
        with Engine(backend, chunk_size=6, **opts).open(cfg) as s:
            return s.run(cfg.num_steps).to_numpy()

    single, sharded = run(), run(devices=2)
    for f, a, b in zip(single._fields, single, sharded):
        assert (np.asarray(a) == np.asarray(b)).all(), (backend, f)


@pytest.mark.distributed
def test_sharded_session_no_warm_retrace_inprocess():
    if _device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    from repro.core.config import MarketConfig
    from repro.core.session import Engine

    cfg = MarketConfig(num_markets=10, num_agents=16, num_levels=32,
                       num_steps=18, seed=1)
    eng = Engine("pallas-kinetic", chunk_size=6, devices=2)
    with eng.open(cfg) as s:
        s.run(6)
        warm = eng.trace_count
        s.run(6)
        s.run(4)  # partial tail: n_valid gating, same trace
        assert eng.trace_count == warm


def test_markets_mesh_validation():
    from repro.launch.mesh import make_markets_mesh

    mesh = make_markets_mesh(1)
    assert mesh.axis_names == ("markets",)
    with pytest.raises(ValueError, match="devices"):
        make_markets_mesh(_device_count() + 1)


def test_hlo_analyzer_loop_accounting():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import summarize
        n = 128
        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=16)
            return out
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((n,n), jnp.float32),
                             jax.ShapeDtypeStruct((n,n), jnp.float32)).compile()
        s = summarize(c.as_text())
        print(s["flops"] / (16 * 2 * n**3))
    """))
    ratio = float(out.strip().splitlines()[-1])
    assert 0.95 < ratio < 1.10  # trip-count-aware (XLA's own reports ~1/16)


def test_sharded_matmul_collectives_detected():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import summarize
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        n = 128
        def g(x, w1, w2):
            return ((x @ w1) @ w2).sum()
        jf = jax.jit(g, in_shardings=(
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P()))
        cc = jf.lower(*[jax.ShapeDtypeStruct((n,n), jnp.float32)]*3).compile()
        s = summarize(cc.as_text())
        print(s["collective_breakdown"]["all-reduce"] > 0)
        print(abs(s["flops"] - 2*2*n**3/8) / (2*2*n**3/8) < 0.05)
    """))
    lines = out.strip().splitlines()
    assert lines[-2] == "True" and lines[-1] == "True"


def test_market_sharding_requires_markets_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat
    from repro.launch.sharding import market_sharding, replicated_sharding

    mesh = make_mesh_compat((1,), ("markets",))
    assert market_sharding(mesh).spec == P("markets")
    assert replicated_sharding(mesh).spec == P()
    other = make_mesh_compat((1,), ("data",))
    with pytest.raises(ValueError, match="markets"):
        market_sharding(other)
