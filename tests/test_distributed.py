"""Distribution machinery: sharding rules, HLO analyzer, mini dry-run.

The mini dry-run runs in a subprocess with 8 forced host devices so the
main pytest process stays single-device.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_probe(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_hlo_analyzer_loop_accounting():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import summarize
        n = 128
        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=16)
            return out
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((n,n), jnp.float32),
                             jax.ShapeDtypeStruct((n,n), jnp.float32)).compile()
        s = summarize(c.as_text())
        print(s["flops"] / (16 * 2 * n**3))
    """))
    ratio = float(out.strip().splitlines()[-1])
    assert 0.95 < ratio < 1.10  # trip-count-aware (XLA's own reports ~1/16)


def test_sharded_matmul_collectives_detected():
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import summarize
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        n = 128
        def g(x, w1, w2):
            return ((x @ w1) @ w2).sum()
        jf = jax.jit(g, in_shardings=(
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P()))
        cc = jf.lower(*[jax.ShapeDtypeStruct((n,n), jnp.float32)]*3).compile()
        s = summarize(cc.as_text())
        print(s["collective_breakdown"]["all-reduce"] > 0)
        print(abs(s["flops"] - 2*2*n**3/8) / (2*2*n**3/8) < 0.05)
    """))
    lines = out.strip().splitlines()
    assert lines[-2] == "True" and lines[-1] == "True"


def test_mini_dryrun_smoke_arch():
    """Full dry-run path (lower+compile+analysis) for a smoke config on an
    8-device (2,4) mesh — the same machinery the production dry-run uses."""
    out = _run_probe(textwrap.dedent("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import sharding as shd, specs as specs_mod
        from repro.launch.steps import make_train_step
        from repro.launch import hlo_analysis
        from repro.models.model import Model
        import dataclasses
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = get_config("llama4-scout-17b-a16e", smoke=True)
        model = Model(cfg)
        train_step, opt = make_train_step(cfg)
        ap = model.abstract_params()
        ao = jax.eval_shape(opt.init, ap)
        psh = shd.param_shardings(mesh, ap)
        osh = shd.param_shardings(mesh, ao)
        repl = NamedSharding(mesh, P())
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        bsh = specs_mod.batch_shardings(mesh, cfg, batch)
        def fn(p, o, s, b):
            with shd.activate(mesh):
                return train_step(p, o, s, b)
        jf = jax.jit(fn, in_shardings=(psh, osh, repl, bsh),
                     out_shardings=(psh, osh, repl, None))
        compiled = jf.lower(ap, ao, jax.ShapeDtypeStruct((), jnp.int32),
                            batch).compile()
        ma = compiled.memory_analysis()
        h = hlo_analysis.summarize(compiled.as_text())
        print(json.dumps({"flops": h["flops"], "bytes": h["hbm_bytes"],
                          "wire": h["collective_wire_bytes"],
                          "arg": ma.argument_size_in_bytes}))
    """))
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["flops"] > 0 and rec["bytes"] > 0
    assert rec["wire"] > 0  # TP/EP requires collectives
    assert rec["arg"] > 0


def test_cache_shardings_rules():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch import specs as specs_mod

    # build shardings against an abstract 2D mesh without devices: use the
    # single host device mesh shaped (1,1); rules must still produce specs
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    cfg = get_config("falcon-mamba-7b", smoke=True)
    cache = specs_mod.abstract_cache(cfg, 2, 16)
    sh = specs_mod.cache_shardings(mesh, cfg, cache)
    leaves = jax.tree_util.tree_leaves(
        sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert leaves, "no shardings built"


def test_param_sharding_rules_structure():
    import jax

    from repro.configs import get_config
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model

    mesh = make_host_mesh()
    for arch in ("kimi-k2-1t-a32b", "whisper-large-v3", "zamba2-2.7b"):
        cfg = get_config(arch, smoke=True)
        ap = Model(cfg).abstract_params()
        sh = shd.param_shardings(mesh, ap, fsdp=True)
        # structure must match exactly (tree_map would fail otherwise)
        jax.tree_util.tree_map(lambda a, b: None, ap, sh)
