"""Operations subsystem (repro.ops): metrics, warm-start, OOM degradation.

Tier-1 acceptance for the ops hardening:
  * metrics collection causes **zero additional traces** and results stay
    bitwise-identical to a metrics-off session (the zero-hot-path
    guarantee), on every compiled backend;
  * ``Engine.warm(specs)`` precompiles the full ``(static_key, chunk)``
    trace set so the first open/run/step after warm never retraces, and
    ``readiness()`` reports warm/cold keys truthfully;
  * an OOM-shaped autotune sweep (every tile candidate fails) degrades to
    the conservative heuristic tile — bitwise-identical results, never a
    crash.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.config import MarketConfig
from repro.core.session import DEFAULT_CHUNK, Engine
from repro.kernels import autotune as tune
from repro.ops import force_autotune_oom
from repro.ops.metrics import MetricsRegistry

CFG = MarketConfig(num_markets=4, num_agents=16, num_levels=16, num_steps=12,
                   seed=3)

COMPILED_BACKENDS = ["jax-scan", "jax-per-step", "pallas-naive",
                     "pallas-kinetic"]
ALL_BACKENDS = ["numpy", "numpy-splitmix64", "numpy-pcg64"] + COMPILED_BACKENDS


def _batches_equal(a, b):
    a, b = a.to_numpy(), b.to_numpy()
    return all((np.asarray(x) == np.asarray(y)).all() for x, y in zip(a, b))


# ---- metrics: zero traces, bitwise parity ----

@pytest.mark.parametrize("backend", ["numpy-pcg64", "jax-scan",
                                     "pallas-kinetic"])
def test_metrics_zero_traces_and_bitwise(backend):
    """The headline guarantee: a metrics-on session produces bitwise the
    same stream as a metrics-off session and causes traces_delta == 0."""
    eng = Engine(backend)
    off = eng.open(CFG, metrics=False)
    batch_off = off.run(12)
    traces_before = eng.trace_count

    on = eng.open(CFG)  # metrics on by default
    assert isinstance(on.metrics, MetricsRegistry)
    batch_on = on.run(12)
    assert eng.trace_count - traces_before == 0, "metrics caused a retrace"
    assert _batches_equal(batch_off, batch_on)
    snap = on.metrics.snapshot()
    assert snap["counters"]["steps_total"] == 12
    assert snap["counters"]["chunks_total"] == 1
    assert snap["counters"].get("traces", 0) == 0  # warm engine
    assert snap["timings"]["chunk_seconds"]["count"] == 1
    assert on.metrics.steps_per_s() > 0


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_metrics_recorded_series(backend):
    """Every session records the documented counters/timings/gauges."""
    eng = Engine(backend)
    with eng.open(CFG) as sess:
        sess.run(8)
        sess.step()
        snap_dict = sess.snapshot()
        sess.restore(snap_dict)
        m = sess.metrics.snapshot()
    assert m["counters"]["steps_total"] == 9
    assert m["counters"]["snapshots_total"] == 1
    assert m["counters"]["restores_total"] == 1
    assert m["gauges"]["num_markets"] == CFG.num_markets
    for series in ("chunk_seconds", "step_seconds", "snapshot_seconds",
                   "restore_seconds"):
        assert m["timings"][series]["count"] >= 1, series
    if backend.startswith("pallas"):
        assert m["gauges"]["autotune_vmem_bytes"] > 0
        assert m["gauges"]["tile_mb"] >= 1


def test_metrics_disabled_engine_wide_and_per_open():
    eng = Engine("numpy", metrics=False)
    assert eng.open(CFG).metrics is None
    assert eng.open(CFG, metrics=True).metrics is not None
    eng2 = Engine("numpy")
    assert eng2.open(CFG, metrics=False).metrics is None
    assert eng2.open(CFG).metrics is not None


def test_metrics_registry_aggregates():
    m = MetricsRegistry()
    m.inc("c")
    m.inc("c", 4)
    for v in (0.5, 1.5, 1.0):
        m.observe("t", v)
    m.gauge("g", 7)
    snap = m.snapshot()
    assert m.counter("c") == 5 and m.counter("missing") == 0
    agg = snap["timings"]["t"]
    assert agg["count"] == 3 and agg["min"] == 0.5 and agg["max"] == 1.5
    assert agg["total"] == pytest.approx(3.0)
    assert agg["mean"] == pytest.approx(1.0)
    assert snap["gauges"]["g"] == 7
    assert m.steps_per_s() == 0.0  # no chunk timings recorded


# ---- warm-start controller ----

@pytest.mark.parametrize("backend", COMPILED_BACKENDS)
def test_warm_precompiles_whole_trace_set(backend):
    """After warm(), the first open/run/step triggers zero new traces."""
    eng = Engine(backend)
    ready = eng.warm(CFG)
    assert ready.ready
    traces = eng.trace_count
    assert traces >= 2  # chunk executable + the single-step executable
    with eng.open(CFG) as sess:
        sess.run(12)
        sess.run(5)
        sess.step()
    assert eng.trace_count == traces, "first request retraced after warm"


def test_warm_numpy_is_a_ready_noop():
    eng = Engine("numpy")
    ready = eng.warm(CFG)
    assert ready.ready and eng.trace_count == 0
    for entry in ready.entries:
        assert entry.warm and entry.traces == 0


def test_readiness_cold_to_warm_transition():
    eng = Engine("pallas-kinetic")
    assert eng.readiness().ready  # vacuously: no cached executables yet
    runner = eng._runner(CFG, 12)  # build without compiling
    probe = eng.readiness()
    assert not probe.ready
    assert probe.cold_keys() and not probe.warm_keys()
    eng.warm(CFG, include_step=False)
    probe = eng.readiness()
    assert probe.ready and not probe.cold_keys()
    entry = probe.entries[0]
    assert entry.chunk == 12 and entry.static_key[-1] == CFG.seed
    assert runner.trace_count == 1


def test_warm_multiple_specs_and_chunk_sizes():
    eng = Engine("jax-scan")
    other = dataclasses.replace(CFG, num_steps=24, seed=4)
    ready = eng.warm([CFG, other], chunk_sizes=[6], include_step=False)
    assert ready.ready
    # default chunk per spec (12 and 24) plus the explicit 6 for each spec
    chunks = sorted(e.chunk for e in ready.entries)
    assert chunks == [6, 6, 12, 24]
    traces = eng.trace_count
    eng.warm([CFG, other], chunk_sizes=[6], include_step=False)  # idempotent
    assert eng.trace_count == traces


def test_warm_default_chunk_matches_open():
    big = dataclasses.replace(CFG, num_steps=10 * DEFAULT_CHUNK)
    eng = Engine("jax-scan")
    eng.warm(big, include_step=False)
    traces = eng.trace_count
    with eng.open(big) as sess:
        sess.run(DEFAULT_CHUNK)
    assert eng.trace_count == traces


# ---- OOM-shaped autotune failure degrades to the conservative tile ----

def test_autotune_oom_degrades_to_heuristic_tile():
    """Every tile candidate failing OOM-shaped must fall back to the
    heuristic tile with bitwise-identical results — never crash."""
    with Engine("pallas-kinetic").open(CFG) as sess:
        want = sess.run(12)
    tune.clear_tune_cache()
    try:
        with force_autotune_oom():
            eng = Engine("pallas-kinetic", autotune=True)
            with eng.open(CFG) as sess:
                got = sess.run(12)
                runner = sess._runner
        report = tune.last_sweep_report()
        assert report is not None and report.fell_back
        assert len(report.failures) == len(report.tried) >= 1
        assert all("RESOURCE_EXHAUSTED" in f for f in report.failures)
        heuristic = tune.auto_tile(CFG.num_markets, CFG.num_agents)
        assert runner.tile == heuristic
        assert _batches_equal(want, got)
    finally:
        tune.clear_tune_cache()


def test_is_oom_error_markers():
    assert tune.is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: ..."))
    assert tune.is_oom_error(MemoryError("out of memory"))
    assert tune.is_oom_error(ValueError("exceeded VMEM limit"))
    assert not tune.is_oom_error(ValueError("shape mismatch"))


def test_estimate_vmem_bytes_scales_with_tile():
    small = tune.TileChoice(mb=8, m_padded=8, agent_chunk=64)
    big = tune.TileChoice(mb=16, m_padded=16, agent_chunk=None)
    a = tune.estimate_vmem_bytes(small, num_levels=32, num_agents=256)
    b = tune.estimate_vmem_bytes(big, num_levels=32, num_agents=256)
    assert 0 < a < b
    # dominated by the [MB, Ac, L] one-hot intermediate
    assert a >= 4 * 8 * 64 * 32
