"""``stats_only`` mode: fused in-stream statistics vs full-path references.

The mode's claim is twofold: (a) the per-market running moments / extremes /
total volume computed *inside* the step loop match a NumPy reference derived
from the full recorded path to float32 tolerance on every backend that
supports the mode, and (b) for the persistent kernel the per-step paths
never reach HBM at all — the chunk executable's outputs are Θ(M), with no
chunk-width array anywhere.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.config import MarketConfig, scenario_config
from repro.core.session import Engine, StepBatch
from repro.core.stats import MarketStats, accumulate, init_stats

CFG = MarketConfig(num_markets=6, num_agents=16, num_levels=32,
                   num_steps=24, seed=13)

#: Every backend registered today supports the mode (host loops accumulate
#: through the same shared helper; the persistent kernel fuses it).
STATS_BACKENDS = ("numpy", "numpy-pcg64", "jax-scan", "jax-per-step",
                  "pallas-kinetic", "pallas-naive")


def _path_reference(backend: str, cfg: MarketConfig) -> StepBatch:
    """Full-path run of the *same* backend (same RNG stream) on host."""
    with Engine(backend).open(cfg) as sess:
        return sess.run(cfg.num_steps).to_numpy()


@pytest.mark.parametrize("backend", STATS_BACKENDS)
def test_stats_match_full_path_reference(backend):
    ref = _path_reference(backend, CFG)
    with Engine(backend, stats_only=True).open(CFG) as sess:
        batch = sess.run(CFG.num_steps)
        assert batch.num_steps == 0  # no paths in stats mode
        st = sess.stats
    mid = np.asarray(ref.mid, dtype=np.float64)
    assert (st.count[:, 0] == CFG.num_steps).all()
    np.testing.assert_allclose(st.mean_mid()[:, 0], mid.mean(axis=1),
                               rtol=1e-5)
    np.testing.assert_allclose(st.var_mid()[:, 0], mid.var(axis=1),
                               rtol=1e-3, atol=1e-3)
    # extremes and exact-integer volume sums are bitwise-representable
    assert (st.min_mid[:, 0] == ref.mid.min(axis=1)).all()
    assert (st.max_mid[:, 0] == ref.mid.max(axis=1)).all()
    np.testing.assert_allclose(st.sum_volume[:, 0],
                               np.asarray(ref.volume).sum(axis=1), rtol=1e-6)


@pytest.mark.parametrize("backend", ["numpy", "jax-scan", "pallas-kinetic"])
def test_stats_chunking_is_bitwise_invisible(backend):
    """Accumulators are carried through chunk calls, never merged after the
    fact — so any chunking equals the one-shot run *bitwise*."""
    def stats_with_chunk(chunk):
        with Engine(backend, stats_only=True,
                    chunk_size=chunk).open(CFG) as sess:
            sess.run(CFG.num_steps)
            return sess.stats

    want = stats_with_chunk(CFG.num_steps)
    for chunk in (1, 5, 7):
        got = stats_with_chunk(chunk)
        for field, a, b in zip(MarketStats._fields, got, want):
            assert (np.asarray(a) == np.asarray(b)).all(), (chunk, field)


def test_stats_scenario_shock(backend="pallas-kinetic"):
    cfg = scenario_config("flash-crash", num_markets=6, num_agents=16,
                          num_levels=32, num_steps=20, shock_step=9, seed=3)
    ref = _path_reference(backend, cfg)
    with Engine(backend, stats_only=True, chunk_size=6).open(cfg) as sess:
        sess.run(cfg.num_steps)  # chunk boundary straddles the shock step
        st = sess.stats
    np.testing.assert_allclose(
        st.mean_mid()[:, 0], np.asarray(ref.mid, np.float64).mean(axis=1),
        rtol=1e-5)
    assert (st.min_mid[:, 0] == ref.mid.min(axis=1)).all()


def test_stats_snapshot_restore_roundtrip(backend="pallas-kinetic"):
    eng = Engine(backend, stats_only=True, chunk_size=5)
    with eng.open(CFG) as sess:
        sess.run(12)
        snap = sess.snapshot()
        assert "stats" in snap
        sess.run(12)
        want = sess.stats
    with eng.open(CFG) as sess:
        sess.restore(snap)
        sess.run(12)
        got = sess.stats
    for field, a, b in zip(MarketStats._fields, got, want):
        assert (np.asarray(a) == np.asarray(b)).all(), field


def test_stats_checkpoint_manager_roundtrip(tmp_path, backend="numpy"):
    from repro.checkpoint.manager import CheckpointManager

    eng = Engine(backend, stats_only=True, chunk_size=5)
    mgr = CheckpointManager(tmp_path / "ckpt")
    with eng.open(CFG) as sess:
        sess.run(9)
        sess.save_checkpoint(mgr)
        sess.run(6)
        want = sess.stats
    with eng.open(CFG) as sess:
        sess.restore_checkpoint(mgr)
        assert sess.step_count == 9
        sess.run(6)
        got = sess.stats
    for field, a, b in zip(MarketStats._fields, got, want):
        assert (np.asarray(a) == np.asarray(b)).all(), field


def test_kinetic_stats_kernel_emits_no_chunk_width_outputs():
    """The Θ(M) HBM claim: the stats_only chunk executable's outputs are the
    books plus six [M, 1] accumulators — nothing with a chunk-width axis."""
    import jax
    import jax.numpy as jnp

    from repro.core.params import EnsembleSpec

    chunk = 16
    spec = EnsembleSpec.coerce(CFG)
    eng = Engine("pallas-kinetic", stats_only=True)
    runner = eng._runner(spec, chunk)
    state = runner.init_state(spec)
    params = runner.params_to_device(spec.params)
    stats = runner.init_stats(spec)
    step0 = jnp.zeros((1, 1), jnp.int32)
    nv = jnp.full((1, 1), chunk, jnp.int32)
    ext = jnp.zeros((CFG.num_markets, CFG.num_levels), jnp.float32)
    out = jax.eval_shape(runner._chunk_fn, state, stats, params, step0, nv,
                         ext, ext)
    shapes = [leaf.shape for leaf in jax.tree_util.tree_leaves(out)]
    assert shapes, "no outputs?"
    assert all(chunk not in shape for shape in shapes), shapes
    assert all(shape[-1] in (1, CFG.num_levels) for shape in shapes), shapes


def test_accumulate_inactive_is_bitwise_noop():
    st = init_stats(4, np)
    st = accumulate(st, np.full((4, 1), 3.5, np.float32),
                    np.ones((4, 1), np.float32), True, np)
    frozen = accumulate(st, np.full((4, 1), 9.9, np.float32),
                        np.ones((4, 1), np.float32), False, np)
    for field, a, b in zip(MarketStats._fields, frozen, st):
        assert (np.asarray(a) == np.asarray(b)).all(), field


def test_stats_only_rejected_by_oneshot_wrappers():
    """The one-shot simulate() wrappers have no stats channel — silent
    zero-width results must be a loud error instead."""
    from repro.core import engine

    with pytest.raises(ValueError, match="Session.stats"):
        engine.simulate(CFG, backend="numpy", stats_only=True)


def test_stats_only_rejected_without_accumulators():
    from repro.kernels.kinetic_clearing import kinetic_clearing_chunk
    import jax.numpy as jnp

    z = jnp.zeros((8, 32), jnp.float32)
    s = jnp.zeros((8, 1), jnp.float32)
    i = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="stats_only"):
        kinetic_clearing_chunk(z, z, s, s, i, i, z, z, cfg=CFG, chunk=4,
                               stats_only=True, interpret=True)
