"""repro.env: pure-functional RL environment acceptance sweep.

The tentpole claims, each asserted bitwise:
  * a zero-action env rollout equals ``Session.run`` — paths and final
    books — on every registered backend;
  * a fixed *nonzero* action sequence produces identical books on all
    counter-RNG backends (the ext_buy/ext_ask injection parity the matrix
    never covered), and env.step ≡ Session.step per backend;
  * one ``lax.scan`` rollout equals a python loop of ``env.step`` calls;
  * auto-reset at the horizon restores the ensemble's opening books
    in-graph; ``vmap`` over runtime seeds equals solo baked-seed envs;
  * a mixed-scenario ensemble rollout compiles exactly once
    (``Engine.trace_count == 1``) and a second mixture of the same shape
    reuses the warm trace;
  * ``EnvState`` snapshot/restore round-trips through ``CheckpointManager``
    (including the stateful PCG64 reference stream);
  * malformed actions raise eager ``ValueError``s from both front doors.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.config import MarketConfig, scenario_config, scenario_names
from repro.core.params import EnsembleSpec
from repro.core.session import Engine, ExternalOrders
from repro.env import (
    BookWindow,
    Composite,
    InventoryPenalty,
    MarketFeatures,
    PnLReward,
    PortfolioFeatures,
    SpreadCapture,
    StatsFeatures,
    Sum,
    rollout,
)

from repro.train.policies import make_market_maker

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = MarketConfig(num_markets=4, num_agents=16, num_levels=16, num_steps=12,
                   seed=3)

ALL_BACKENDS = ["numpy", "numpy-splitmix64", "numpy-pcg64", "jax-scan",
                "jax-per-step", "pallas-naive", "pallas-kinetic"]
#: Backends sharing the production counter-RNG stream (bitwise-comparable
#: to each other); the splitmix64/pcg64 references run different streams.
BITWISE_BACKENDS = ["numpy", "jax-scan", "jax-per-step", "pallas-naive",
                    "pallas-kinetic"]
TRACEABLE = ["jax-scan", "pallas-kinetic"]

_ENGINES = {}


def _engine(backend: str) -> Engine:
    if backend not in _ENGINES:
        _ENGINES[backend] = Engine(backend)
    return _ENGINES[backend]


def _states_equal(a, b, ctx=""):
    for f, x, y in zip(type(a)._fields, a, b):
        assert (np.asarray(x) == np.asarray(y)).all(), f"{ctx}: {f} differs"


def _fixed_actions(t: int) -> ExternalOrders:
    """A deterministic, step-varying nonzero action sequence."""
    M = CFG.num_markets
    return ExternalOrders(side_buy=np.arange(M) % 2 == 0,
                          price=np.full(M, 5 + (t % 4)),
                          qty=np.full(M, 2.0 + (t % 2)))


# ---------------------------------------------------------------------------
# Zero-action parity: env rollout == Session.run, bitwise, on every backend.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_zero_action_rollout_matches_session(backend):
    eng = _engine(backend)
    env = eng.env(CFG, auto_reset=False)
    final, traj = rollout(env, None, CFG.num_steps)
    sess = eng.open(CFG)
    ref = sess.run(CFG.num_steps).to_numpy()
    assert (np.asarray(traj.price) == ref.price).all(), backend
    assert (np.asarray(traj.volume) == ref.volume).all(), backend
    assert (np.asarray(traj.mid) == ref.mid).all(), backend
    _states_equal(final.market, sess.state, backend)
    # zero actions never fill: the portfolio stays identically flat
    for leaf in final.portfolio:
        assert (np.asarray(leaf) == 0.0).all(), backend


# ---------------------------------------------------------------------------
# Nonzero-action injection parity (satellite: the ext_buy/ext_ask path).
# ---------------------------------------------------------------------------

def _run_action_sequence(backend, n=6):
    eng = _engine(backend)
    sess = eng.open(CFG)
    batches = [sess.step(_fixed_actions(t)).to_numpy() for t in range(n)]
    books = tuple(np.asarray(x) for x in sess.state)
    return batches, books


def test_action_injection_bitwise_across_backends():
    """A fixed nonzero action sequence produces identical books and step
    outputs on every counter-RNG backend (today's parity matrix only
    covers the actions=None path)."""
    ref_batches, ref_books = _run_action_sequence(BITWISE_BACKENDS[0])
    for backend in BITWISE_BACKENDS[1:]:
        batches, books = _run_action_sequence(backend)
        for t, (a, b) in enumerate(zip(ref_batches, batches)):
            for f, x, y in zip(a._fields, a, b):
                assert (np.asarray(x) == np.asarray(y)).all(), \
                    f"{backend} step {t}: {f}"
        for f, x, y in zip(("bid", "ask", "last", "pmid"), ref_books, books):
            assert (x == y).all(), f"{backend}: {f}"


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_env_step_matches_session_step_with_actions(backend):
    """env.step(actions) ≡ Session.step(actions), per backend, including
    the reference backends running their own RNG streams."""
    eng = _engine(backend)
    env = eng.env(CFG, auto_reset=False)
    state, obs = env.reset()
    sess = eng.open(CFG)
    for t in range(6):
        state, obs, reward, done, info = env.step(state, _fixed_actions(t))
        batch = sess.step(_fixed_actions(t)).to_numpy()
        assert (np.asarray(info.price) == batch.price).all(), (backend, t)
        assert (np.asarray(info.volume) == batch.volume).all(), (backend, t)
        assert (np.asarray(info.mid) == batch.mid).all(), (backend, t)
    _states_equal(state.market, sess.state, backend)


# ---------------------------------------------------------------------------
# Scan rollout == python loop of steps (in-graph ≡ eager), bitwise.
# ---------------------------------------------------------------------------

# The deterministic market-maker fixture now lives in
# repro.train.policies (shared with examples/ and the trainer's eval
# baseline); built once so the rollout executable cache keys stay stable.
_mm_policy = make_market_maker(CFG.num_levels)


@pytest.mark.parametrize("backend", TRACEABLE)
def test_scan_rollout_equals_step_loop(backend):
    eng = _engine(backend)
    env = eng.env(CFG)  # auto_reset on: the loop crosses the horizon reset
    final, traj = rollout(env, _mm_policy, CFG.num_steps)
    state, obs = env.reset()
    for t in range(CFG.num_steps):
        state, obs, reward, done, info = env.step(state,
                                                  _mm_policy(obs, state.t))
        assert (np.asarray(reward) == np.asarray(traj.reward[t])).all(), t
        assert (np.asarray(obs) == np.asarray(traj.obs[t])).all(), t
        assert (np.asarray(info.price)
                == np.asarray(traj.price[:, t:t + 1])).all(), t
        assert bool(done) == bool(traj.done[t]), t
    _states_equal(final.market, state.market, backend)
    _states_equal(final.portfolio, state.portfolio, backend)


# ---------------------------------------------------------------------------
# Carried policies: rollout(policy_carry=...) on jitted AND host paths.
# ---------------------------------------------------------------------------

def _carried_policy(obs_like_xp):
    """Stateful quoting policy in the carried signature
    ``policy_fn(carry, obs, t) -> (carry, actions, extras)``: the carry
    threads an own step counter and a reference mid that skews the quote
    offset — state the policy could not recover from (obs, t) alone."""

    def policy(carry, obs, t):
        xp = np if isinstance(obs, np.ndarray) else obs_like_xp
        count, ref_mid = carry
        mid = obs[:, 0]
        side_buy = (count % 2) == 0
        off = xp.where(mid >= ref_mid, 1.0, 2.0)
        price = xp.clip(
            xp.round(mid + xp.where(side_buy, -off, off)).astype(xp.int32),
            0, CFG.num_levels - 1)
        orders = ExternalOrders(side_buy=xp.broadcast_to(side_buy, mid.shape),
                                price=price, qty=xp.ones_like(mid))
        extras = {"mid": mid, "count": count}
        return (count + 1, ref_mid), orders, extras

    return policy


def test_policy_carry_host_loop_matches_jitted():
    """The numpy host loop honours the same policy-carry signature as the
    jitted scan — rewards, stacked extras, and the final carry bitwise."""
    import jax.numpy as jnp

    policy = _carried_policy(jnp)
    carry0 = (np.int32(0), np.float32(CFG.num_levels / 2))
    results = {}
    for backend in ("numpy", "jax-scan"):
        env = _engine(backend).env(CFG)
        final, batch, carry = rollout(env, policy, CFG.num_steps,
                                      policy_carry=carry0)
        results[backend] = (batch, carry)
    ref_b, ref_c = results["numpy"]
    b, c = results["jax-scan"]
    assert (np.asarray(ref_b.reward) == np.asarray(b.reward)).all()
    assert (np.asarray(ref_b.obs) == np.asarray(b.obs)).all()
    for k in ("mid", "count"):
        assert (np.asarray(ref_b.extras[k])
                == np.asarray(b.extras[k])).all(), k
    assert int(np.asarray(ref_c[0])) == int(np.asarray(c[0]))
    assert np.asarray(ref_b.extras["count"]).shape == (CFG.num_steps,)
    assert np.asarray(ref_b.extras["mid"]).shape \
        == (CFG.num_steps, CFG.num_markets)


def test_policy_carry_requires_policy():
    env = _engine("jax-scan").env(CFG)
    with pytest.raises(ValueError, match="policy_carry"):
        rollout(env, None, 4, policy_carry=0)


def test_stateless_rollout_has_no_extras():
    env = _engine("jax-scan").env(CFG)
    _, batch = rollout(env, _mm_policy, 4)
    assert batch.extras is None


# ---------------------------------------------------------------------------
# Auto-reset at the horizon (in-graph, from the carried opening books).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jax-scan"])
def test_auto_reset_at_horizon(backend):
    env = _engine(backend).env(CFG)
    state, obs = env.reset()
    ref0, _ = env.reset()
    for t in range(CFG.num_steps):
        state, obs, reward, done, info = env.step(state)
        assert bool(done) == (t == CFG.num_steps - 1), t
    assert int(np.asarray(state.t)) == 0
    _states_equal(state.market, ref0.market, "auto-reset books")
    for leaf in state.portfolio:
        assert (np.asarray(leaf) == 0.0).all()
    assert (np.asarray(obs) == np.asarray(env.observe(ref0))).all()
    # the second episode replays the first bitwise (deterministic replay)
    state, obs, reward, done, info = env.step(state)
    s1, o1, r1, d1, i1 = env.step(ref0)
    assert (np.asarray(info.price) == np.asarray(i1.price)).all()


def test_no_auto_reset_keeps_counting():
    env = _engine("jax-scan").env(CFG, auto_reset=False)
    state, obs = env.reset()
    for t in range(CFG.num_steps + 2):
        state, obs, reward, done, info = env.step(state)
    assert int(np.asarray(state.t)) == CFG.num_steps + 2
    assert bool(done)


def test_custom_horizon():
    env = _engine("jax-scan").env(CFG, horizon=5)
    state, obs = env.reset()
    for t in range(5):
        state, obs, reward, done, info = env.step(state)
    assert bool(done) and int(np.asarray(state.t)) == 0


# ---------------------------------------------------------------------------
# vmap over runtime seeds (counter-RNG jax backends).
# ---------------------------------------------------------------------------

def test_vmap_over_seeds_matches_solo_envs():
    import jax

    eng = _engine("jax-scan")
    env = eng.env(CFG, auto_reset=False)
    seeds = np.array([3, 11, 42], np.uint32)
    states, obs = jax.vmap(env.reset)(seeds)
    for _ in range(4):
        states, obs, rewards, done, info = jax.vmap(
            lambda s: env.step(s))(states)
    for i, sd in enumerate(seeds):
        solo_env = eng.env(dataclasses.replace(CFG, seed=int(sd)),
                           auto_reset=False)
        st, ob = solo_env.reset()
        for _ in range(4):
            st, ob, r, d, inf = solo_env.step(st)
        assert (np.asarray(ob) == np.asarray(obs[i])).all(), int(sd)
        for f, x, y in zip(st.market._fields, st.market, states.market):
            assert (np.asarray(x) == np.asarray(y[i])).all(), (int(sd), f)


def test_runtime_seed_rejected_where_baked():
    for backend in ("pallas-kinetic", "numpy-pcg64"):
        env = _engine(backend).env(CFG)
        with pytest.raises(ValueError, match="seed"):
            env.reset(seed=7)


# ---------------------------------------------------------------------------
# One compile for any scenario mixture (the ensemble tentpole, RL edition).
# ---------------------------------------------------------------------------

def _mixture(blocks):
    return EnsembleSpec.from_scenarios(blocks, num_markets=2, num_agents=16,
                                       num_levels=16, num_steps=10, seed=0)


@pytest.mark.parametrize("backend", TRACEABLE)
def test_mixed_ensemble_rollout_single_trace(backend):
    eng = Engine(backend)  # fresh engine: exact trace accounting
    spec = _mixture(list(scenario_names()))
    env = eng.env(spec, auto_reset=False)
    final, traj = rollout(env, None, spec.num_steps)
    assert eng.trace_count == 1, f"{backend}: rollout retraced"
    assert traj.reward.shape == (spec.num_steps, spec.num_markets)
    # A different mixture of the same shape reuses every warm executable.
    env2 = eng.env(_mixture(["baseline"] * len(scenario_names())),
                   auto_reset=False)
    final2, traj2 = rollout(env2, None, spec.num_steps)
    assert eng.trace_count == 1, f"{backend}: second mixture retraced"
    # Per-market parity: mixture rows equal the homogeneous spec's rows.
    solo = eng.env(_mixture(["baseline"] * len(scenario_names())),
                   auto_reset=False)
    assert solo._cache is env2._cache


def test_mixed_rollout_rows_match_solo_scenarios():
    """Market rows of a mixed-ensemble rollout are bitwise the rows of the
    per-scenario homogeneous rollouts (row-independence through the env)."""
    eng = _engine("pallas-kinetic")
    names = sorted(scenario_names())
    spec = _mixture(names)
    final, traj = rollout(eng.env(spec, auto_reset=False), None, 10)
    for k, name in enumerate(names):
        solo_spec = _mixture([name] * len(names))
        sfinal, straj = rollout(eng.env(solo_spec, auto_reset=False),
                                None, 10)
        rows = slice(2 * k, 2 * k + 2)
        assert (np.asarray(traj.price[rows])
                == np.asarray(straj.price[rows])).all(), name
        assert (np.asarray(final.market.bid[rows])
                == np.asarray(sfinal.market.bid[rows])).all(), name


# ---------------------------------------------------------------------------
# Snapshot / restore through CheckpointManager.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy-pcg64", "jax-scan",
                                     "pallas-kinetic"])
def test_env_checkpoint_roundtrip(backend, tmp_path):
    env = _engine(backend).env(CFG, auto_reset=False,
                               obs=Composite((MarketFeatures(),
                                              StatsFeatures())))
    state, obs = env.reset()
    for t in range(4):
        state, obs, reward, done, info = env.step(state, _fixed_actions(t))
    manager = CheckpointManager(tmp_path, async_write=False)
    step = env.save_checkpoint(manager, state)
    assert step == 4
    restored = env.restore_checkpoint(manager)
    _states_equal(state.market, restored.market, backend)
    _states_equal(state.portfolio, restored.portfolio, backend)
    _states_equal(state.stats, restored.stats, backend)
    # both continuations advance identically (incl. the PCG64 stream)
    sa, sb = state, restored
    for t in range(4):
        sa, oa, ra, da, ia = env.step(sa, _fixed_actions(t))
        sb, ob, rb, db, ib = env.step(sb, _fixed_actions(t))
        assert (np.asarray(oa) == np.asarray(ob)).all(), (backend, t)
        assert (np.asarray(ra) == np.asarray(rb)).all(), (backend, t)


def test_env_restore_rejects_static_mismatch(tmp_path):
    env = _engine("jax-scan").env(CFG, auto_reset=False)
    state, _ = env.reset()
    snap = env.snapshot(state)
    other = _engine("jax-scan").env(dataclasses.replace(CFG, seed=9),
                                    auto_reset=False)
    with pytest.raises(ValueError, match="static_seed"):
        other.restore(snap)


# ---------------------------------------------------------------------------
# Eager action validation (both front doors).
# ---------------------------------------------------------------------------

_BAD_ACTIONS = [
    (ExternalOrders(True, CFG.num_levels, 1.0), "grid"),
    (ExternalOrders(True, -1, 1.0), "grid"),
    (ExternalOrders(True, 5, -2.0), "negative"),
    (ExternalOrders(np.ones(3, bool), 5, 1.0), "market mismatch"),
    (ExternalOrders(True, np.full(7, 5), 1.0), "market mismatch"),
    (ExternalOrders(True, 5.5, 1.0), "fractional"),
    ({"side_buy": True, "price": 5}, "missing key"),
    (object(), "must be an ExternalOrders"),
]


@pytest.mark.parametrize("bad,match", _BAD_ACTIONS,
                         ids=[m for _, m in _BAD_ACTIONS])
def test_env_step_validates_actions_eagerly(bad, match):
    env = _engine("numpy").env(CFG)
    state, _ = env.reset()
    with pytest.raises(ValueError, match=match):
        env.step(state, bad)


@pytest.mark.parametrize("bad,match", _BAD_ACTIONS,
                         ids=[m for _, m in _BAD_ACTIONS])
def test_session_step_validates_actions_eagerly(bad, match):
    sess = _engine("numpy").open(CFG)
    with pytest.raises(ValueError, match=match):
        sess.step(bad)


def test_validation_covers_concrete_jax_arrays():
    """Concrete device arrays get the same eager value checks as host
    arrays (only tracers skip them)."""
    import jax.numpy as jnp

    env = _engine("jax-scan").env(CFG)
    state, _ = env.reset()
    M = CFG.num_markets
    with pytest.raises(ValueError, match="grid"):
        env.step(state, ExternalOrders(jnp.ones(M, bool),
                                       jnp.full(M, CFG.num_levels),
                                       jnp.ones(M)))
    with pytest.raises(ValueError, match="negative"):
        env.step(state, ExternalOrders(jnp.ones(M, bool), jnp.full(M, 5),
                                       jnp.full(M, -1.0)))
    env.step(state, ExternalOrders(jnp.ones(M, bool), jnp.full(M, 5),
                                   jnp.ones(M)))  # in-grid still accepted


def _traced_neg_qty_policy(obs, t):
    z = obs[:, 0] * 0.0  # traced zeros: value checks cannot see these
    return ExternalOrders(side_buy=z == 0.0, price=z + 5.0, qty=z - 5.0)


def _traced_frac_price_policy(obs, t):
    z = obs[:, 0] * 0.0
    return ExternalOrders(side_buy=z == 0.0, price=z + 10.6, qty=z + 1.0)


def _tick11_policy(obs, t):
    return ExternalOrders(side_buy=True, price=11, qty=1.0)


def test_traced_negative_qty_clamps_to_noop():
    """In-graph policies can emit values validation cannot inspect; a
    traced negative quantity must clamp to a zero (no-op) order instead of
    injecting negative depth into the clearing."""
    env = _engine("jax-scan").env(CFG, auto_reset=False)
    f1, t1 = rollout(env, _traced_neg_qty_policy, 6)
    f2, t2 = rollout(env, None, 6)
    assert (np.asarray(t1.price) == np.asarray(t2.price)).all()
    assert (np.asarray(t1.volume) == np.asarray(t2.volume)).all()
    for leaf in f1.portfolio:
        assert (np.asarray(leaf) == 0.0).all()


def test_traced_fractional_price_rounds_to_nearest_tick():
    """Traced float prices quote the nearest tick (10.6 -> 11), matching
    the concrete path's semantics rather than truncating toward zero."""
    env = _engine("jax-scan").env(CFG, auto_reset=False)
    f1, t1 = rollout(env, _traced_frac_price_policy, 6)
    f2, t2 = rollout(env, _tick11_policy, 6)
    assert (np.asarray(t1.price) == np.asarray(t2.price)).all()
    assert (np.asarray(t1.fill_buy) == np.asarray(t2.fill_buy)).all()


def test_valid_action_shapes_accepted():
    env = _engine("numpy").env(CFG)
    state, _ = env.reset()
    M = CFG.num_markets
    for actions in (ExternalOrders(True, 5, 1.0),
                    ExternalOrders(np.ones(M, bool), np.full(M, 5),
                                   np.full(M, 2.0)),
                    ExternalOrders(np.ones((M, 1), bool),
                                   np.full((M, 1), 5), np.full((M, 1), 0.0)),
                    (True, 5, 1.0),
                    {"side_buy": True, "price": 5, "qty": 1.0}):
        env.step(state, actions)


# ---------------------------------------------------------------------------
# Observation / reward plumbing.
# ---------------------------------------------------------------------------

def test_observation_specs_shapes_and_composition():
    obs_spec = Composite((MarketFeatures(), BookWindow(depth=3),
                          PortfolioFeatures(), StatsFeatures()))
    env = _engine("jax-scan").env(CFG, obs=obs_spec)
    assert env.obs_size() == 5 + 12 + 3 + 6
    state, obs = env.reset()
    assert obs.shape == (CFG.num_markets, env.obs_size())
    assert state.stats is not None  # StatsFeatures forces the accumulators
    state, obs, reward, done, info = env.step(state)
    assert obs.shape == (CFG.num_markets, env.obs_size())
    # the stats features move once steps accumulate
    assert (np.asarray(state.stats.count) == 1.0).all()


def test_stats_not_carried_unless_needed():
    env = _engine("jax-scan").env(CFG, obs=MarketFeatures())
    state, _ = env.reset()
    assert state.stats is None


def test_fills_and_rewards_account_consistently():
    """Crossing buys fill at p*, cash flows match, and the reward surfaces
    decompose as documented."""
    env = _engine("numpy").env(
        CFG, auto_reset=False,
        reward=Sum((PnLReward(), SpreadCapture(), InventoryPenalty(0.5)),
                   (1.0, 0.0, 0.0)))
    state, obs = env.reset()
    # marketable buy at the top of the grid: fills whenever volume clears
    for t in range(6):
        state, obs, reward, done, info = env.step(
            state, ExternalOrders(True, CFG.num_levels - 1, 3.0))
    fb = np.asarray(state.portfolio.inventory)
    assert (fb >= 0).all() and fb.sum() > 0, "marketable buys never filled"
    port = state.portfolio
    # equity ≡ cash + inventory · mid at the marking mid of the last step
    mid = np.asarray(state.last_out.mid, np.float32)
    assert (np.asarray(port.equity)
            == np.asarray(port.cash) + fb * mid).all()


def test_stats_only_engine_rejected():
    eng = Engine("jax-scan", stats_only=True)
    with pytest.raises(ValueError, match="stats_only"):
        eng.env(CFG)


# ---------------------------------------------------------------------------
# Sharded composition (shard_map ensembles under the env).
# ---------------------------------------------------------------------------

_SHARDED_ENV_CODE = textwrap.dedent("""
    import numpy as np, jax
    from repro.core.config import MarketConfig
    from repro.core.session import Engine
    from repro.env import rollout
    assert len(jax.devices()) >= 2, jax.devices()
    cfg = MarketConfig(num_markets=6, num_agents=16, num_levels=16,
                       num_steps=10, seed=5)
    f1, t1 = rollout(Engine("pallas-kinetic").env(cfg, auto_reset=False),
                     None, 10)
    f2, t2 = rollout(
        Engine("pallas-kinetic", devices=2).env(cfg, auto_reset=False),
        None, 10)
    assert (np.asarray(t1.price) == np.asarray(t2.price)).all()
    assert (np.asarray(t1.obs) == np.asarray(t2.obs)).all()
    for a, b in zip(f1.market, f2.market):
        assert (np.asarray(a) == np.asarray(b)).all()
    print("OK")
""")


def test_sharded_env_rollout_parity_subprocess():
    """2-device sharded env rollout == single-device, bitwise (forced host
    devices in a child process, runnable anywhere)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", _SHARDED_ENV_CODE], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.distributed
def test_sharded_env_rollout_parity_in_process():
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices in-process")
    cfg = MarketConfig(num_markets=6, num_agents=16, num_levels=16,
                       num_steps=10, seed=5)
    f1, t1 = rollout(Engine("pallas-kinetic").env(cfg, auto_reset=False),
                     None, 10)
    f2, t2 = rollout(
        Engine("pallas-kinetic", devices=2).env(cfg, auto_reset=False),
        None, 10)
    assert (np.asarray(t1.price) == np.asarray(t2.price)).all()
    for a, b in zip(f1.market, f2.market):
        assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# vmap(seeds) × EnsembleSpec mixture × sharded path, composed in ONE trace.
# ---------------------------------------------------------------------------

_VMAP_MIX_SHARDED_CODE = textwrap.dedent("""
    import numpy as np, jax
    from repro.core.params import EnsembleSpec
    from repro.core.session import Engine
    from repro.env import rollout
    from repro.train.policies import make_market_maker
    assert len(jax.devices()) >= 2, jax.devices()
    mk = lambda seed: EnsembleSpec.from_scenarios(
        ["flash-crash", "high-vol"], num_markets=2, num_agents=16,
        num_levels=16, num_steps=10, seed=seed)
    policy = make_market_maker(16)
    eng = Engine("jax-scan")
    env = eng.env(mk(0), auto_reset=False)

    def roll(seed):
        state, obs = env.reset(seed)
        final, batch = rollout(env, policy, 10, state=state)
        return batch

    seeds = np.array([0, 9, 23], np.uint32)
    batches = jax.vmap(roll)(seeds)
    # the whole seeds-batch of mixture rollouts compiled exactly once
    assert eng.trace_count == 1, eng.trace_count
    # per-seed bitwise vs solo envs with the seed baked into the spec
    for i, s in enumerate(seeds):
        solo = Engine("jax-scan").env(mk(int(s)), auto_reset=False)
        _, ref = rollout(solo, policy, 10)
        assert (np.asarray(ref.obs) == np.asarray(batches.obs[i])).all(), s
        assert (np.asarray(ref.price)
                == np.asarray(batches.price[i])).all(), s
    # sharded composition: the 2-device shard_map rollout of the same
    # mixture is bitwise-identical to the vmapped seed-0 row (jax-scan and
    # pallas-kinetic share the counter-RNG stream)
    sharded = Engine("pallas-kinetic", devices=2).env(mk(0),
                                                      auto_reset=False)
    _, sb = rollout(sharded, policy, 10)
    assert (np.asarray(sb.obs) == np.asarray(batches.obs[0])).all()
    assert (np.asarray(sb.price) == np.asarray(batches.price[0])).all()
    print("OK")
""")


def test_vmap_seeds_mixture_sharded_composition_subprocess():
    """vmap over runtime seeds × a scenario mixture in one trace, with the
    seed-0 row bitwise-equal to a 2-device sharded rollout of the same
    mixture (PR-3 sharding × PR-4 ensembles × PR-5 env, finally composed)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", _VMAP_MIX_SHARDED_CODE],
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
