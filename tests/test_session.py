"""Session API: chunk-boundary semantics, compile-once reuse, RL hook.

Acceptance sweep for the stateful open/step/close lifecycle:
  * any chunking of S steps is bitwise-identical to one ``run(S)`` call on
    every registered backend — including a flash-crash config whose
    ``shock_step`` straddles a chunk boundary;
  * ``snapshot()/restore()`` round-trips exactly (incl. the stateful PCG64
    generator), and survives a ``CheckpointManager`` round-trip on disk;
  * repeated runs on a warm jax/pallas session trigger no retracing
    (trace-counter assertion);
  * ``Session.step(actions=...)`` injects external orders; ``actions=None``
    is bitwise-invisible to the stream.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import engine
from repro.core.config import MarketConfig, scenario_config
from repro.core.session import DEFAULT_CHUNK, Engine, ExternalOrders, StepBatch

CFG = MarketConfig(num_markets=4, num_agents=16, num_levels=16, num_steps=12,
                   seed=3)

ALL_BACKENDS = ["numpy", "numpy-splitmix64", "numpy-pcg64", "jax-scan",
                "jax-per-step", "pallas-naive", "pallas-kinetic"]
# One representative per backend family for the slower sweeps.
FAMILY_BACKENDS = ["numpy", "numpy-pcg64", "jax-scan", "pallas-naive",
                   "pallas-kinetic"]

BATCH_FIELDS = ("price", "volume", "mid")
STATE_FIELDS = ("bid", "ask", "last_price", "prev_mid")

_ENGINES = {}


def _engine(backend: str) -> Engine:
    # Shared warm engines: compile-once reuse across the whole module.
    if backend not in _ENGINES:
        _ENGINES[backend] = Engine(backend)
    return _ENGINES[backend]


def _assert_batches_equal(a: StepBatch, b: StepBatch, ctx: str):
    a, b = a.to_numpy(), b.to_numpy()
    for f in BATCH_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype and x.shape == y.shape, (ctx, f)
        assert (x == y).all(), f"{ctx}: batch field {f} differs"


def _assert_states_equal(a, b, ctx: str):
    for f, x, y in zip(STATE_FIELDS, a, b):
        x, y = np.asarray(x), np.asarray(y)
        assert (x == y).all(), f"{ctx}: state field {f} differs"


def _run_chunked(eng: Engine, cfg: MarketConfig, chunking):
    sess = eng.open(cfg)
    parts = [sess.run(k) for k in chunking]
    batch = StepBatch(*(np.concatenate([np.asarray(g) for g in field], axis=1)
                        for field in zip(*(p.to_numpy() for p in parts))))
    return sess, batch


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_chunked_bitwise_identical(backend):
    """run(S) == any chunking of S: batches and final books, bitwise."""
    eng = _engine(backend)
    whole_sess = eng.open(CFG)
    whole = whole_sess.run(CFG.num_steps)
    for chunking in ((1,) * CFG.num_steps, (5, 4, 3), (11, 1)):
        sess, batch = _run_chunked(eng, CFG, chunking)
        ctx = f"{backend} chunking={chunking}"
        _assert_batches_equal(whole, batch, ctx)
        _assert_states_equal(whole_sess.state, sess.state, ctx)


@pytest.mark.parametrize("backend", FAMILY_BACKENDS)
def test_flash_crash_shock_straddles_chunk_boundary(backend):
    """The scenario overlay keys on the *absolute* step, so a shock placed
    right at / next to a chunk boundary is chunking-invariant."""
    cfg = scenario_config("flash-crash", num_markets=4, num_agents=16,
                          num_levels=16, num_steps=14, shock_step=7, seed=5)
    eng = _engine(backend)
    whole_sess = eng.open(cfg)
    whole = whole_sess.run(14)
    # boundary exactly at the shock, one step before, and one after
    for chunking in ((7, 7), (6, 8), (8, 6), (3, 4, 7)):
        sess, batch = _run_chunked(eng, cfg, chunking)
        ctx = f"{backend} shock chunking={chunking}"
        _assert_batches_equal(whole, batch, ctx)
        _assert_states_equal(whole_sess.state, sess.state, ctx)
    # sanity: the shock actually bit (price drops at shock_step)
    p = whole.to_numpy().price
    assert p[:, 7].mean() < p[:, 6].mean()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_session_matches_one_shot_simulate(backend):
    """The compat wrapper and a manual session produce identical results."""
    r = engine.simulate(CFG, backend=backend).to_numpy()
    sess = _engine(backend).open(CFG)
    s = sess.run_to_result(CFG.num_steps).to_numpy()
    for f in r._fields:
        assert (getattr(r, f) == getattr(s, f)).all(), (backend, f)


@pytest.mark.parametrize("backend", ["jax-scan", "jax-per-step",
                                     "pallas-naive", "pallas-kinetic"])
def test_warm_session_never_retraces(backend):
    """Repeated runs, fresh sessions, different step counts and different
    num_steps totals all reuse one compiled chunk executable."""
    eng = Engine(backend)  # fresh engine: count traces from zero
    sess = eng.open(CFG)
    sess.run(12)
    assert eng.trace_count == 1
    sess.run(12)        # warm re-run
    sess.run(5)         # partial chunk: n_valid gating, same trace
    other = eng.open(CFG)               # second session, same semantics
    other.run(12)
    # num_steps is not part of the executable key (same explicit chunk)
    longer = eng.open(dataclasses.replace(CFG, num_steps=24), chunk_size=12)
    longer.run(24)
    assert eng.trace_count == 1
    # The gym-style hook uses its own single-step executable — exactly one
    # more trace, then warm forever.
    sess.step()
    sess.step(ExternalOrders(side_buy=True, price=3, qty=2.0))
    other.step()
    assert eng.trace_count == 2


@pytest.mark.parametrize("backend", FAMILY_BACKENDS)
def test_snapshot_restore_roundtrip(backend):
    """restore(snapshot()) resumes the exact stream — books, cursor, RNG."""
    eng = _engine(backend)
    sess = eng.open(CFG)
    sess.run(5)
    snap = sess.snapshot()
    first = sess.run(7)
    final_first = [np.asarray(x) for x in sess.state]
    sess.restore(snap)
    assert sess.step_count == 5
    second = sess.run(7)
    _assert_batches_equal(first, second, f"{backend} snapshot/restore")
    _assert_states_equal(final_first, sess.state, f"{backend} snapshot/restore")


@pytest.mark.parametrize("backend", ["numpy", "numpy-pcg64", "pallas-kinetic"])
def test_checkpoint_manager_roundtrip(backend, tmp_path):
    """Session state survives a CheckpointManager disk round-trip exactly
    (incl. PCG64's 128-bit generator state via the JSON meta leaf)."""
    from repro.checkpoint.manager import CheckpointManager

    eng = _engine(backend)
    sess = eng.open(CFG)
    sess.run(5)
    mgr = CheckpointManager(tmp_path, async_write=False)
    step = sess.save_checkpoint(mgr)
    assert step == 5
    ref = sess.run(7)

    fresh = eng.open(CFG)
    assert fresh.restore_checkpoint(mgr) == 5
    got = fresh.run(7)
    _assert_batches_equal(ref, got, f"{backend} checkpoint")
    _assert_states_equal(sess.state, fresh.state, f"{backend} checkpoint")


@pytest.mark.parametrize("backend", FAMILY_BACKENDS)
def test_step_none_is_bitwise_invisible(backend):
    """run(4) + step() + run(7) == run(12): the hook shares the stream."""
    eng = _engine(backend)
    whole = eng.open(CFG).run(12)
    sess = eng.open(CFG)
    parts = [sess.run(4), sess.step(), sess.run(7)]
    mix = StepBatch(*(np.concatenate([np.asarray(g) for g in field], axis=1)
                      for field in zip(*(p.to_numpy() for p in parts))))
    _assert_batches_equal(whole, mix, f"{backend} step-interleave")


@pytest.mark.parametrize("backend", ["numpy", "jax-scan", "pallas-kinetic"])
def test_step_actions_inject_external_orders(backend):
    """A marketable external buy prints a trade a no-action twin does not."""
    quiet = dataclasses.replace(CFG, p_marketable=0.0, alpha_maker=0.0,
                                alpha_momentum=0.0)
    eng = _engine(backend)
    with eng.open(quiet) as active, eng.open(quiet) as control:
        L = quiet.num_levels
        obs = active.step(ExternalOrders(side_buy=True, price=L - 1,
                                         qty=100.0)).to_numpy()
        base = control.step().to_numpy()
        assert obs.volume.sum() > base.volume.sum()
        assert active.step_count == control.step_count == 1
        # the dict spelling is accepted too
        active.step({"side_buy": False, "price": 0, "qty": 1.0})


def test_step_batch_shapes_and_stream():
    eng = _engine("numpy")
    sess = eng.open(CFG)
    chunks = list(sess.stream(12))
    assert sum(c.num_steps for c in chunks) == 12
    assert all(c.price.shape[0] == CFG.num_markets for c in chunks)
    empty = sess.run(0)
    assert empty.num_steps == 0
    sess.close()
    with pytest.raises(RuntimeError):
        sess.run(1)


def test_default_chunk_bounds():
    big = dataclasses.replace(CFG, num_steps=10 * DEFAULT_CHUNK)
    eng = Engine("numpy")
    assert eng.open(big)._runner.chunk == DEFAULT_CHUNK
    assert eng.open(CFG)._runner.chunk == CFG.num_steps


# ---- satellite: mid-stream snapshot/restore semantics (ops PR) ----

@pytest.mark.parametrize("backend", ["numpy-pcg64", "pallas-kinetic"])
def test_mid_stream_snapshot_is_chunk_aligned(backend):
    """snapshot() between streamed chunks is chunk-boundary-aligned: it
    captures exactly the state after the last yielded chunk (the cursor
    only ever moves one whole compiled chunk at a time), bitwise equal to
    a snapshot after an explicit run() of the same steps."""
    eng = _engine(backend)
    sess = eng.open(CFG, chunk_size=4)
    it = sess.stream(12)
    next(it)
    snap = sess.snapshot()
    assert snap["t"] == 4 == sess.step_count
    ref_sess = eng.open(CFG, chunk_size=4)
    ref_sess.run(4)
    ref = ref_sess.snapshot()
    for f in STATE_FIELDS:
        assert (np.asarray(snap[f]) == np.asarray(ref[f])).all(), f
    assert snap["rng"] == ref["rng"]
    # the in-flight iterator keeps its fixed schedule after the snapshot
    assert sum(b.num_steps for b in it) == 8
    assert sess.step_count == 12


@pytest.mark.parametrize("backend", ["numpy", "pallas-kinetic"])
def test_restore_during_active_stream_raises(backend):
    """restore() under an in-flight stream() is rejected with a clear
    error (the iterator would keep the pre-restore cursor); closing or
    exhausting the iterator re-enables it."""
    eng = _engine(backend)
    sess = eng.open(CFG, chunk_size=4)
    snap0 = sess.snapshot()
    it = sess.stream(12)
    next(it)
    with pytest.raises(RuntimeError, match="active stream"):
        sess.restore(snap0)
    it.close()
    sess.restore(snap0)
    assert sess.step_count == 0
    assert sess.run(12).num_steps == 12


# ---- satellite: backend availability introspection ----

def test_backend_available():
    assert engine.backend_available("numpy") is True
    assert engine.backend_available("jax-scan") is True
    assert engine.backend_available("no-such-backend") is False


def test_unknown_backend_error_lists_registry():
    with pytest.raises(KeyError, match="no-such-backend"):
        engine.simulate(CFG, backend="no-such-backend")


def test_failed_backend_reason_surfaced(monkeypatch):
    """A recorded registration failure shows up in backend_available and in
    the KeyError raised for the failed backend."""
    from repro.core import session

    monkeypatch.setitem(session._FAILED, "pallas-broken",
                        "ImportError: no module named 'jax.experimental'")
    avail = engine.backend_available("pallas-broken")
    assert isinstance(avail, str) and "ImportError" in avail
    with pytest.raises(KeyError, match="failed to register"):
        Engine("pallas-broken")


def test_simulate_scenario_accepts_none_overrides():
    import inspect

    sig = inspect.signature(engine.simulate_scenario)
    assert sig.parameters["config_overrides"].default is None
    r = engine.simulate_scenario(
        "flash-crash", backend="numpy",
        config_overrides={"num_markets": 4, "num_agents": 16,
                          "num_levels": 16, "num_steps": 8})
    assert np.asarray(r.price_path).shape == (4, 8)
