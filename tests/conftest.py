import os
import sys

# Tests run on the single host device (multi-device cases force N host
# devices in their own subprocess, or are `distributed`-marked).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
